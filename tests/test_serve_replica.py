"""Replicated self-healing serving: HealthTracker state transitions,
replica routing/failover/hedging, degraded-mode coverage accounting,
supervised restart, queue checkpointing across restarts, admission
re-pricing, alert webhooks, and FlakyStore on the scheduler/router path."""
import http.server
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core import DiskJoinIndex, JoinConfig
from repro.data import clustered_vectors
from repro.ft import FaultInjector, FlakyStore, InjectedKill
from repro.obs import WebhookSink
from repro.plan import predict_replica_service_s
from repro.serve import (DEGRADED, DOWN, HEALTHY, AdmissionRejected,
                         DeadlineExceeded, HealthTracker, IndexRouter,
                         QueryScheduler, ReplicaSet, ReplicaSupervisor,
                         SchedulerClosed, ShardUnavailable)
from repro.store.vector_store import FlatVectorStore

EPS = 0.35


@pytest.fixture(scope="module")
def data():
    return clustered_vectors(2200, 24, seed=9)


@pytest.fixture(scope="module")
def workdirs(data, tmp_path_factory):
    """Two shard manifests built once per module (replica tests reopen
    them freely — open() does no dataset rescan)."""
    root = tmp_path_factory.mktemp("replica_shards")
    x = data
    cfg = JoinConfig(epsilon=EPS, recall_target=0.9, pad_align=64,
                     num_buckets=20, memory_budget_bytes=1 << 20)
    parts = [x[:1100], x[1100:]]
    dirs = []
    for i, part in enumerate(parts):
        store = FlatVectorStore.from_array(str(root / f"x{i}.bin"), part)
        DiskJoinIndex.build(store, cfg, str(root / f"shard{i}")).close()
        dirs.append(str(root / f"shard{i}"))
    return dirs, parts


def _open(d):
    return DiskJoinIndex.open(d)


def _truth(part, q, eps=EPS):
    return set(np.where(
        np.linalg.norm(part - q[None, :], axis=1) <= eps)[0].tolist())


def _equalize(rset):
    """Pin every replica's service EWMA to one value so the near-equal
    rotation in ``_pick`` is deterministic — seed queries measure OS
    page-cache noise (first toucher pays the cold read), which can park
    one replica 30x above its sibling and exclude it from rotation."""
    for r in rset.replicas:
        r.service_ewma = 0.001


# ---------------------------------------------------------------------------
# HealthTracker
# ---------------------------------------------------------------------------
class TestHealthTracker:
    def test_state_transitions_from_outcomes(self):
        h = HealthTracker(window=8, min_events=4)
        assert h.state == HEALTHY
        for _ in range(6):
            h.record_ok()
        assert h.state == HEALTHY
        h.record_error(IOError("x"))
        assert h.state == DEGRADED          # 1/7 >= 0.1 error rate
        for _ in range(5):
            h.record_error(IOError("x"))
        assert h.state == DOWN              # 6/8 >= 0.5 in window
        h.reset()
        assert h.state == HEALTHY

    def test_injected_kill_is_immediate_down(self):
        h = HealthTracker()
        h.record_error(InjectedKill("dead"))
        assert h.state == DOWN              # no min_events grace
        assert h.snapshot()["down_reason"]
        h.reset()
        assert h.state == HEALTHY

    def test_drop_rate_degrades(self):
        h = HealthTracker(window=8, min_events=4, degraded_drop_rate=0.25)
        for _ in range(3):
            h.record_ok()
        h.record_drop()
        assert h.state == DEGRADED

    def test_slo_burn_state_folds_in(self):
        firing = [0]
        h = HealthTracker(slo_source=lambda: firing[0])
        assert h.state == HEALTHY
        firing[0] = 2
        assert h.state == DEGRADED
        firing[0] = 0
        assert h.state == HEALTHY

    def test_io_read_errors_fold_in(self):
        counters = {"io_read_errors": 0}
        h = HealthTracker(pipeline_source=lambda: dict(counters),
                          io_error_limit=4)
        assert h.state == HEALTHY
        counters["io_read_errors"] = 5
        assert h.state == DEGRADED
        h.reset()                           # new baseline
        assert h.state == HEALTHY

    def test_mark_down_and_snapshot(self):
        h = HealthTracker()
        h.mark_down("operator said so")
        assert h.state == DOWN
        snap = h.snapshot()
        assert snap["state"] == DOWN
        assert snap["down_reason"] == "operator said so"


def test_predict_replica_service_s():
    # no backlog: the request's own service
    assert predict_replica_service_s(0.01, 0) == pytest.approx(0.01)
    # backlog drains at the modeled rate absent an observation
    assert predict_replica_service_s(0.01, 3) == pytest.approx(0.04)
    # an observed EWMA overrides the modeled per-request rate
    assert predict_replica_service_s(0.01, 3, observed_s=0.002) \
        == pytest.approx(0.016)


# ---------------------------------------------------------------------------
# replica sets: routing, parity, failover
# ---------------------------------------------------------------------------
class TestReplicaSet:
    def test_replicated_router_byte_parity_with_single(self, data,
                                                       workdirs):
        dirs, _ = workdirs
        single = IndexRouter([_open(d) for d in dirs], epsilon=EPS,
                             close_shards=True,
                             scheduler=dict(max_wait_s=0.001))
        repl = IndexRouter([[_open(d), _open(d)] for d in dirs],
                           epsilon=EPS, close_shards=True,
                           scheduler=dict(max_wait_s=0.001))
        try:
            for q in data[::150]:
                i1, d1 = single.query(q + 0.001, timeout=120)
                i2, d2 = repl.query(q + 0.001, timeout=120)
                assert np.array_equal(i1, i2)
                assert np.array_equal(d1, d2)
        finally:
            single.close()
            repl.close()

    def test_kill_fails_over_without_request_loss(self, data, workdirs):
        dirs, parts = workdirs
        rset = ReplicaSet([_open(dirs[0]), _open(dirs[0])], epsilon=EPS,
                          scheduler=dict(max_wait_s=0.001))
        try:
            for q in parts[0][:4]:          # warm + seed estimates
                rset.query(q + 0.001, timeout=120)
            FaultInjector().kill_replica(rset.replicas[0])
            _equalize(rset)
            for i in range(20):
                q = parts[0][i * 3] + 0.001
                ids, _ = rset.query(q, timeout=120)
                assert set(ids.tolist()) == _truth(parts[0], q)
            snap = rset.snapshot()
            assert snap["counters"]["failovers"] >= 1
            assert snap["replicas"][0]["health"]["state"] == DOWN
            assert snap["replicas"][1]["health"]["state"] == HEALTHY
            # the DOWN replica is ejected: subsequent picks skip it
            assert rset._pick([]) is rset.replicas[1]
        finally:
            rset.close(close_indexes=True)

    def test_degraded_replica_deprioritized(self, workdirs):
        dirs, parts = workdirs
        rset = ReplicaSet([_open(dirs[0]), _open(dirs[0])], epsilon=EPS)
        try:
            for _ in range(4):
                rset.replicas[0].health.record_drop()
            assert rset.replicas[0].health.state == DEGRADED
            # healthy sibling takes every pick while it can
            picks = {rset._pick([]) for _ in range(6)}
            assert picks == {rset.replicas[1]}
            # ... but a degraded replica still serves as last resort
            assert rset._pick([rset.replicas[1]]) is rset.replicas[0]
        finally:
            rset.close(close_indexes=True)

    def test_round_robin_policy_spreads(self, workdirs):
        dirs, _ = workdirs
        rset = ReplicaSet([_open(dirs[0]), _open(dirs[0])], epsilon=EPS,
                          policy="round_robin")
        try:
            picks = [rset._pick([]) for _ in range(4)]
            assert set(picks) == set(rset.replicas)
        finally:
            rset.close(close_indexes=True)

    def test_least_loaded_avoids_backlogged_replica(self, workdirs):
        dirs, _ = workdirs
        rset = ReplicaSet([_open(dirs[0]), _open(dirs[0])], epsilon=EPS)
        try:
            r0, r1 = rset.replicas
            r0.service_ewma = r1.service_ewma = 0.01
            r0.inflight = 64                # deep backlog on replica 0
            picks = {rset._pick([]) for _ in range(6)}
            assert picks == {r1}
        finally:
            rset.close(close_indexes=True)

    def test_policy_validation(self, workdirs):
        dirs, _ = workdirs
        idx = _open(dirs[0])
        try:
            with pytest.raises(ValueError, match="policy"):
                ReplicaSet([idx], epsilon=EPS, policy="darts")
            with pytest.raises(ValueError, match="hedge"):
                ReplicaSet([idx], epsilon=EPS, hedge=-1.0)
        finally:
            idx.close()

    def test_hedged_probe_rescues_browned_out_replica(self, data,
                                                      workdirs):
        dirs, parts = workdirs
        rset = ReplicaSet([_open(dirs[0]), _open(dirs[0])], epsilon=EPS,
                          scheduler=dict(max_wait_s=0.001), hedge="plan")
        try:
            for q in parts[0][:6]:          # seed service estimates
                rset.query(q + 0.001, timeout=120)
            inj = FaultInjector()
            inj.brownout(rset.replicas[0], extra_latency_s=0.05)
            rset.replicas[0].index.drop_warm_cache()
            _equalize(rset)
            for i in range(12):
                q = parts[0][5 + i * 7] + 0.002
                ids, _ = rset.query(q, timeout=120)
                assert set(ids.tolist()) == _truth(parts[0], q)
            c = rset.snapshot()["counters"]
            assert c["hedges"] >= 1         # slow replica tripped hedging
        finally:
            rset.close(close_indexes=True)


# ---------------------------------------------------------------------------
# degraded-mode coverage contract
# ---------------------------------------------------------------------------
class TestCoverage:
    def _dead_router(self, dirs, **kw):
        router = IndexRouter([[_open(dirs[0]), _open(dirs[0])],
                              [_open(dirs[1])]], epsilon=EPS,
                             close_shards=True,
                             scheduler=dict(max_wait_s=0.001), **kw)
        inj = FaultInjector()
        for r in router.replica_sets[1].replicas:
            inj.kill_replica(r)
            r.health.mark_down("killed for coverage test")
        return router

    def test_strict_mode_raises_on_dead_shard(self, data, workdirs):
        dirs, _ = workdirs
        router = self._dead_router(dirs)
        try:
            # epsilon large enough that the fan-out must include the
            # dead shard — strict mode cannot answer
            q = data[0] + 0.001
            assert router.route(q, epsilon=1e3) == [0, 1]
            with pytest.raises(ShardUnavailable):
                router.query(q, epsilon=1e3, timeout=120)
        finally:
            router.close()

    def test_partial_result_with_coverage(self, data, workdirs):
        dirs, parts = workdirs
        router = self._dead_router(dirs, require_full_coverage=False)
        try:
            # epsilon large enough that every query fans to both shards
            q = data[0] + 0.001
            fut = router.submit(q, epsilon=1e3)
            assert fut.coverage is None        # set at gather, not submit
            ids, dists = fut.result(timeout=120)
            cov = fut.coverage
            assert cov is not None and not cov.complete
            assert cov.total == 2 and cov.answered == 1
            by_shard = {s.shard: s for s in cov.statuses}
            assert by_shard[0].status == "ok"
            assert by_shard[1].status == "unavailable"
            assert "ShardUnavailable" in by_shard[1].error
            # the surviving shard's answer is complete and correctly
            # offset into the global id space (shard 0 owns [0, 1100))
            assert set(ids.tolist()) == _truth(parts[0], q, eps=1e3)
            d = cov.to_dict()
            assert d["complete"] is False and len(d["statuses"]) == 2
        finally:
            router.close()

    def test_per_request_override_beats_router_default(self, data,
                                                       workdirs):
        dirs, _ = workdirs
        router = self._dead_router(dirs)     # strict default
        try:
            q = data[0] + 0.001
            fut = router.submit(q, epsilon=1e3,
                                require_full_coverage=False)
            fut.result(timeout=120)
            assert fut.coverage.answered == 1
        finally:
            router.close()

    def test_full_coverage_reported_when_healthy(self, data, workdirs):
        dirs, _ = workdirs
        router = IndexRouter([_open(d) for d in dirs], epsilon=EPS,
                             close_shards=True,
                             require_full_coverage=False,
                             scheduler=dict(max_wait_s=0.001))
        try:
            fut = router.submit(data[0] + 0.001, epsilon=1e3)
            fut.result(timeout=120)
            assert fut.coverage.complete
            assert fut.coverage.answered == fut.coverage.total == 2
        finally:
            router.close()


# ---------------------------------------------------------------------------
# supervised restart
# ---------------------------------------------------------------------------
class TestReplicaSupervisor:
    def test_restart_reopens_probes_and_readmits(self, workdirs):
        dirs, parts = workdirs
        rset = ReplicaSet([_open(dirs[0]), _open(dirs[0])], epsilon=EPS,
                          scheduler=dict(max_wait_s=0.001))
        events = []
        sup = ReplicaSupervisor(rset, poll_s=0.02, backoff_s=0.05,
                                on_event=events.append)
        try:
            for q in parts[0][:4]:
                rset.query(q + 0.001, timeout=120)
            dead_index = rset.replicas[0].index
            FaultInjector().kill_replica(rset.replicas[0])
            _equalize(rset)
            # the kill surfaces organically: failover records the
            # InjectedKill into health, which latches DOWN
            for q in parts[0][4:8]:
                rset.query(q + 0.001, timeout=120)
            assert rset.replicas[0].health.state == DOWN
            assert sup.poll_once() == 1
            assert sup.restarts == 1
            assert rset.replicas[0].health.state == HEALTHY
            assert rset.replicas[0].index is not dead_index
            assert rset.replicas[0].restarts == 1
            assert [e["event"] for e in events].count("restart_ok") == 1
            # the restarted replica serves real traffic again
            q = parts[0][9] + 0.001
            ids, _ = rset.replicas[0].scheduler.query(q, timeout=120)
            assert set(ids.tolist()) == _truth(parts[0], q)
            assert rset.snapshot()["counters"]["restarts"] == 1
        finally:
            sup.close()
            rset.close(close_indexes=True)

    def test_restart_resumes_spilled_queue(self, workdirs):
        dirs, parts = workdirs
        # wide wave window: submitted requests sit in the queue long
        # enough that the kill catches them pending and the spill path
        # carries them over (but narrow enough for the restart probe)
        rset = ReplicaSet([_open(dirs[0])], epsilon=EPS,
                          scheduler=dict(max_wait_s=2.0, wave_size=64))
        sup = ReplicaSupervisor(rset, poll_s=0.02, backoff_s=0.05)
        try:
            replica = rset.replicas[0]
            futs = [replica.scheduler.submit(parts[0][i] + 0.001,
                                             deadline_s=300.0)
                    for i in range(5)]
            replica.health.mark_down("test kill with queued work")
            assert sup.poll_once() == 1
            # spilled futures failed fast (a replica-set caller would
            # fail over); the resumed copies complete on the fresh one
            for f in futs:
                assert isinstance(f.exception(timeout=30),
                                  SchedulerClosed)
            sched = replica.scheduler
            assert len(sched.resumed) == 5
            for i, f in enumerate(sched.resumed):
                ids, _ = f.result(timeout=120)
                assert set(ids.tolist()) \
                    == _truth(parts[0], parts[0][i] + 0.001)
        finally:
            sup.close()
            rset.close(close_indexes=True)

    def test_failed_restart_backs_off(self, workdirs, monkeypatch):
        dirs, _ = workdirs
        rset = ReplicaSet([_open(dirs[0])], epsilon=EPS)
        sup = ReplicaSupervisor(rset, poll_s=0.02, backoff_s=0.2,
                                backoff_cap_s=0.4)
        try:
            replica = rset.replicas[0]
            replica.health.mark_down("test")
            monkeypatch.setattr(DiskJoinIndex, "open",
                                classmethod(lambda *a, **k: (_ for _ in ())
                                            .throw(OSError("disk gone"))))
            assert sup.poll_once() == 0
            assert sup.failed_restarts == 1
            assert replica.backoff_s == pytest.approx(0.2)
            assert replica.health.state == DOWN
            # within the backoff window nothing is attempted
            assert sup.poll_once() == 0
            assert sup.failed_restarts == 1
            time.sleep(0.25)
            assert sup.poll_once() == 0     # still failing
            assert sup.failed_restarts == 2
            assert replica.backoff_s == pytest.approx(0.4)
        finally:
            monkeypatch.undo()
            sup.close()
            rset.close(close_indexes=True)

    def test_background_thread_restarts(self, workdirs):
        dirs, parts = workdirs
        rset = ReplicaSet([_open(dirs[0])], epsilon=EPS,
                          scheduler=dict(max_wait_s=0.001))
        with ReplicaSupervisor(rset, poll_s=0.02, backoff_s=0.05):
            rset.replicas[0].health.mark_down("bg test")
            deadline = time.time() + 30
            while (rset.replicas[0].health.state != HEALTHY
                   and time.time() < deadline):
                time.sleep(0.02)
            assert rset.replicas[0].health.state == HEALTHY
        q = parts[0][2] + 0.001
        ids, _ = rset.query(q, timeout=120)
        assert set(ids.tolist()) == _truth(parts[0], q)
        rset.close(close_indexes=True)


# ---------------------------------------------------------------------------
# queue checkpoint across scheduler restarts (ft follow-on)
# ---------------------------------------------------------------------------
class TestQueueCheckpoint:
    def test_spill_and_resume_preserves_requests(self, workdirs,
                                                 tmp_path):
        dirs, parts = workdirs
        idx = _open(dirs[0])
        path = str(tmp_path / "queue.json")
        try:
            s1 = QueryScheduler(idx, epsilon=EPS, max_wait_s=30.0,
                                wave_size=64)
            futs = [s1.submit(parts[0][i] + 0.001, k=7,
                              deadline_s=300.0) for i in range(4)]
            futs.append(s1.submit(parts[0][4] + 0.001))   # no deadline
            s1.close(persist_queue=path)
            assert os.path.exists(path)
            spill = json.load(open(path))
            assert spill["format"] == "diskjoin-queue/v1"
            assert len(spill["requests"]) == 5
            assert spill["requests"][0]["k"] == 7
            assert 0 < spill["requests"][0]["remaining_s"] <= 300.0
            assert spill["requests"][4]["remaining_s"] is None
            for f in futs:
                assert isinstance(f.exception(timeout=30),
                                  SchedulerClosed)
            s2 = QueryScheduler(idx, epsilon=EPS, max_wait_s=0.001,
                                resume_queue=path)
            assert not os.path.exists(path)   # consumed, no double-resume
            assert len(s2.resumed) == 5
            for i, f in enumerate(s2.resumed):
                ids, _ = f.result(timeout=120)
                expect = _truth(parts[0], parts[0][i] + 0.001)
                if i < 4:
                    assert len(ids) == min(7, len(expect))
                else:
                    assert set(ids.tolist()) == expect
            assert s2.snapshot()["resumed"] == 5
            s2.close()
        finally:
            idx.close()

    def test_expired_deadline_resumes_as_honest_drop(self, workdirs,
                                                     tmp_path):
        dirs, parts = workdirs
        idx = _open(dirs[0])
        path = str(tmp_path / "queue.json")
        try:
            s1 = QueryScheduler(idx, epsilon=EPS, max_wait_s=30.0,
                                wave_size=64)
            s1.submit(parts[0][0] + 0.001, deadline_s=0.05)
            s1.close(persist_queue=path)
            time.sleep(0.1)                   # deadline expires off-line
            s2 = QueryScheduler(idx, epsilon=EPS, max_wait_s=0.001,
                                resume_queue=path)
            assert len(s2.resumed) == 1
            with pytest.raises(DeadlineExceeded):
                s2.resumed[0].result(timeout=30)
            s2.close()
        finally:
            idx.close()

    def test_plain_close_still_drains(self, workdirs):
        dirs, parts = workdirs
        idx = _open(dirs[0])
        try:
            s = QueryScheduler(idx, epsilon=EPS, max_wait_s=5.0,
                               wave_size=64)
            fut = s.submit(parts[0][0] + 0.001)
            s.close()                         # no persist: executes
            ids, _ = fut.result(timeout=0)
            assert set(ids.tolist()) == _truth(parts[0],
                                               parts[0][0] + 0.001)
        finally:
            idx.close()

    def test_resume_rejects_foreign_file(self, workdirs, tmp_path):
        dirs, _ = workdirs
        idx = _open(dirs[0])
        path = str(tmp_path / "bogus.json")
        json.dump({"format": "something/else"}, open(path, "w"))
        try:
            with pytest.raises(ValueError, match="diskjoin-queue"):
                QueryScheduler(idx, epsilon=EPS, resume_queue=path)
        finally:
            idx.close()


# ---------------------------------------------------------------------------
# admission re-pricing (planner follow-on)
# ---------------------------------------------------------------------------
class TestAdmissionRepricing:
    def test_rejection_carries_feasible_deadline(self, workdirs):
        dirs, parts = workdirs
        idx = _open(dirs[0])
        try:
            s = QueryScheduler(idx, epsilon=EPS, admission="estimate",
                               max_wait_s=0.0,
                               emulate_read_latency_s=0.05)
            with pytest.raises(AdmissionRejected) as ei:
                s.submit(parts[0][0] + 0.001, deadline_s=0.001)
            exc = ei.value
            assert exc.suggested_deadline_s is not None
            assert exc.suggested_deadline_s > exc.predicted_s
            assert "feasible deadline" in str(exc)
            # re-pricing works: the suggested deadline is admitted
            fut = s.submit(parts[0][0] + 0.001,
                           deadline_s=exc.suggested_deadline_s)
            ids, _ = fut.result(timeout=120)
            assert set(ids.tolist()) == _truth(parts[0],
                                               parts[0][0] + 0.001)
            s.close()
        finally:
            idx.close()


# ---------------------------------------------------------------------------
# alert webhooks (obs follow-on)
# ---------------------------------------------------------------------------
class _Hook(http.server.BaseHTTPRequestHandler):
    received: list = []

    def do_POST(self):
        n = int(self.headers.get("Content-Length", 0))
        _Hook.received.append(json.loads(self.rfile.read(n)))
        self.send_response(200)
        self.end_headers()

    def log_message(self, *a):
        pass


class TestWebhookSink:
    def test_delivers_alert_payloads(self):
        _Hook.received = []
        srv = http.server.HTTPServer(("127.0.0.1", 0), _Hook)
        threading.Thread(target=srv.serve_forever, daemon=True).start()
        try:
            sink = WebhookSink(f"http://127.0.0.1:{srv.server_port}/h")
            sink({"slo": "latency", "state": "firing", "fast_burn": 9.0})
            deadline = time.time() + 10
            while not _Hook.received and time.time() < deadline:
                time.sleep(0.01)
            assert _Hook.received == [{"slo": "latency",
                                       "state": "firing",
                                       "fast_burn": 9.0}]
            assert sink.snapshot()["delivered"] == 1
            sink.close()
        finally:
            srv.shutdown()

    def test_wired_into_slo_monitor(self):
        from repro.obs.live import Alert

        _Hook.received = []
        srv = http.server.HTTPServer(("127.0.0.1", 0), _Hook)
        threading.Thread(target=srv.serve_forever, daemon=True).start()
        try:
            sink = WebhookSink(f"http://127.0.0.1:{srv.server_port}/h")
            # the sink is a plain on_alert callback: Alert objects
            # serialize through to_dict()
            alert = Alert("goodput", "firing", 1.0, 15.0, 6.0, 0.5, "m")
            sink(alert)
            deadline = time.time() + 10
            while not _Hook.received and time.time() < deadline:
                time.sleep(0.01)
            assert _Hook.received[0]["slo"] == "goodput"
            assert _Hook.received[0]["state"] == "firing"
            sink.close()
        finally:
            srv.shutdown()

    def test_failures_counted_never_raised(self):
        # nothing listens on this port: delivery fails, the fold path
        # (the __call__) never sees it
        sink = WebhookSink("http://127.0.0.1:9/h", timeout_s=0.2)
        sink({"slo": "x", "state": "firing"})
        deadline = time.time() + 10
        while sink.snapshot()["failures"] == 0 and time.time() < deadline:
            time.sleep(0.01)
        assert sink.snapshot()["failures"] == 1
        assert sink.snapshot()["delivered"] == 0
        sink.close()

    def test_full_queue_drops_without_blocking(self):
        sink = WebhookSink("http://127.0.0.1:9/h", queue_size=1,
                           timeout_s=5.0)
        sink._post = lambda payload: time.sleep(0.3)   # slow delivery
        t0 = time.perf_counter()
        for i in range(50):
            sink({"i": i})
        assert time.perf_counter() - t0 < 1.0   # never blocked the caller
        assert sink.snapshot()["dropped"] >= 1
        sink.close(timeout=0.5)


# ---------------------------------------------------------------------------
# FlakyStore on the scheduler/router path
# ---------------------------------------------------------------------------
class TestFlakyServing:
    def test_transient_errors_retry_in_place(self, workdirs):
        """Wave execution under transient read errors: the capped-backoff
        retry absorbs them inside the wave — no failover, no loss."""
        dirs, parts = workdirs
        idx = _open(dirs[0])
        try:
            idx.store = FlakyStore(idx.store, read_error_every=3)
            s = QueryScheduler(idx, epsilon=EPS, max_wait_s=0.001)
            for i in range(10):
                q = parts[0][i * 5] + 0.001
                ids, _ = s.query(q, timeout=120)
                assert set(ids.tolist()) == _truth(parts[0], q)
            snap = idx.stats.snapshot()
            assert idx.store.errors_injected >= 1
            assert snap["io_retries"] >= 1
            assert snap["io_read_errors"] >= 1
            s.close()
        finally:
            idx.close()

    def test_permanent_failure_fails_over_not_loses(self, workdirs):
        """A replica whose store dies permanently (retries exhausted)
        triggers failover to the sibling — every request still answers."""
        dirs, parts = workdirs
        rset = ReplicaSet([_open(dirs[0]), _open(dirs[0])], epsilon=EPS,
                          scheduler=dict(max_wait_s=0.001,
                                         io_retries=1))
        try:
            for q in parts[0][:4]:
                rset.query(q + 0.001, timeout=120)
            # every read fails: retries can never absorb it
            FaultInjector().flaky_replica(rset.replicas[0], every=1)
            rset.replicas[0].index.drop_warm_cache()
            _equalize(rset)
            for i in range(16):
                q = parts[0][i * 4] + 0.001
                ids, _ = rset.query(q, timeout=120)
                assert set(ids.tolist()) == _truth(parts[0], q)
            snap = rset.snapshot()
            assert snap["counters"]["failovers"] >= 1
            assert snap["replicas"][0]["health"]["state"] in (DEGRADED,
                                                              DOWN)
        finally:
            rset.close(close_indexes=True)

    def test_brownout_verb_scales_latency(self, workdirs):
        dirs, _ = workdirs
        idx = _open(dirs[0])
        try:
            idx.store.read_latency_s = 0.01
            store = FaultInjector().brownout(idx, latency_x=4.0)
            assert store.extra_latency_s == pytest.approx(0.03)
        finally:
            idx.close()
