"""Launch-layer tests: HLO census correctness, roofline math, dry-run
record integrity (when results/ exists), mesh planning."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import summarize_cost
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   model_flops_per_device, roofline_terms)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


class TestHloCensus:
    def test_scan_trip_count_multiplied(self):
        def f(x):
            def body(c, _):
                return c @ c, None
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        c = jax.jit(f).lower(x).compile()
        r = analyze_hlo(c.as_text())
        assert r["flops"] == 10 * 2 * 128 ** 3
        # XLA's own analysis undercounts — that's why the census exists
        assert summarize_cost(c.cost_analysis())["flops"] < r["flops"]

    def test_nested_scan(self):
        def g(x):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ ci, None
                y, _ = jax.lax.scan(inner, c, None, length=5)
                return y, None
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = jax.jit(g).lower(x).compile()
        assert analyze_hlo(c.as_text())["flops"] == 15 * 2 * 64 ** 3

    def test_flash_region_attribution(self):
        def f(q, k):
            with jax.named_scope("flash_attn_region"):
                return q @ k.T
        q = jax.ShapeDtypeStruct((128, 64), jnp.float32)
        c = jax.jit(f).lower(q, q).compile()
        r = analyze_hlo(c.as_text())
        assert r["flash_region_flops"] == 2 * 128 * 128 * 64
        assert r["flash_region_flops"] == r["flops"]

    def test_bytes_bracket_ordering(self):
        def f(a, b):
            return jnp.tanh(a @ b) + 1.0
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c = jax.jit(f).lower(a, a).compile()
        r = analyze_hlo(c.as_text())
        assert 0 < r["bytes_lo"] <= r["bytes_hi"]


class TestRooflineMath:
    def _rec(self, **kw):
        base = dict(
            status="ok", arch="qwen3-0.6b", shape="train_4k", mesh="16x16",
            tag="baseline", step="train_step",
            active_params=10 ** 9, tokens=10 ** 6, chips=256,
            hlo_cost={"flops": 1e13, "bytes_lo": 1e11, "bytes_hi": 2e11,
                      "collective_traffic_bytes": 1e10},
            memory={"argument_size_in_bytes": 0, "output_size_in_bytes": 0,
                    "alias_size_in_bytes": 0, "bytes_per_device": 1e9},
        )
        base.update(kw)
        return base

    def test_terms_and_dominance(self):
        t = roofline_terms(self._rec())
        assert abs(t["compute_s"] - 1e13 / PEAK_FLOPS) < 1e-12
        assert abs(t["memory_s"] - 1e11 / HBM_BW) < 1e-12
        assert abs(t["collective_s"] - 1e10 / ICI_BW) < 1e-12
        assert t["dominant"] == "collective"
        assert 0 < t["roofline_fraction"] <= 1.5

    def test_model_flops_kinds(self):
        train = model_flops_per_device(self._rec())
        pre = model_flops_per_device(self._rec(step="prefill_step"))
        assert train == 3 * pre  # 6·N·D vs 2·N·D

    def test_skipped_and_partial_records_pass_through(self):
        assert roofline_terms({"status": "skipped"}) is None
        assert roofline_terms({"status": "ok"}) is None  # no hlo_cost


@pytest.mark.skipif(not os.path.exists(RESULTS),
                    reason="dry-run results not generated")
class TestDryrunRecords:
    def test_full_40_cell_coverage_both_meshes(self):
        rows = json.load(open(RESULTS))
        base = [r for r in rows if r.get("tag") == "baseline"]
        cells = {(r["arch"], r["shape"], r["mesh"]) for r in base}
        archs = ["gemma3-4b", "mistral-nemo-12b", "qwen3-0.6b",
                 "chatglm3-6b", "deepseek-moe-16b", "olmoe-1b-7b",
                 "mamba2-1.3b", "recurrentgemma-2b", "internvl2-26b",
                 "whisper-small"]
        shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
        for a in archs:
            for s in shapes:
                for m in ("16x16", "2x16x16"):
                    assert (a, s, m) in cells, f"missing cell {(a, s, m)}"

    def test_no_error_cells(self):
        rows = json.load(open(RESULTS))
        errs = [(r["arch"], r["shape"], r["mesh"], r.get("tag"))
                for r in rows if r["status"] == "error"]
        assert not errs, errs

    def test_skips_match_design_matrix(self):
        rows = json.load(open(RESULTS))
        skipped = {(r["arch"], r["shape"]) for r in rows
                   if r["status"] == "skipped" and r.get("tag") == "baseline"}
        expected = {(a, "long_500k") for a in
                    ("mistral-nemo-12b", "qwen3-0.6b", "chatglm3-6b",
                     "deepseek-moe-16b", "olmoe-1b-7b", "internvl2-26b",
                     "whisper-small")}
        assert skipped == expected

    def test_sub_quadratic_archs_run_long_context(self):
        rows = json.load(open(RESULTS))
        ok = {(r["arch"], r["shape"]) for r in rows if r["status"] == "ok"}
        for a in ("mamba2-1.3b", "recurrentgemma-2b", "gemma3-4b"):
            assert (a, "long_500k") in ok


def test_plan_mesh_production_shapes():
    from repro.runtime import plan_mesh
    p = plan_mesh(512, global_batch=256)
    assert (p.pod, p.data, p.model) == (2, 16, 16)
    p = plan_mesh(256, global_batch=256)
    assert (p.data, p.model) == (16, 16) and p.pod == 1
