"""DiskJoinIndex session API: build→open manifest roundtrip, ε re-query
parity with one bucketization, online point-query recall, shared
pool/stats surface, config split validation, deprecation shims."""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import (BUILD_TIME_FIELDS, QUERY_TIME_FIELDS, TIMING_KEYS,
                        BuildConfig, DiskJoinIndex, JoinConfig, QueryConfig,
                        merge_config, recall, similarity_cross_join,
                        similarity_self_join, split_config)
from repro.data import brute_force_pairs, clustered_vectors
from repro.store.striped_store import StripedBucketedVectorStore
from repro.store.vector_store import FlatVectorStore


def _pair_keys(pairs):
    return set(map(tuple, np.asarray(pairs).tolist()))


@pytest.fixture(scope="module")
def data():
    x = clustered_vectors(2500, 24, seed=9)
    return x, 0.35


@pytest.fixture()
def flat_store(tmp_path):
    def make(x, name="x.bin"):
        return FlatVectorStore.from_array(str(tmp_path / name), x)
    return make


def _cfg(x, eps, **kw):
    base = dict(epsilon=eps, recall_target=0.9, pad_align=64,
                num_buckets=20, memory_budget_bytes=1 << 20)
    base.update(kw)
    return JoinConfig(**base)


# ---------------------------------------------------------------------------
# config split: the build/query partition is total and rejects crossover
# ---------------------------------------------------------------------------
class TestConfigSplit:
    def test_partition_is_total_and_disjoint(self):
        all_fields = {f.name for f in dataclasses.fields(JoinConfig)}
        assert BUILD_TIME_FIELDS | QUERY_TIME_FIELDS == all_fields
        assert not BUILD_TIME_FIELDS & QUERY_TIME_FIELDS
        assert {f.name for f in dataclasses.fields(BuildConfig)} \
            == BUILD_TIME_FIELDS
        assert {f.name for f in dataclasses.fields(QueryConfig)} \
            == QUERY_TIME_FIELDS

    def test_split_merge_roundtrip(self):
        cfg = JoinConfig(epsilon=0.2, num_buckets=7, io_devices=2,
                         io_coalesce=True, io_mode="prefetch", pad_align=32)
        assert merge_config(*split_config(cfg)) == cfg

    def test_defaults_agree_with_joinconfig(self):
        b, q = split_config(JoinConfig(epsilon=0.5))
        assert b == BuildConfig()
        assert q == QueryConfig(epsilon=0.5)

    def test_build_time_override_rejected(self, data, flat_store, tmp_path):
        x, eps = data
        index = DiskJoinIndex.build(flat_store(x), _cfg(x, eps),
                                    str(tmp_path / "idx"))
        with pytest.raises(ValueError, match="build-time"):
            index.self_join(num_buckets=5)
        with pytest.raises(ValueError, match="build-time"):
            index.query(x[0], eps, io_devices=2)
        with pytest.raises(TypeError, match="unknown"):
            index.self_join(bogus=1)
        index.close()


# ---------------------------------------------------------------------------
# build → open manifest roundtrip (striped and unstriped)
# ---------------------------------------------------------------------------
class TestBuildOpen:
    def test_roundtrip_unstriped(self, data, flat_store, tmp_path):
        x, eps = data
        wd = str(tmp_path / "idx")
        built = DiskJoinIndex.build(flat_store(x), _cfg(x, eps), wd)
        r_built = built.self_join()
        opened = DiskJoinIndex.open(wd)
        # no dataset rescan: metadata comes back identical from disk
        np.testing.assert_array_equal(opened.meta.centers,
                                      built.meta.centers)
        np.testing.assert_array_equal(opened.meta.sizes, built.meta.sizes)
        assert opened.build_config == built.build_config
        assert opened.query_defaults == built.query_defaults
        r_opened = opened.self_join()
        assert _pair_keys(r_opened.pairs) == _pair_keys(r_built.pairs)
        # reattach performed zero writes
        assert opened.store.stats.write_ops == 0
        built.close()
        opened.close()

    def test_roundtrip_striped(self, data, flat_store, tmp_path):
        x, eps = data
        wd = str(tmp_path / "idx_striped")
        cfg = _cfg(x, eps, io_devices=3, io_coalesce=True,
                   io_batch_reads=True, io_mode="prefetch", io_lookahead=12)
        built = DiskJoinIndex.build(flat_store(x), cfg, wd)
        r_built = built.self_join()
        opened = DiskJoinIndex.open(wd)
        assert isinstance(opened.store, StripedBucketedVectorStore)
        assert opened.store.num_devices == built.store.num_devices
        r_opened = opened.self_join()
        assert _pair_keys(r_opened.pairs) == _pair_keys(r_built.pairs)
        p = r_opened.io_stats["pipeline"]
        assert p["num_devices"] == opened.store.num_devices
        built.close()
        opened.close()

    def test_open_validates_build_half(self, data, flat_store, tmp_path):
        x, eps = data
        wd = str(tmp_path / "idx_v")
        DiskJoinIndex.build(flat_store(x), _cfg(x, eps), wd).close()
        with pytest.raises(ValueError, match="build-time parameters"):
            DiskJoinIndex.open(wd, _cfg(x, eps, num_buckets=99))
        # query-half changes are fine
        opened = DiskJoinIndex.open(wd, _cfg(x, eps * 0.5))
        assert opened.query_defaults.epsilon == pytest.approx(eps * 0.5)
        opened.close()


# ---------------------------------------------------------------------------
# ε re-query: one bucketization, many thresholds (acceptance criterion)
# ---------------------------------------------------------------------------
class TestEpsilonSweep:
    def test_one_build_three_epsilons_matches_one_shot(self, data,
                                                       flat_store,
                                                       tmp_path):
        x, eps = data
        cfg = _cfg(x, eps)
        index = DiskJoinIndex.build(flat_store(x), cfg,
                                    str(tmp_path / "idx"))
        writes_after_build = index.store.stats.write_ops
        assert writes_after_build > 0
        sweeps = (eps, eps * 0.7, eps * 1.2)
        for i, e in enumerate(sweeps):
            r_idx = index.self_join(epsilon=e)
            # exactly ONE bucketization: no further store writes, ever
            assert index.store.stats.write_ops == writes_after_build
            one_shot = similarity_self_join(
                flat_store(x, f"x{i}.bin"),
                dataclasses.replace(cfg, epsilon=e),
                workdir=str(tmp_path / f"os{i}"))
            assert _pair_keys(r_idx.pairs) == _pair_keys(one_shot.pairs)
        index.close()

    def test_timings_schema_uniform_across_join_kinds(self, data,
                                                      flat_store,
                                                      tmp_path):
        x, eps = data
        y = clustered_vectors(1200, 24, seed=11)
        ix = DiskJoinIndex.build(flat_store(x), _cfg(x, eps),
                                 str(tmp_path / "ix"))
        iy = DiskJoinIndex.build(flat_store(y, "y.bin"), _cfg(y, eps),
                                 str(tmp_path / "iy"))
        t_self = ix.self_join().timings
        t_cross = ix.cross_join(iy).timings
        top = lambda t: {k for k in t if "/" not in k}  # noqa: E731
        assert top(t_self) == set(TIMING_KEYS)
        assert top(t_cross) == set(TIMING_KEYS)
        ix.close()
        iy.close()


# ---------------------------------------------------------------------------
# online point queries
# ---------------------------------------------------------------------------
class TestPointQuery:
    def test_query_recall_and_precision_vs_brute_force(self, data,
                                                       flat_store,
                                                       tmp_path):
        x, eps = data
        index = DiskJoinIndex.build(flat_store(x),
                                    _cfg(x, eps, recall_target=0.95),
                                    str(tmp_path / "idx"))
        rng = np.random.default_rng(0)
        qids = rng.choice(x.shape[0], 40, replace=False)
        got_total = truth_total = hit_total = 0
        for qi in qids:
            ids, dists = index.query(x[qi], eps)
            d_true = np.linalg.norm(x - x[qi], axis=1)
            truth = set(np.flatnonzero(d_true <= eps).tolist())
            got = set(int(i) for i in ids)
            assert got <= truth  # perfect precision (exact distances)
            np.testing.assert_allclose(dists, d_true[ids], atol=1e-4)
            got_total += len(got)
            truth_total += len(truth)
            hit_total += len(got & truth)
        assert truth_total > 0
        assert hit_total / truth_total >= 0.9  # λ=0.95 with slack
        index.close()

    def test_query_batch_matches_single_queries(self, data, flat_store,
                                                tmp_path):
        x, eps = data
        index = DiskJoinIndex.build(flat_store(x), _cfg(x, eps),
                                    str(tmp_path / "idx"))
        Q = x[:8] + 0.01
        batch = index.query_batch(Q, eps)
        for qi in range(Q.shape[0]):
            ids, dists = index.query(Q[qi], eps)
            assert set(ids.tolist()) == set(batch[qi][0].tolist())
        index.close()

    def test_queries_share_pool_and_stats_with_batch_joins(self, data,
                                                           flat_store,
                                                           tmp_path):
        """Acceptance: query reads ride the shared BufferPool and land in
        the SAME PipelineStats snapshot as batch-join loads."""
        x, eps = data
        index = DiskJoinIndex.build(flat_store(x),
                                    _cfg(x, eps, io_mode="prefetch"),
                                    str(tmp_path / "idx"))
        r = index.self_join()          # batch join: loads > 0
        assert r.bucket_loads > 0
        index.query(x[3], eps)         # online lookup, same session
        index.query(x[3], eps)         # repeat: warm slab hits
        snap = index.pipeline_snapshot()
        assert snap["loads"] >= r.bucket_loads      # join traffic
        assert snap["query_reads"] > 0              # pooled query reads
        assert snap["query_warm_hits"] > 0          # warm-cache reuse
        assert snap["queries"] == 2
        # the warm cache holds pool slabs between queries
        assert len(index.warm_buckets()) > 0
        index.close()

    def test_concurrent_join_and_queries_one_pool(self, data, flat_store,
                                                  tmp_path):
        """A batch join and online queries run concurrently against one
        pool without deadlock; results of both stay correct."""
        x, eps = data
        index = DiskJoinIndex.build(flat_store(x),
                                    _cfg(x, eps, io_mode="prefetch",
                                         emulate_read_latency_s=2e-4),
                                    str(tmp_path / "idx"))
        ref = index.self_join()
        out = {}

        def joiner():
            out["res"] = index.self_join()

        t = threading.Thread(target=joiner)
        t.start()
        q_results = []
        while t.is_alive():
            q_results.append(index.query(x[11], eps))
        t.join(timeout=60)
        assert not t.is_alive()
        assert _pair_keys(out["res"].pairs) == _pair_keys(ref.pairs)
        expected = set(np.flatnonzero(
            np.linalg.norm(x - x[11], axis=1) <= eps).tolist())
        for ids, _ in q_results:
            assert set(ids.tolist()) <= expected
        index.close()


# ---------------------------------------------------------------------------
# serving facade
# ---------------------------------------------------------------------------
class TestVectorQueryService:
    def test_sorted_topk_and_snapshot(self, data, flat_store, tmp_path):
        from repro.serve import VectorQueryService
        x, eps = data
        index = DiskJoinIndex.build(flat_store(x), _cfg(x, eps),
                                    str(tmp_path / "idx"))
        svc = VectorQueryService(index)
        ids, dists = svc.query(x[2], k=3)
        assert len(ids) <= 3
        assert np.all(np.diff(dists) >= 0)      # nearest first
        assert int(ids[0]) == 2                 # itself at distance 0
        snap = svc.snapshot()
        assert snap["requests"] == 1
        assert snap["pipeline"]["queries"] == 1
        index.close()


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------
class TestDeprecationShims:
    def test_self_join_wrapper_warns_once_and_matches_index(
            self, data, flat_store, tmp_path):
        from repro.core import join as join_mod
        x, eps = data
        cfg = _cfg(x, eps)
        join_mod._deprecation_warned.clear()
        with pytest.deprecated_call():
            r_wrap = similarity_self_join(flat_store(x), cfg,
                                          workdir=str(tmp_path / "w"))
        # second call: silent (once per process)
        import warnings as _w
        with _w.catch_warnings(record=True) as rec:
            _w.simplefilter("always")
            similarity_self_join(flat_store(x, "x2.bin"), cfg,
                                 workdir=str(tmp_path / "w2"))
        assert not any(issubclass(i.category, DeprecationWarning)
                       for i in rec)
        index = DiskJoinIndex.build(flat_store(x, "x3.bin"), cfg,
                                    str(tmp_path / "idx"))
        r_idx = index.self_join()
        assert _pair_keys(r_wrap.pairs) == _pair_keys(r_idx.pairs)
        index.close()

    def test_cross_join_wrapper_warns_and_threads_attribute_mask(
            self, data, flat_store, tmp_path):
        from repro.core import join as join_mod
        x, eps = data
        rng = np.random.default_rng(12)
        y = (x[:1000] + rng.normal(scale=0.03, size=(1000, 24))
             ).astype(np.float32)
        mask = np.ones(x.shape[0] + y.shape[0], bool)
        mask[::3] = False
        cfg = _cfg(x, eps)
        join_mod._deprecation_warned.clear()
        with pytest.deprecated_call():
            r_wrap = similarity_cross_join(
                flat_store(x), flat_store(y, "y.bin"), cfg,
                workdir=str(tmp_path / "w"), attribute_mask=mask)
        assert r_wrap.pairs.shape[0] > 0
        assert mask[r_wrap.pairs].all()   # satellite: mask now threads
        ix = DiskJoinIndex.build(flat_store(x, "x2.bin"), cfg,
                                 str(tmp_path / "ix"), layout="spatial")
        iy = DiskJoinIndex.build(flat_store(y, "y2.bin"), cfg,
                                 str(tmp_path / "iy"), layout="spatial")
        r_idx = ix.cross_join(iy, attribute_mask=mask)
        assert _pair_keys(r_wrap.pairs) == _pair_keys(r_idx.pairs)
        with pytest.raises(ValueError, match="combined id space"):
            ix.cross_join(iy, attribute_mask=np.ones(7, bool))
        ix.close()
        iy.close()

    def test_self_join_full_pipeline_recall(self, data, flat_store,
                                            tmp_path):
        """The index path preserves the paper's end-to-end quality."""
        x, eps = data
        truth = brute_force_pairs(x, eps)
        index = DiskJoinIndex.build(flat_store(x), _cfg(x, eps),
                                    str(tmp_path / "idx"))
        r = index.self_join()
        assert recall(r.pairs, truth) >= 0.88
        index.close()
