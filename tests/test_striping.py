"""Multi-SSD striped store + batched/coalescing prefetch, and the PR's
bugfix regressions: writer extent overrun, distributed eviction keep-set,
pair-key overflow, and the pinned-eviction cache branches."""
import numpy as np
import pytest


def _pair_keys(pairs):
    return set(map(tuple, np.asarray(pairs).tolist()))


def _filled_writer(writer, sizes, dim, seed=0):
    rng = np.random.default_rng(seed)
    vid = 0
    data = {}
    for b, n in enumerate(sizes):
        rows = rng.normal(size=(int(n), dim)).astype(np.float32)
        data[b] = (rows, np.arange(vid, vid + int(n)))
        for i in range(int(n)):
            writer.append(b, rows[i], vid)
            vid += 1
    return data


# ---------------------------------------------------------------------------
# writer extent bounds (regression: silent overrun into the neighbor bucket)
# ---------------------------------------------------------------------------
class TestWriterBounds:
    def test_append_past_extent_raises_at_offending_append(self, tmp_path):
        from repro.store.vector_store import BucketedVectorStore

        sizes = np.array([2, 2])
        w = BucketedVectorStore.create(
            str(tmp_path / "bk"), 4, np.float32, sizes,
            np.zeros((2, 4), np.float32), np.ones(2, np.float32))
        v = np.zeros(4, np.float32)
        w.append(0, v, 0)
        w.append(0, v, 1)
        with pytest.raises(ValueError, match="bucket 0 overflow"):
            w.append(0, v, 2)
        # neighbor bucket untouched: finishing bucket 1 still works
        w.append(1, v, 3)
        w.append(1, v, 4)

    def test_overrun_caught_even_after_partial_flush(self, tmp_path):
        """Rows already flushed to disk (tiny buffer) must still count
        against the extent — the original bug wrote past it silently."""
        from repro.store.vector_store import _BucketedWriter
        from repro.store.io_stats import IOStats

        sizes = np.array([3, 2])
        w = _BucketedWriter(str(tmp_path / "bk"), 4, np.float32, sizes,
                            np.zeros((2, 4), np.float32),
                            np.ones(2, np.float32), IOStats(),
                            buffer_rows_per_bucket=1)  # flush every append
        v = np.zeros(4, np.float32)
        for i in range(3):
            w.append(0, v, i)
        with pytest.raises(ValueError, match="bucket 0 overflow"):
            w.append(0, v, 99)

    def test_finalize_mismatch_names_first_offending_bucket(self, tmp_path):
        from repro.store.vector_store import BucketedVectorStore

        sizes = np.array([1, 3, 2])
        w = BucketedVectorStore.create(
            str(tmp_path / "bk"), 4, np.float32, sizes,
            np.zeros((3, 4), np.float32), np.ones(3, np.float32))
        v = np.zeros(4, np.float32)
        w.append(0, v, 0)          # bucket 0 complete
        w.append(1, v, 1)          # bucket 1 short by 2
        w.append(2, v, 2)
        w.append(2, v, 3)          # bucket 2 complete
        with pytest.raises(ValueError, match="bucket 1 appended 1 rows"):
            w.finalize()


# ---------------------------------------------------------------------------
# disk layout order + coalesced run reads
# ---------------------------------------------------------------------------
class TestLayoutAndRuns:
    def test_layout_order_roundtrip_and_contiguity(self, tmp_path):
        from repro.store.vector_store import BucketedVectorStore

        sizes = np.array([3, 1, 4, 2])
        order = np.array([2, 0, 3, 1])  # disk order ≠ id order
        w = BucketedVectorStore.create(
            str(tmp_path / "bk"), 4, np.float32, sizes,
            np.zeros((4, 4), np.float32), np.ones(4, np.float32),
            layout_order=order)
        data = _filled_writer(w, sizes, 4)
        store = w.finalize()
        for b in range(4):
            vecs, ids = store.read_bucket(b)
            np.testing.assert_array_equal(vecs, data[b][0])
            np.testing.assert_array_equal(ids, data[b][1])
        # layout-adjacent buckets are disk-adjacent, id-adjacent are not
        assert store.contiguous_after(2, 0)
        assert store.contiguous_after(0, 3)
        assert store.contiguous_after(3, 1)
        assert not store.contiguous_after(0, 1)

    def test_bad_layout_order_rejected(self, tmp_path):
        from repro.store.vector_store import BucketedVectorStore

        with pytest.raises(ValueError, match="permutation"):
            BucketedVectorStore.create(
                str(tmp_path / "bk"), 4, np.float32, np.array([1, 1]),
                np.zeros((2, 4), np.float32), np.ones(2, np.float32),
                layout_order=np.array([0, 0]))

    def test_read_run_into_is_one_accounted_read(self, tmp_path):
        from repro.store.vector_store import BucketedVectorStore

        sizes = np.array([3, 2, 4])
        w = BucketedVectorStore.create(
            str(tmp_path / "bk"), 4, np.float32, sizes,
            np.zeros((3, 4), np.float32), np.ones(3, np.float32))
        data = _filled_writer(w, sizes, 4)
        store = w.finalize()
        cap = 6
        vecs = [np.empty((cap, 4), np.float32) for _ in range(3)]
        ids = [np.empty(cap, np.int64) for _ in range(3)]
        ops_before = store.stats.read_ops
        ns = store.read_run_into([0, 1, 2], vecs, ids, pad_value=7.0)
        assert ns == [3, 2, 4]
        # one vector read + one id-sidecar read for the whole 3-bucket run
        assert store.stats.read_ops - ops_before == 2
        for b in range(3):
            np.testing.assert_array_equal(vecs[b][:ns[b]], data[b][0])
            np.testing.assert_array_equal(ids[b][:ns[b]], data[b][1])
            assert (vecs[b][ns[b]:] == 7.0).all()
            assert (ids[b][ns[b]:] == -1).all()

    def test_fragmented_store_never_coalesces(self, tmp_path):
        """Emulated fragmentation (fig14) guarantees nothing contiguous:
        contiguous_after must refuse so coalescing can't model a single
        sequential read the fragmented file couldn't serve."""
        from repro.store.vector_store import BucketedVectorStore

        sizes = np.array([2, 2])
        w = BucketedVectorStore.create(
            str(tmp_path / "bk"), 4, np.float32, sizes,
            np.zeros((2, 4), np.float32), np.ones(2, np.float32))
        _filled_writer(w, sizes, 4)
        store = w.finalize()
        assert store.contiguous_after(0, 1)
        store.fragment_rows = 1
        assert not store.contiguous_after(0, 1)

    def test_read_run_rejects_non_contiguous(self, tmp_path):
        from repro.store.vector_store import BucketedVectorStore

        sizes = np.array([2, 2, 2])
        w = BucketedVectorStore.create(
            str(tmp_path / "bk"), 4, np.float32, sizes,
            np.zeros((3, 4), np.float32), np.ones(3, np.float32))
        _filled_writer(w, sizes, 4)
        store = w.finalize()
        vecs = [np.empty((2, 4), np.float32) for _ in range(2)]
        ids = [np.empty(2, np.int64) for _ in range(2)]
        with pytest.raises(ValueError, match="not disk-contiguous"):
            store.read_run_into([0, 2], vecs, ids)


# ---------------------------------------------------------------------------
# striped store: placement, roundtrip, device surface
# ---------------------------------------------------------------------------
class TestStripedStore:
    @pytest.mark.parametrize("stripe_by", ["phase", "hash"])
    def test_roundtrip_matches_plain_store(self, tmp_path, stripe_by):
        from repro.store.striped_store import StripedBucketedVectorStore

        rng = np.random.default_rng(1)
        sizes = rng.integers(1, 6, size=10)
        centers = rng.normal(size=(10, 4)).astype(np.float32)
        radii = np.ones(10, np.float32)
        w = StripedBucketedVectorStore.create(
            str(tmp_path / "st"), 4, np.float32, sizes, centers, radii,
            num_devices=4, stripe_by=stripe_by)
        data = _filled_writer(w, sizes, 4)
        store = w.finalize()
        assert store.num_devices == 4
        assert store.num_vectors == int(sizes.sum())
        for b in range(10):
            vecs, ids = store.read_bucket(b)
            np.testing.assert_array_equal(vecs, data[b][0])
            np.testing.assert_array_equal(ids, data[b][1])
        devs = [store.device_of(b) for b in range(10)]
        assert set(devs) == {0, 1, 2, 3}
        if stripe_by == "phase":  # round-robin in (identity) layout order
            assert devs == [b % 4 for b in range(10)]
        balance = store.device_loads_balanced()
        assert balance.sum() == store.nbytes
        assert (balance > 0).all()
        # reopen from disk
        reopened = StripedBucketedVectorStore(str(tmp_path / "st"))
        v2, i2 = reopened.read_bucket(3)
        np.testing.assert_array_equal(v2, data[3][0])
        reopened.close()
        store.close()

    def test_same_device_rank_neighbors_are_contiguous(self, tmp_path):
        from repro.store.striped_store import StripedBucketedVectorStore

        sizes = np.ones(8, np.int64) * 2
        w = StripedBucketedVectorStore.create(
            str(tmp_path / "st"), 4, np.float32, sizes,
            np.zeros((8, 4), np.float32), np.ones(8, np.float32),
            num_devices=2, stripe_by="phase")
        _filled_writer(w, sizes, 4)
        store = w.finalize()
        # phase striping over identity layout: device 0 holds 0,2,4,6 in
        # that order — rank neighbors on one device are disk-adjacent
        assert store.contiguous_after(0, 2)
        assert store.contiguous_after(2, 4)
        assert not store.contiguous_after(0, 1)   # different devices
        ns = store.read_run_into(
            [0, 2], [np.empty((2, 4), np.float32) for _ in range(2)],
            [np.empty(2, np.int64) for _ in range(2)])
        assert ns == [2, 2]
        with pytest.raises(ValueError, match="spans devices"):
            store.read_run_into(
                [0, 1], [np.empty((2, 4), np.float32) for _ in range(2)],
                [np.empty(2, np.int64) for _ in range(2)])

    def test_chunked_striping_compacts_empty_devices(self, tmp_path):
        """chunk 4 × 4 devices × 10 buckets would leave device 3 with no
        buckets — an unmappable empty file. Device ids must compact onto
        the devices actually used."""
        from repro.store.striped_store import StripedBucketedVectorStore

        sizes = np.full(10, 2, np.int64)
        w = StripedBucketedVectorStore.create(
            str(tmp_path / "st"), 4, np.float32, sizes,
            np.zeros((10, 4), np.float32), np.ones(10, np.float32),
            num_devices=4, stripe_by="phase", stripe_chunk=4)
        data = _filled_writer(w, sizes, 4)
        store = w.finalize()
        assert store.num_devices == 3  # ranks 0-3, 4-7, 8-9
        for b in range(10):
            vecs, _ = store.read_bucket(b)
            np.testing.assert_array_equal(vecs, data[b][0])

    def test_striped_writer_rejects_bad_layout_order(self, tmp_path):
        from repro.store.striped_store import StripedBucketedVectorStore

        with pytest.raises(ValueError, match="permutation"):
            StripedBucketedVectorStore.create(
                str(tmp_path / "st"), 4, np.float32, np.array([1, 1]),
                np.zeros((2, 4), np.float32), np.ones(2, np.float32),
                num_devices=2, layout_order=np.array([1, 1]))

    def test_striped_writer_overrun_names_global_bucket(self, tmp_path):
        from repro.store.striped_store import StripedBucketedVectorStore

        w = StripedBucketedVectorStore.create(
            str(tmp_path / "st"), 4, np.float32, np.array([1, 1, 1]),
            np.zeros((3, 4), np.float32), np.ones(3, np.float32),
            num_devices=2)
        v = np.zeros(4, np.float32)
        w.append(2, v, 0)
        with pytest.raises(ValueError, match="striped bucket 2"):
            w.append(2, v, 1)


# ---------------------------------------------------------------------------
# end-to-end parity: sync vs prefetch × 1 vs 4 stripes, self- and cross-join
# ---------------------------------------------------------------------------
class TestStripedParity:
    @pytest.mark.parametrize("devices,io_mode", [
        (1, "sync"), (1, "prefetch"), (4, "sync"), (4, "prefetch")])
    def test_self_join_identical_pairs(self, small_dataset, tmp_store,
                                       devices, io_mode):
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        base = dict(epsilon=eps, pad_align=64, num_buckets=24,
                    memory_budget_bytes=1 << 20, io_lookahead=12)
        r_ref = similarity_self_join(tmp_store(x), JoinConfig(**base),
                                     io_mode="sync")
        cfg = JoinConfig(io_devices=devices, io_batch_reads=True,
                         io_coalesce=True, **base)
        r = similarity_self_join(tmp_store(x[:, :]), cfg, io_mode=io_mode)
        assert r_ref.pairs.shape[0] > 0
        assert _pair_keys(r.pairs) == _pair_keys(r_ref.pairs)
        if io_mode == "prefetch":
            p = r.io_stats["pipeline"]
            assert p["num_devices"] == devices
            assert len(p["device_loads"]) == devices
            assert sum(p["device_loads"]) == r.bucket_loads
            assert all(d >= 1 for d in p["device_depth_max"])

    @pytest.mark.parametrize("devices,io_mode", [(1, "prefetch"),
                                                 (4, "sync"),
                                                 (4, "prefetch")])
    def test_cross_join_identical_pairs(self, tmp_path, devices, io_mode):
        from repro.core import JoinConfig
        from repro.core.join import similarity_cross_join
        from repro.data import clustered_vectors
        from repro.store.vector_store import FlatVectorStore

        rng = np.random.default_rng(3)
        x = clustered_vectors(2000, 32, seed=5)
        y = (x[:1200] + rng.normal(scale=0.05, size=(1200, 32))
             ).astype(np.float32)

        def mk(a, name):
            return FlatVectorStore.from_array(str(tmp_path / name), a)

        base = dict(epsilon=0.3, pad_align=64, num_buckets=16,
                    memory_budget_bytes=1 << 20, io_lookahead=8)
        r_ref = similarity_cross_join(mk(x, "x0"), mk(y, "y0"),
                                      JoinConfig(**base), io_mode="sync")
        cfg = JoinConfig(io_devices=devices, io_batch_reads=True,
                         io_coalesce=True, **base)
        r = similarity_cross_join(mk(x, "x1"), mk(y, "y1"), cfg,
                                  io_mode=io_mode)
        assert r_ref.pairs.shape[0] > 0
        assert _pair_keys(r.pairs) == _pair_keys(r_ref.pairs)

    def test_coalescing_reduces_read_ops(self, small_dataset, tmp_store):
        """Schedule-order layout + coalescing must merge adjacent misses:
        fewer read ops for the same useful bytes, counters reported."""
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        base = dict(epsilon=eps, pad_align=64, num_buckets=24,
                    memory_budget_bytes=1 << 20, io_lookahead=16,
                    io_mode="prefetch")
        r_plain = similarity_self_join(tmp_store(x), JoinConfig(**base))
        r_co = similarity_self_join(
            tmp_store(x[:, :]),
            JoinConfig(io_batch_reads=True, io_coalesce=True, **base))
        assert _pair_keys(r_co.pairs) == _pair_keys(r_plain.pairs)
        p = r_co.io_stats["pipeline"]
        assert p["batched_submissions"] > 0
        assert p["coalesced_reads"] > 0
        assert p["coalesced_buckets"] > p["coalesced_reads"]
        assert (r_co.io_stats["read_ops"] < r_plain.io_stats["read_ops"])
        assert (r_co.io_stats["bytes_read_useful"]
                == r_plain.io_stats["bytes_read_useful"])

    def test_config_validation(self):
        from repro.core import JoinConfig
        with pytest.raises(ValueError, match="io_devices"):
            JoinConfig(epsilon=0.1, io_devices=0)
        with pytest.raises(ValueError, match="io_stripe_by"):
            JoinConfig(epsilon=0.1, io_stripe_by="rr")


# ---------------------------------------------------------------------------
# pair dedup: packed fast path vs ≥ 2^32 id fallback
# ---------------------------------------------------------------------------
class TestDedupPairs:
    def test_small_ids_match_canonicalize(self):
        from repro.core.types import canonicalize_pairs, dedup_pairs

        rng = np.random.default_rng(0)
        raw = rng.integers(0, 50, size=(200, 2))
        pairs, _ = dedup_pairs(raw)
        np.testing.assert_array_equal(pairs, canonicalize_pairs(raw))

    def test_huge_ids_do_not_collide(self):
        """(lo << 32) | hi packing collides for ids ≥ 2^32 — e.g. pairs
        (0, 2^32) and (1, 0) both pack to key 2^32. The fallback must keep
        them distinct."""
        from repro.core.types import dedup_pairs

        big = 1 << 32
        raw = np.array([[0, big], [1, 0], [big, 0], [0, 1]], dtype=np.int64)
        pairs, _ = dedup_pairs(raw)
        assert _pair_keys(pairs) == {(0, 1), (0, big)}

    def test_mid_band_ids_do_not_sign_overflow(self):
        """ids in [2^31, 2^32): `lo << 32` would flip the int64 sign and
        the arithmetic unshift would emit negative ids — must take the
        lexicographic fallback."""
        from repro.core.types import dedup_pairs

        a = 1 << 31
        raw = np.array([[a, a + 1], [a + 1, a], [3, a]], dtype=np.int64)
        pairs, _ = dedup_pairs(raw)
        assert (pairs >= 0).all()
        assert _pair_keys(pairs) == {(a, a + 1), (3, a)}

    def test_dists_follow_first_occurrence(self):
        from repro.core.types import dedup_pairs

        raw = np.array([[2, 1], [1, 2], [3, 4]])
        d = np.array([0.5, 0.9, 0.1], np.float32)
        pairs, dists = dedup_pairs(raw, d)
        out = {tuple(p): float(v) for p, v in zip(pairs.tolist(), dists)}
        assert out == {(1, 2): 0.5, (3, 4): pytest.approx(0.1)}

    def test_huge_ids_with_dists(self):
        from repro.core.types import dedup_pairs

        big = 1 << 33
        raw = np.array([[big + 5, 2], [2, big + 5], [7, 7]], dtype=np.int64)
        d = np.array([0.3, 0.6, 0.0], np.float32)
        pairs, dists = dedup_pairs(raw, d)
        assert pairs.tolist() == [[2, big + 5]]  # self-pair dropped
        assert dists[0] == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# cache simulator: pinned-victim edge branches (previously untested)
# ---------------------------------------------------------------------------
class TestPinnedEviction:
    def test_belady_pinned_victim_spill_path(self):
        """The heap-top (furthest next access) is pinned: Belady must
        spill past it, evict the next-furthest, and re-push the spilled
        entries intact."""
        from repro.core.cache import simulate_belady

        seq = np.array([0, 1, 0, 2, 0])
        pins = np.array([-1, -1, -1, 1, -1])
        s = simulate_belady(seq, 3, capacity=2, pinned_partner=pins)
        # at the miss on 2, bucket 1 (next access ∞) is pinned → evict 0
        assert s.actions[3] == (2, False, 0)
        # spilled entry survived: bucket 1 is still evictable afterwards
        assert s.actions[4] == (0, False, 1)
        assert s.hits == 1 and s.misses == 4

    def test_belady_unpinned_baseline(self):
        from repro.core.cache import simulate_belady

        seq = np.array([0, 1, 0, 2, 0])
        s = simulate_belady(seq, 3, capacity=2)
        assert s.actions[3] == (2, False, 1)  # no pin → evict furthest
        assert s.actions[4] == (0, True, None)

    @pytest.mark.parametrize("policy", ["lru", "fifo"])
    def test_policy_skips_pinned_victim(self, policy):
        from repro.core.cache import simulate_policy

        seq = np.array([0, 1, 2])
        pins = np.array([-1, -1, 0])
        s = simulate_policy(seq, 3, capacity=2, policy=policy,
                            pinned_partner=pins)
        # 0 would be the natural victim (oldest) but is pinned → evict 1
        assert s.actions[2] == (2, False, 1)
        s_nopin = simulate_policy(seq, 3, capacity=2, policy=policy)
        assert s_nopin.actions[2] == (2, False, 0)

    def test_lfu_skips_pinned_victim(self):
        from repro.core.cache import simulate_policy

        seq = np.array([0, 1, 1, 2])
        pins = np.array([-1, -1, -1, 0])
        s = simulate_policy(seq, 3, capacity=2, policy="lfu",
                            pinned_partner=pins)
        # 0 has min frequency but is pinned → evict 1 despite freq 2
        assert s.actions[3] == (2, False, 1)

    def test_unknown_policy_raises(self):
        from repro.core.cache import simulate_policy

        with pytest.raises(ValueError, match="unknown policy"):
            simulate_policy(np.array([0, 1, 2]), 3, 2, policy="mru")


# ---------------------------------------------------------------------------
# distributed host-cache eviction: keep the UPCOMING window (regression)
# ---------------------------------------------------------------------------
class TestDistributedEviction:
    def _store(self, tmp_path, num_buckets=6, dim=4):
        from repro.store.vector_store import BucketedVectorStore

        sizes = np.full(num_buckets, 2, np.int64)
        rng = np.random.default_rng(0)
        centers = rng.normal(size=(num_buckets, dim)).astype(np.float32)
        w = BucketedVectorStore.create(str(tmp_path / "bk"), dim,
                                       np.float32, sizes, centers,
                                       np.ones(num_buckets, np.float32))
        _filled_writer(w, sizes, dim)
        return w.finalize(), centers, sizes

    def test_host_hits_improve_on_overlapping_windows(self, tmp_path):
        """Windows {0,1},{1,2},{1,5},{2,3},{3,4},{5}: bucket 2 is used in
        windows 2 and 4 with a gap at 3, bucket 5 in windows 3 and 6.
        Evicting on the *finished* window's keep-set drops both at their
        gaps (3 hits / 8 loads); keeping the upcoming window retains
        them — 5 hits / 6 loads — without parking dead slabs above the
        memory budget."""
        from repro.core.distributed import DistributedJoin
        from repro.core.types import BucketGraph, BucketMeta, JoinConfig

        store, centers, sizes = self._store(tmp_path)
        meta = BucketMeta(centers=centers,
                          radii=np.ones(6, np.float32), sizes=sizes)
        graph = BucketGraph(num_nodes=6,
                            edges=np.array([[1, 2], [1, 5], [3, 4]],
                                           dtype=np.int64))
        cfg = JoinConfig(epsilon=10.0, reorder=False, bucket_capacity=8,
                         pad_align=8, num_buckets=6,
                         memory_budget_bytes=2 * 8 * 4 * 4)  # 2 slots
        dj = DistributedJoin(store, meta, cfg)
        assert dj.cache_buckets == 2
        pairs, info = dj.run(graph)
        assert info["host_loads"] == 6   # 8 with the old keep-set bug
        assert info["host_hits"] == 5    # 3 with the old keep-set bug
        # result must still contain every epsilon-pair of the edge set
        assert pairs.shape[0] > 0
