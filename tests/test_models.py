"""Per-arch smoke tests (deliverable f): reduced same-family configs, one
forward/train step + greedy decode on CPU, asserting shapes + no NaNs.
Also: block-level equivalence checks (decode == teacher-forced forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import build_model
from repro.models import encdec, transformer

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    tokens = jax.random.randint(RNG, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        fdim = cfg.encoder.frontend_dim or cfg.d_model
        batch["patches"] = jax.random.normal(
            RNG, (b, cfg.encoder.n_patches, fdim), jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            RNG, (b, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_loss(arch):
    cfg = smoke_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(RNG)
    batch = _batch(cfg)
    loss, metrics = m.loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    """One full gradient+optimizer step; params must change and stay finite."""
    from repro.launch.steps import make_train_step
    from repro.train.optimizer import AdamW, AdamWConfig
    cfg = smoke_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(RNG)
    opt = AdamW(AdamWConfig(learning_rate=1e-3))
    opt_state = opt.init(params)
    step = make_train_step(m, opt)
    batch = _batch(cfg)
    new_params, new_state, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state["step"]) == 1
    # at least one leaf moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved, f"{arch}: optimizer step was a no-op"
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_steps(arch):
    cfg = smoke_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(RNG)
    b = 2
    if cfg.enc_dec:
        frames = jax.random.normal(RNG, (b, cfg.encoder.n_frames,
                                         cfg.d_model), jnp.float32)
        enc_out = encdec.encode(params, cfg, frames)
        caches = m.init_cache(b, 64, params=params, enc_out=enc_out)
    else:
        caches = m.init_cache(b, 64)
    tok = jnp.zeros((b, 1), jnp.int32)
    for i in range(4):
        logits, caches = m.decode(params, tok, caches)
        assert logits.shape == (b, cfg.vocab)
        assert jnp.isfinite(logits).all(), f"{arch} step {i}"
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "gemma3-4b"])
def test_decode_matches_teacher_forcing(arch):
    """Step-by-step decode reproduces the training forward's next-token
    logits (cache correctness across attention, SSM, RG-LRU, local attn)."""
    cfg = smoke_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(RNG)
    b, s = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, cfg.vocab)
    hidden, _ = transformer.forward(params, cfg, tokens, remat=False)
    tf_logits = transformer.lm_logits(params, cfg, hidden)  # (b, s, v)

    caches = m.init_cache(b, 64)
    step_logits = []
    for t in range(s):
        lg, caches = m.decode(params, tokens[:, t:t + 1], caches)
        step_logits.append(lg)
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(tf_logits),
                               rtol=2e-2, atol=2e-3)


def test_moe_aux_loss_nonzero():
    cfg = smoke_config(get_config("olmoe-1b-7b"))
    m = build_model(cfg)
    params = m.init(RNG)
    _, metrics = m.loss(params, _batch(cfg))
    assert float(metrics["aux"]) > 0


def test_param_count_formula_matches_init():
    for arch in ("qwen3-0.6b", "olmoe-1b-7b", "mamba2-1.3b",
                 "recurrentgemma-2b"):
        cfg = smoke_config(get_config(arch))
        m = build_model(cfg)
        params = m.init(RNG)
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree_util.tree_leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.05, \
            f"{arch}: {actual} vs {predicted}"


def test_full_configs_match_assignment():
    """Full (non-smoke) configs carry the exact assigned hyperparameters."""
    spec = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "mamba2-1.3b": (48, 2048, None, None, 0, 50280),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == v
        if h is not None:
            assert cfg.n_heads == h and cfg.n_kv_heads == kv
        if arch == "deepseek-moe-16b":
            assert cfg.moe.d_ff_expert == ff
            assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 6
            assert cfg.moe.num_shared == 2
        elif arch == "olmoe-1b-7b":
            assert cfg.moe.d_ff_expert == ff
            assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 8
        elif ff:
            assert cfg.d_ff == ff
    # ssm specifics
    ms = get_config("mamba2-1.3b")
    assert ms.ssm.state_dim == 128
