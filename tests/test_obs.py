"""Unified telemetry subsystem (repro.obs): tracer thread-safety and
nesting, Chrome-trace schema validity, disabled-overhead bound,
TraceAnalysis interval math on synthetic spans, MetricsRegistry instruments
and rollup merge, PipelineStats.merge regression, and the end-to-end
acceptance: a traced prefetch+device self-join whose span-derived hidden
fraction agrees with the stats-derived overlap efficiency."""
import json
import threading
import time

import numpy as np
import pytest

from repro.core import DiskJoinIndex, JoinConfig
from repro.data import clustered_vectors
from repro.io import PipelineStats
from repro.obs import (NOOP_SPAN, Counter, Gauge, Histogram,
                       MetricsRegistry, TraceAnalysis, Tracer, get_tracer,
                       log_bounds, trace_session, validate_chrome_trace)
from repro.obs.tracer import _DISABLED
from repro.serve import QueryScheduler, VectorQueryService
from repro.store.vector_store import FlatVectorStore


def _disabled_span_cost_s(n: int = 200_000) -> float:
    """Measured per-call cost of the disabled tracer's span fast path
    (including the caller's kwargs construction — the full price an
    instrumentation site pays when tracing is off)."""
    tr = Tracer(enabled=False)
    span = tr.span
    best = float("inf")
    for _ in range(3):                       # best-of-3 against CI jitter
        t0 = time.perf_counter()
        for _ in range(n):
            with span("io.read", dev=0):
                pass
        best = min(best, (time.perf_counter() - t0) / n)
    return best


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_nesting_records_both(self):
        tr = Tracer()
        with tr.span("outer", a=1):
            with tr.span("inner"):
                time.sleep(0.001)
        evs = tr.events()
        by_name = {e["name"]: e for e in evs}
        assert set(by_name) == {"outer", "inner"}
        o, i = by_name["outer"], by_name["inner"]
        assert o["ph"] == i["ph"] == "X"
        # inner nests inside outer on the timeline
        assert o["ts"] <= i["ts"]
        assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1.0  # µs slack
        assert o["args"] == {"a": 1}

    def test_span_set_attaches_args(self):
        tr = Tracer()
        with tr.span("s") as sp:
            sp.set(rows=7)
        (ev,) = tr.events()
        assert ev["args"] == {"rows": 7}

    def test_complete_uses_caller_interval(self):
        tr = Tracer()
        t0 = time.perf_counter()
        tr.complete("io.read", t0, 0.25, dev=3)
        (ev,) = tr.events()
        assert ev["dur"] == pytest.approx(0.25e6)
        assert ev["args"] == {"dev": 3}

    def test_instant_counter_async_phases(self):
        tr = Tracer()
        tr.instant("mark", k=1)
        tr.counter("depth", 4)
        tr.async_begin("req", 9, src="test")
        tr.async_end("req", 9, ok=True)
        phases = {e["name"]: e for e in tr.events()}
        assert phases["mark"]["ph"] == "i"
        assert phases["depth"]["ph"] == "C"
        assert phases["depth"]["args"]["value"] == 4
        bs = [e for e in tr.events() if e["ph"] == "b"]
        es = [e for e in tr.events() if e["ph"] == "e"]
        assert bs[0]["id"] == es[0]["id"] == 9
        assert bs[0]["cat"] == "async"

    def test_threads_do_not_corrupt_each_other(self):
        tr = Tracer()
        n_threads, n_each = 8, 500

        def work(k):
            for i in range(n_each):
                with tr.span(f"t{k}", i=i):
                    pass

        ts = [threading.Thread(target=work, args=(k,))
              for k in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        evs = tr.events()
        assert len(evs) == n_threads * n_each
        assert tr.dropped == 0
        for k in range(n_threads):
            mine = [e for e in evs if e["name"] == f"t{k}"]
            assert len(mine) == n_each
            # one ring per thread: all of a thread's events share one tid
            assert len({e["tid"] for e in mine}) == 1
            assert sorted(e["args"]["i"] for e in mine) == list(range(n_each))

    def test_ring_overflow_drops_oldest_and_counts(self):
        tr = Tracer(ring_capacity=16)
        for i in range(40):
            tr.instant("e", i=i)
        evs = tr.events()
        assert len(evs) == 16
        assert tr.dropped == 24
        # newest survive, oldest overwritten
        assert [e["args"]["i"] for e in evs] == list(range(24, 40))

    def test_clear(self):
        tr = Tracer()
        tr.instant("x")
        tr.clear()
        assert tr.events() == []

    def test_disabled_tracer_is_noop(self):
        tr = Tracer(enabled=False)
        assert tr.span("s") is NOOP_SPAN
        with tr.span("s") as sp:
            sp.set(a=1)
        tr.instant("i")
        tr.counter("c", 1)
        tr.complete("x", 0.0, 1.0)
        tr.async_begin("r", 1)
        tr.async_end("r", 1)
        assert tr.events() == []

    def test_trace_session_scopes_current_tracer(self):
        assert get_tracer() is _DISABLED
        with trace_session() as tr:
            assert get_tracer() is tr
            get_tracer().instant("inside")
        assert get_tracer() is _DISABLED
        assert [e["name"] for e in tr.events()] == ["inside"]

    def test_disabled_span_per_call_cost_is_submicrosecond(self):
        """Micro-benchmark of the no-op fast path: a disabled span —
        kwargs construction included — must stay well under a µs per
        call. (The <1% claim on the real fig19-shaped workload is
        asserted in ``TestEndToEnd``, where the actual instrumentation
        call count and wall time are both measured.)"""
        assert _disabled_span_cost_s() < 2e-6


# ---------------------------------------------------------------------------
# Export schema + TraceAnalysis interval math
# ---------------------------------------------------------------------------

def _x(name, ts_s, dur_s, tid=1, **args):
    ev = {"name": name, "ph": "X", "pid": 1, "tid": tid,
          "ts": ts_s * 1e6, "dur": dur_s * 1e6}
    if args:
        ev["args"] = args
    return ev


class TestExport:
    def test_export_roundtrip_schema_valid(self, tmp_path):
        tr = Tracer()
        with tr.span("a"):
            tr.instant("m")
        tr.async_begin("r", 1)
        tr.async_end("r", 1)
        path = tr.export(str(tmp_path / "t.json"))
        n = validate_chrome_trace(path)
        doc = json.load(open(path))
        assert doc["displayTimeUnit"] == "ms"
        # span + instant + async pair + thread_name metadata
        assert n == len(doc["traceEvents"]) >= 5
        assert any(e["ph"] == "M" and e["name"] == "thread_name"
                   for e in doc["traceEvents"])

    @pytest.mark.parametrize("bad", [
        [{"ph": "X", "pid": 1, "tid": 1, "ts": 0}],           # no name
        [{"name": "a", "ph": "?", "pid": 1, "tid": 1, "ts": 0}],
        [{"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": "z"}],
        [{"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0}],  # no dur
        [{"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0,
          "dur": -1}],
        [{"name": "a", "ph": "b", "pid": 1, "tid": 1, "ts": 0}],  # no id
        [{"name": "a", "ph": "i", "pid": 1, "tid": 1, "ts": 0,
          "args": 3}],
        "not-a-trace",
    ])
    def test_validate_rejects(self, bad):
        if isinstance(bad, str):
            with pytest.raises((ValueError, OSError)):
                validate_chrome_trace({"traceEvents": bad})
        else:
            with pytest.raises(ValueError):
                validate_chrome_trace(bad)

    def test_overlap_exact_on_synthetic_spans(self):
        an = TraceAnalysis([
            _x("read", 0.0, 1.0), _x("read", 2.0, 1.0),
            _x("verify", 0.5, 2.0),
        ])
        assert an.total_seconds("read") == pytest.approx(2.0)
        assert an.busy_seconds("read") == pytest.approx(2.0)
        # read∩verify = [0.5,1.0] + [2.0,2.5] = 1.0
        assert an.overlap_seconds("read", "verify") == pytest.approx(1.0)
        assert an.overlap_fraction("read", "verify") == pytest.approx(0.5)

    def test_hidden_fraction_union_semantics(self):
        # two concurrent reads (thread-seconds 2.0), one wait covering
        # [0.25, 0.75]: visible covers 0.5s of EACH read's interval on the
        # union timeline → hidden = (2.0 − 0.5) / 2.0... union(read) is
        # [0,1] so vis∩union = 0.5, hidden = (2.0 − 0.5)/2.0 = 0.75
        an = TraceAnalysis([
            _x("io.read", 0.0, 1.0, tid=1), _x("io.read", 0.0, 1.0, tid=2),
            _x("io.wait", 0.25, 0.5),
        ])
        assert an.hidden_fraction("io.read", "io.wait") == \
            pytest.approx(0.75)
        # nothing recorded → 1.0 (matches stats convention for read_s==0)
        assert an.hidden_fraction("absent", "io.wait") == 1.0

    def test_prefix_and_union_specs(self):
        an = TraceAnalysis([
            _x("verify.dispatch", 0.0, 1.0), _x("verify.collect", 2.0, 1.0),
            _x("join.run", 0.0, 4.0),
        ])
        assert an.total_seconds("verify.*") == pytest.approx(2.0)
        assert an.overlap_seconds(("verify.*", "join.run"), "join.run") \
            == pytest.approx(4.0)

    def test_critical_path_sums_to_extent_no_double_count(self):
        an = TraceAnalysis([
            _x("a", 0.0, 2.0), _x("b", 1.0, 2.0),  # overlap [1,2]
        ])
        cp = an.critical_path(priorities=["a", "b"])
        assert cp["a"] == pytest.approx(2.0)   # owns its full extent
        assert cp["b"] == pytest.approx(1.0)   # only its exclusive tail
        assert cp["idle"] == pytest.approx(0.0)
        assert sum(cp.values()) == pytest.approx(3.0)  # span extent

    def test_wall_breakdown_and_summary(self):
        an = TraceAnalysis([
            _x("io.read", 0.0, 1.0), _x("io.read", 0.5, 1.0),
            _x("io.wait", 0.2, 0.1),
        ])
        bd = an.wall_breakdown()
        assert bd["io.read"]["count"] == 2
        assert bd["io.read"]["total_s"] == pytest.approx(2.0)
        assert bd["io.read"]["busy_s"] == pytest.approx(1.5)
        s = an.summary()
        assert s["read_hidden_fraction"] == pytest.approx(1.9 / 2.0)

    def test_async_pairs(self):
        an = TraceAnalysis([
            {"name": "req", "ph": "b", "pid": 1, "tid": 1, "ts": 0.0,
             "id": 5},
            {"name": "req", "ph": "e", "pid": 1, "tid": 2, "ts": 2e6,
             "id": 5, "args": {"wave": 3}},
            {"name": "req", "ph": "b", "pid": 1, "tid": 1, "ts": 1e6,
             "id": 6},   # unterminated — skipped
        ])
        pairs = an.async_pairs("req")
        assert len(pairs) == 1
        assert pairs[0]["id"] == 5
        assert pairs[0]["duration_s"] == pytest.approx(2.0)
        assert pairs[0]["args"]["wave"] == 3


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_basics(self):
        reg = MetricsRegistry()
        reg.counter("io.reads").inc()
        reg.counter("io.reads").inc(4)        # get-or-create: same object
        reg.gauge("pool.slabs").set(7)
        reg.gauge("pool.slabs").max(3)        # high-watermark keeps 7
        snap = reg.snapshot()
        assert snap["counters"]["io.reads"] == 5
        assert snap["gauges"]["pool.slabs"] == 7

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")

    def test_log_bounds_validation(self):
        with pytest.raises(ValueError):
            log_bounds(0, 1, 2)
        with pytest.raises(ValueError):
            log_bounds(1, 2, 1.0)
        b = log_bounds(1.0, 8.0, 2.0)
        assert b == [1.0, 2.0, 4.0, 8.0]

    def test_histogram_percentiles_within_bucket_factor(self):
        h = Histogram("lat", lo=1e-4, hi=10.0, factor=2.0)
        rng = np.random.default_rng(0)
        vals = rng.lognormal(mean=-4, sigma=1.0, size=5000)
        for v in vals:
            h.observe(v)
        for q in (50, 95, 99):
            exact = float(np.percentile(vals, q))
            est = h.percentile(q)
            assert exact / 2.0 <= est <= exact * 2.0, \
                f"p{q}: est {est} vs exact {exact}"
        s = h.snapshot()
        assert s["count"] == 5000
        assert s["min"] == pytest.approx(vals.min())
        assert s["max"] == pytest.approx(vals.max())

    def test_histogram_overflow_bucket(self):
        h = Histogram("x", lo=1.0, hi=4.0, factor=2.0)
        h.observe(1e9)
        assert h.counts[-1] == 1
        assert h.percentile(50) == h.bounds[-1]

    def test_provider_suffix_and_unregister(self):
        reg = MetricsRegistry()
        k1 = reg.register_provider("svc", lambda: {"a": 1})
        k2 = reg.register_provider("svc", lambda: {"a": 2})
        assert k1 == "svc" and k2 == "svc#2"
        snap = reg.snapshot()
        assert snap["svc"] == {"a": 1} and snap["svc#2"] == {"a": 2}
        reg.unregister_provider(k2)
        assert "svc#2" not in reg.snapshot()

    def test_raising_provider_isolated(self):
        reg = MetricsRegistry()
        reg.register_provider("bad", lambda: 1 / 0)
        reg.counter("ok").inc()
        snap = reg.snapshot()
        assert "error" in snap["bad"]
        assert snap["counters"]["ok"] == 1

    def test_to_json_roundtrips(self):
        reg = MetricsRegistry()
        reg.histogram("h").observe(0.5)
        doc = json.loads(reg.to_json())
        assert doc["histograms"]["h"]["count"] == 1

    def test_merge_exact_histogram_rollup(self):
        shards = []
        all_vals = []
        rng = np.random.default_rng(1)
        for s in range(3):
            reg = MetricsRegistry()
            reg.counter("reads").inc(10 * (s + 1))
            reg.gauge("depth").set(s)
            vals = rng.lognormal(-3, 1, 1000)
            h = reg.histogram("lat", lo=1e-4, hi=10.0)
            for v in vals:
                h.observe(v)
            all_vals.append(vals)
            shards.append(reg.snapshot())
        merged = MetricsRegistry.merge(shards)
        assert merged["counters"]["reads"] == 60
        assert merged["gauges"]["depth"] == 2
        mh = merged["histograms"]["lat"]
        assert mh["count"] == 3000
        # exact rollup: merged percentile == one histogram over all values
        ref = Histogram("ref", lo=1e-4, hi=10.0)
        for v in np.concatenate(all_vals):
            ref.observe(v)
        assert mh["p95"] == pytest.approx(ref.percentile(95))
        assert mh["buckets"] == ref.counts

    def test_merge_incompatible_bounds_degrades(self):
        a = MetricsRegistry()
        a.histogram("h", lo=1e-3, hi=1.0).observe(0.1)
        b = MetricsRegistry()
        b.histogram("h", lo=1e-6, hi=1.0).observe(0.2)
        m = MetricsRegistry.merge([a.snapshot(), b.snapshot()])
        mh = m["histograms"]["h"]
        assert mh["count"] == 2
        assert mh["sum"] == pytest.approx(0.3)
        assert "p95" not in mh and "buckets" not in mh

    def test_merge_collects_provider_sections(self):
        a = MetricsRegistry()
        a.register_provider("pipeline", lambda: {"read_s": 1.0})
        b = MetricsRegistry()
        b.register_provider("pipeline", lambda: {"read_s": 2.0})
        m = MetricsRegistry.merge([a.snapshot(), b.snapshot()])
        assert m["pipeline"] == [{"read_s": 1.0}, {"read_s": 2.0}]


# ---------------------------------------------------------------------------
# PipelineStats.merge regression (satellite: list-valued fields)
# ---------------------------------------------------------------------------

class TestPipelineStatsMerge:
    def test_merge_list_fields_concatenate(self):
        a, b = PipelineStats(), PipelineStats()
        a.init_devices(2)
        a.count_device_loads(0, 5)
        a.count_device_loads(1, 3)
        b.init_devices(3)          # unequal lengths — the old failure mode
        b.count_device_loads(2, 7)
        a.add("read_s", 1.0)
        a.add("io_wait_s", 0.25)
        b.add("read_s", 3.0)
        b.add("io_wait_s", 0.75)
        a.observe_depth(4)
        b.observe_depth(9)
        m = PipelineStats.merge([a.snapshot(), b.snapshot()])
        assert m["device_loads"] == [5, 3, 0, 0, 7]
        assert m["device_depth_max"] == [0, 0, 0, 0, 0]
        assert m["num_devices"] == 5
        assert m["read_s"] == pytest.approx(4.0)
        assert m["max_queue_depth"] == 9
        # derived ratio recomputed from merged totals, not summed/maxed
        assert m["overlap_efficiency"] == pytest.approx(3.0 / 4.0)

    def test_snapshot_since_survives_device_list_reset(self):
        """Regression: a base captured BEFORE a prefetcher re-attached
        (init_devices resets the per-device lists) must not be subtracted
        from the fresh lists — that undercounted whichever devices the
        earlier (e.g. build/layout) pass had used."""
        s = PipelineStats()
        s.init_devices(4)
        s.count_device_loads(0, 4)         # layout pass activity
        s.count_device_loads(1, 2)
        base = s.snapshot()
        s.init_devices(4)                  # the measured run's prefetcher
        for dev, n in enumerate((8, 8, 5, 4)):
            s.count_device_loads(dev, n)
        s.add("loads", 25)
        out = s.snapshot_since(base)
        assert out["device_loads"] == [8, 8, 5, 4]
        assert sum(out["device_loads"]) == out["loads"]

    def test_merge_empty_and_single(self):
        assert PipelineStats.merge([])["read_s"] == 0
        s = PipelineStats()
        s.add("read_s", 2.0)
        m = PipelineStats.merge([s.snapshot()])
        assert m["read_s"] == pytest.approx(2.0)
        assert m["overlap_efficiency"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# End-to-end: instrumented pipeline + metrics surface
# ---------------------------------------------------------------------------

def _build_index(tmp_path, n=6000, dim=24, seed=7, **cfg_kw):
    x = clustered_vectors(n, dim, seed=seed)
    store = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
    base = dict(epsilon=0.35, recall_target=0.9, pad_align=64,
                num_buckets=max(24, n // 150),
                memory_budget_bytes=max(1 << 20, x.nbytes // 10))
    base.update(cfg_kw)
    return DiskJoinIndex.build(store, JoinConfig(**base),
                               str(tmp_path / "idx")), x


class TestEndToEnd:
    def test_traced_join_agrees_with_pipeline_stats(self, tmp_path):
        """Acceptance: prefetch+device self-join exports a valid Chrome
        trace whose hidden_fraction("io.read","io.wait") agrees with the
        PipelineStats-derived overlap_efficiency within 10%."""
        index, x = _build_index(
            tmp_path, io_mode="prefetch", io_threads=8, io_lookahead=16,
            compute_mode="device", emulate_read_latency_s=1e-3)
        index.self_join()                      # warm jit outside the trace
        index.drop_warm_cache()
        base = index.pipeline_snapshot()
        with trace_session() as tr:
            t0 = time.perf_counter()
            res = index.self_join()
            traced_wall_s = time.perf_counter() - t0
        snap = index.pipeline_snapshot()
        assert res.pairs.shape[0] > 0

        path = tr.export(str(tmp_path / "join.json"))
        assert validate_chrome_trace(path) > 0
        an = tr.analysis()
        assert {"io.read", "io.wait", "join.run", "join.plan",
                "verify.dispatch", "verify.collect"} <= set(an.names())
        # the trace must show reads proceeding under the verify walk
        assert an.overlap_seconds("io.read", ("verify.*", "join.run")) > 0

        read_s = snap["read_s"] - base["read_s"]
        io_wait = snap["io_wait_s"] - base["io_wait_s"]
        stats_eff = (max(0.0, read_s - io_wait) / read_s
                     if read_s > 0 else 1.0)
        hidden = an.hidden_fraction("io.read", "io.wait")
        assert abs(hidden - stats_eff) <= 0.10, \
            f"trace hidden={hidden:.3f} vs stats overlap={stats_eff:.3f}"
        # trace and stats see the SAME measurements (tracer.complete):
        # summed span durations equal the accumulated counters
        assert an.total_seconds("io.read") == pytest.approx(read_s,
                                                            rel=1e-6)
        assert an.total_seconds("io.wait") == pytest.approx(io_wait,
                                                            rel=1e-6)

        # disabled-tracing overhead on THIS workload: every event above
        # is one instrumentation call; when tracing is off each such call
        # costs the measured no-op fast path — must be <1% of the
        # workload's wall time
        n_calls = len(tr.events())
        overhead = _disabled_span_cost_s() * n_calls
        assert overhead < 0.01 * traced_wall_s, \
            f"disabled tracing would cost {overhead * 1e3:.3f}ms over " \
            f"{n_calls} sites on a {traced_wall_s * 1e3:.0f}ms workload " \
            f"({overhead / traced_wall_s:.2%})"
        index.close()

    def test_tracing_disabled_records_nothing(self, tmp_path):
        index, _ = _build_index(tmp_path, n=2000)
        assert get_tracer() is _DISABLED
        index.self_join()
        assert get_tracer().events() == []
        index.close()

    def test_scheduler_wave_request_linkage(self, tmp_path):
        index, x = _build_index(tmp_path, n=2500)
        rng = np.random.default_rng(3)
        queries = x[rng.choice(x.shape[0], 12)]
        with trace_session() as tr:
            with QueryScheduler(index, wave_size=4,
                                max_wait_s=0.002) as sched:
                futs = [sched.submit(q) for q in queries]
                for f in futs:
                    f.result(timeout=120)
        an = tr.analysis()
        assert an.count("serve.wave") >= 1
        pairs = an.async_pairs("serve.request")
        assert len(pairs) == len(queries)
        wave_ids = {p["args"]["wave"] for p in pairs}
        assert all(w >= 1 for w in wave_ids)
        # every request's wave id names a traced wave span
        wave_spans = [e for e in tr.events()
                      if e["ph"] == "X" and e["name"] == "serve.wave"]
        assert wave_ids <= {e["args"]["wave"] for e in wave_spans}
        index.close()

    def test_index_metrics_surface_and_service_provider(self, tmp_path):
        index, x = _build_index(tmp_path, n=2000)
        svc = VectorQueryService(index)
        svc.query(x[0])
        svc.query(x[1])
        snap = index.metrics_snapshot()
        assert {"counters", "gauges", "histograms", "pipeline",
                "io"} <= set(snap)
        assert snap["service"]["requests"] == 2
        assert snap["service"]["latency_p95_ms"] > 0
        svc.close()
        assert "service" not in index.metrics_snapshot()
        index.close()

    def test_two_services_do_not_shadow(self, tmp_path):
        index, x = _build_index(tmp_path, n=2000)
        s1 = VectorQueryService(index)
        s2 = VectorQueryService(index)
        s1.query(x[0])
        snap = index.metrics_snapshot()
        assert snap["service"]["requests"] == 1
        assert snap["service#2"]["requests"] == 0
        s2.close()
        s1.close()
        index.close()

    def test_router_metrics_rollup(self, tmp_path):
        from repro.serve import IndexRouter
        rng = np.random.default_rng(11)
        shards = []
        for si in range(2):
            x = clustered_vectors(1500, 16, seed=20 + si)
            store = FlatVectorStore.from_array(
                str(tmp_path / f"s{si}.bin"), x)
            cfg = JoinConfig(epsilon=0.35, recall_target=0.9,
                             pad_align=64, num_buckets=12,
                             memory_budget_bytes=1 << 20)
            shards.append(DiskJoinIndex.build(
                store, cfg, str(tmp_path / f"idx{si}")))
        router = IndexRouter(shards, close_shards=True)
        Q = clustered_vectors(1500, 16, seed=20)[rng.choice(1500, 4)]
        for qv in Q:
            router.query(qv, timeout=120)
        m = router.metrics_snapshot()
        # the per-shard pipeline sections re-merged domain-aware: one
        # dict, not a per-shard list
        assert isinstance(m["pipeline"], dict)
        assert m["pipeline"]["read_s"] >= 0
        p = router.pipeline_snapshot()
        assert p["num_devices"] == sum(
            s.stats.snapshot()["num_devices"] for s in shards)
        router.close()
