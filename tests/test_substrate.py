"""Substrate tests: checkpoint/restart, data pipeline, dedup, optimizer,
gradient compression, elastic runtime, straggler mitigation, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_latest, save_checkpoint
from repro.checkpoint.checkpoint import cleanup, list_checkpoints
from repro.configs import get_config, smoke_config
from repro.data import clustered_vectors
from repro.data.dedup import UnionFind, semantic_dedup
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.runtime import (ElasticController, HeartbeatRegistry, HostMonitor,
                           StepTimer, plan_mesh, rebalance_edges)
from repro.train import AdamW, AdamWConfig, make_int8_compressor


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
class TestCheckpoint:
    def _tree(self):
        return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": {"c": jnp.ones((2,), jnp.bfloat16)},
                "step": jnp.asarray(7, jnp.int32)}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_checkpoint(str(tmp_path), 5, tree, extra={"note": "x"})
        step, restored, extra = restore_latest(str(tmp_path), tree)
        assert step == 5 and extra["note"] == "x"
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_bfloat16_preserved(self, tmp_path):
        tree = self._tree()
        save_checkpoint(str(tmp_path), 1, tree)
        _, restored, _ = restore_latest(str(tmp_path), tree)
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_latest_wins_and_cleanup(self, tmp_path):
        tree = self._tree()
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(str(tmp_path), s, tree)
        cleanup(str(tmp_path), keep=2)
        steps = [s for s, _ in list_checkpoints(str(tmp_path))]
        assert steps == [4, 5]
        step, _, _ = restore_latest(str(tmp_path), tree)
        assert step == 5

    def test_structure_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, self._tree())
        with pytest.raises(ValueError):
            restore_latest(str(tmp_path), {"only": jnp.zeros(1)})

    def test_async_manager(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2, async_save=True)
        tree = self._tree()
        for s in (10, 20):
            m.save(s, tree)
        m.close()
        step, _, _ = restore_latest(str(tmp_path), tree)
        assert step == 20

    def test_crash_tmp_ignored(self, tmp_path):
        tree = self._tree()
        save_checkpoint(str(tmp_path), 1, tree)
        os.makedirs(str(tmp_path / "step_000000099.tmp"))  # simulated crash
        step, _, _ = restore_latest(str(tmp_path), tree)
        assert step == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
class TestPipeline:
    def test_deterministic_resume(self):
        cfg = PipelineConfig(vocab=100, seq_len=8, global_batch=4, seed=3)
        p1 = TokenPipeline(cfg)
        batches = [p1.batch_at(s) for s in range(5)]
        p2 = TokenPipeline(cfg)
        p2.restore({"step": 3, "seed": 3, "host_id": 0})
        np.testing.assert_array_equal(batches[3]["tokens"],
                                      p2.batch_at(3)["tokens"])

    def test_host_sharding_partitions_batch(self):
        full = TokenPipeline(PipelineConfig(vocab=50, seq_len=4,
                                            global_batch=8, seed=1))
        shards = [TokenPipeline(PipelineConfig(
            vocab=50, seq_len=4, global_batch=8, seed=1,
            num_hosts=2, host_id=h)) for h in (0, 1)]
        want = full.batch_at(0)["tokens"]
        got = np.concatenate([s.batch_at(0)["tokens"] for s in shards])
        np.testing.assert_array_equal(want, got)

    def test_seed_mismatch_rejected(self):
        p = TokenPipeline(PipelineConfig(vocab=10, seq_len=4,
                                         global_batch=2, seed=1))
        with pytest.raises(ValueError):
            p.restore({"step": 0, "seed": 999})


# ---------------------------------------------------------------------------
# semantic dedup (the paper's flagship application)
# ---------------------------------------------------------------------------
class TestDedup:
    def test_union_find(self):
        uf = UnionFind(5)
        uf.union(0, 1)
        uf.union(1, 2)
        assert uf.find(2) == 0
        assert uf.find(4) == 4

    def test_dedup_finds_planted_duplicates(self, tmp_path):
        rng = np.random.default_rng(0)
        base = clustered_vectors(600, 24, seed=9)
        dups = base[:200] + rng.normal(scale=1e-3,
                                       size=(200, 24)).astype(np.float32)
        emb = np.concatenate([base, dups])
        rep = semantic_dedup(emb, epsilon=0.05, workdir=str(tmp_path),
                             recall_target=0.95)
        # every planted duplicate pair is within eps → ≥ ~200 drops
        assert rep.num_dropped >= 180
        # survivors keep one representative per group
        assert rep.num_docs - rep.num_dropped >= 580
        assert rep.join_stats["read_amplification"] <= 1.2


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------
class TestOptimizer:
    def test_adamw_reduces_quadratic_loss(self):
        opt = AdamW(AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                                warmup_steps=0, total_steps=100))
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)

        def loss_fn(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(60):
            g = jax.grad(loss_fn)(params)
            params, state, _ = opt.update(g, state, params)
        assert float(loss_fn(params)) < 0.3

    def test_grad_clipping_bounds_update(self):
        opt = AdamW(AdamWConfig(learning_rate=1.0, clip_norm=1.0,
                                weight_decay=0.0, warmup_steps=0))
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        huge = {"w": jnp.full(3, 1e9)}
        new, state, metrics = opt.update(huge, state, params)
        assert float(metrics["grad_norm"]) > 1e8
        assert np.abs(np.asarray(new["w"])).max() < 10.0

    def test_int8_compression_error_feedback(self):
        """Error feedback: quantization residual carried, not lost —
        the sum of applied gradients converges to the true sum."""
        tf = make_int8_compressor()
        g = {"w": jnp.asarray([1e-4, 0.5, -0.3])}
        err = {"w": jnp.zeros(3)}
        applied = jnp.zeros(3)
        for _ in range(50):
            deq, err = tf(g, err)
            applied = applied + deq["w"]
        np.testing.assert_allclose(np.asarray(applied) / 50,
                                   np.asarray(g["w"]), atol=2e-3)


# ---------------------------------------------------------------------------
# runtime: elastic + straggler
# ---------------------------------------------------------------------------
class TestRuntime:
    def test_plan_mesh_prefers_pods(self):
        p = plan_mesh(512, global_batch=256)
        assert p.chips == 512 and p.pod == 2 and p.model == 16

    def test_plan_mesh_shrinks_gracefully(self):
        p = plan_mesh(200, global_batch=256)
        assert p is not None and p.chips <= 200

    def test_heartbeat_and_elastic_shrink(self):
        t = [0.0]
        reg = HeartbeatRegistry(timeout_s=10, clock=lambda: t[0])
        for h in ("h0", "h1", "h2", "h3"):
            reg.heartbeat(h, chips=128)
        ctl = ElasticController(reg, global_batch=256)
        ev = ctl.evaluate()
        assert ev.new_plan.chips == 512
        t[0] = 20.0  # h* all stale
        reg.heartbeat("h0", chips=128)
        reg.heartbeat("h1", chips=128)
        ev = ctl.evaluate()
        assert ev.kind == "shrink" and ev.new_plan.chips == 256

    def test_straggler_quarantine_and_rebalance(self):
        mon = HostMonitor(threshold=1.5, patience=2)
        for _ in range(6):
            for h in ("a", "b", "c"):
                mon.record(h, 1.0)
            mon.record("slow", 5.0)
            mon.evaluate()
        assert "slow" not in mon.healthy_hosts()
        assign = {"a": [1], "b": [2], "c": [], "slow": [3, 4]}
        out = rebalance_edges(assign, ["slow"], mon.healthy_hosts())
        assert sorted(sum(out.values(), [])) == [1, 2, 3, 4]
        assert "slow" not in out

    def test_step_timer_outliers(self):
        t = StepTimer()
        for _ in range(20):
            t.record(0.1)
        assert t.record(1.0) is True
        rep = t.report()
        assert rep["outliers"] == 1 and rep["steps"] == 21


# ---------------------------------------------------------------------------
# end-to-end training loop (tiny arch) + serve engine
# ---------------------------------------------------------------------------
def test_train_loop_checkpoint_restart(tmp_path):
    from repro.train import TrainConfig, train
    cfg = smoke_config(get_config("qwen3-0.6b"))
    tcfg = TrainConfig(steps=6, log_every=100, checkpoint_every=3,
                       checkpoint_dir=str(tmp_path), global_batch=2,
                       seq_len=16,
                       optimizer=AdamWConfig(learning_rate=1e-3,
                                             warmup_steps=1, total_steps=6))
    out1 = train(cfg, tcfg)
    assert np.isfinite(out1["final_loss"])
    # restart: resumes from step 4 (checkpoint at step 3+1)
    out2 = train(cfg, tcfg)
    assert len(out2["loss_history"]) < len(out1["loss_history"])


def test_serve_engine_batched_requests():
    from repro.serve import ServeEngine
    cfg = smoke_config(get_config("qwen3-0.6b"))
    eng = ServeEngine(cfg, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(0, cfg.vocab, size=5), max_new_tokens=4)
            for _ in range(4)]
    results = eng.run()
    assert set(results) == set(uids)
    for toks in results.values():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab for t in toks)
