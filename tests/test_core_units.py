"""Unit tests for the DiskJoin core: pruning math, ordering, cache policies,
bucketization invariants, store I/O accounting."""
import math

import numpy as np
import pytest

from repro.core import (BucketGraph, cap_constant, edge_schedule, gorder,
                        miss_bound_terms, prune_candidates, simulate_belady,
                        simulate_policy, window_size)
from repro.core.types import JoinConfig, canonicalize_pairs, recall
from repro.store.io_stats import IOStats, PAGE_SIZE


# ---------------------------------------------------------------------------
# pruning (Eq. 3 / Alg. 3)
# ---------------------------------------------------------------------------
def test_cap_constant_matches_gamma_identity():
    # μ(d) = Γ((d−1)/2) / (√π Γ(d/2)); check d=3 analytically:
    # Γ(1)/（√π Γ(1.5)) = 1/(√π·(√π/2)) = 2/π
    assert abs(cap_constant(3) - 2 / math.pi) < 1e-12


def test_cap_constant_decreases_with_dimension():
    vals = [cap_constant(d) for d in (4, 16, 64, 256, 1024)]
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_miss_bound_zero_when_no_intersection():
    # candidate center at distance 2r ⇒ bisector beyond the ball ⇒ x=1
    terms = miss_bound_terms(np.asarray([4.0]), radius=2.0, dim=32)
    assert terms[0] == 0.0


def test_prune_keeps_all_at_recall_1():
    dists = np.asarray([0.5, 1.0, 1.5, 2.0])
    keep = prune_candidates(dists, radius=2.0, dim=32, recall_target=1.0)
    assert keep.all()


def test_prune_drops_far_first():
    dists = np.asarray([0.5, 3.9, 2.0, 3.5])
    keep = prune_candidates(dists, radius=2.0, dim=64, recall_target=0.9)
    # whatever is pruned must be a suffix of the distance ordering
    pruned = set(np.flatnonzero(~keep))
    if pruned:
        order = np.argsort(-dists)
        k = len(pruned)
        assert pruned == set(order[:k])


def test_cross_join_bound_monotone_in_candidate_radius():
    """Bigger candidate radius ⇒ shallower cap cut ⇒ larger miss bound."""
    d = np.asarray([1.0, 2.0])
    r = 1.5
    t_small = miss_bound_terms(d, r, 64, cand_radii=np.asarray([0.2, 0.2]))
    t_large = miss_bound_terms(d, r, 64, cand_radii=np.asarray([0.9, 0.9]))
    assert (t_large >= t_small - 1e-12).all()


# ---------------------------------------------------------------------------
# ordering (Alg. 2) + schedules
# ---------------------------------------------------------------------------
def _ring_graph(n):
    edges = np.asarray([(i, (i + 1) % n) for i in range(n)])
    e = np.stack([edges.min(1), edges.max(1)], 1)
    return BucketGraph(num_nodes=n, edges=np.unique(e, axis=0))


def test_gorder_is_permutation():
    g = _ring_graph(12)
    order = gorder(g, window=3)
    assert sorted(order.tolist()) == list(range(12))


def test_edge_schedule_covers_all_edges_once():
    g = _ring_graph(8)
    tasks, access, pins = edge_schedule(g, np.arange(8))
    edges = {(min(u, v), max(u, v)) for t, *rest in [() for _ in []]} or set()
    edge_tasks = [t for t in tasks if t[0] == "edge"]
    got = {(min(u, v), max(u, v)) for _, u, v in edge_tasks}
    want = {tuple(e) for e in g.edges.tolist()}
    assert got == want
    touches = [t[1] for t in tasks if t[0] == "touch"]
    assert sorted(touches) == list(range(8))
    assert len(access) == len(pins)


def test_window_size_formula():
    g = _ring_graph(10)  # avg degree 2
    assert window_size(8, g) == 4


# ---------------------------------------------------------------------------
# cache policies (Alg. 1 + Fig. 17)
# ---------------------------------------------------------------------------
def test_belady_beats_or_equals_lru_fifo_lfu():
    rng = np.random.default_rng(3)
    seq = rng.integers(0, 30, size=600)
    for cap in (3, 6, 10):
        b = simulate_belady(seq, 30, cap)
        for policy in ("lru", "fifo", "lfu"):
            other = simulate_policy(seq, 30, cap, policy)
            assert b.misses <= other.misses, (cap, policy)


def test_belady_classic_example():
    # paper Fig. 4 flavour: Belady keeps the soon-reused page
    seq = np.asarray([1, 2, 3, 4, 1, 2, 5, 1, 2, 3, 4, 5])
    b = simulate_belady(seq, 6, 4)
    lru = simulate_policy(seq, 6, 4, "lru")
    assert b.misses <= lru.misses


def test_belady_respects_pins():
    seq = np.asarray([0, 1, 2, 0, 3])
    pins = np.asarray([-1, 0, -1, -1, -1])  # while loading 1, pin 0
    s = simulate_belady(seq, 5, 2, pins)
    # replay: at access of 1, victim must not be 0
    for (b, hit, victim), pin in zip(s.actions, pins):
        if victim is not None:
            assert victim != pin


def test_schedule_replay_consistency():
    """hits+misses == accesses; loads == misses."""
    rng = np.random.default_rng(0)
    seq = rng.integers(0, 20, size=200)
    for policy in ("belady", "lru", "fifo", "lfu"):
        s = simulate_policy(seq, 20, 5, policy)
        assert s.hits + s.misses == len(seq)
        assert s.loads == s.misses
        assert len(s.actions) == len(seq)


# ---------------------------------------------------------------------------
# store + io accounting
# ---------------------------------------------------------------------------
def test_per_vector_reads_amplify(tmp_path, tmp_store):
    x = np.zeros((100, 16), np.float32)  # 64B rows << 4KB page
    store = tmp_store(x)
    store.stats.reset()
    store.read_vector(3)
    assert store.stats.bytes_read_total == PAGE_SIZE
    assert store.stats.read_amplification == PAGE_SIZE / 64


def test_block_reads_do_not_amplify(tmp_store):
    x = np.zeros((4096, 64), np.float32)
    store = tmp_store(x)
    store.stats.reset()
    store.read_block(0, 4096)
    assert store.stats.read_amplification < 1.01


def test_types_recall_and_canonicalize():
    pairs = np.asarray([[3, 1], [1, 3], [2, 2], [4, 5]])
    canon = canonicalize_pairs(pairs)
    assert canon.tolist() == [[1, 3], [4, 5]]
    assert recall(canon, np.asarray([[1, 3], [4, 5], [6, 7]])) == 2 / 3
    assert recall(np.zeros((0, 2), np.int64), np.zeros((0, 2), np.int64)) \
        == 1.0


def test_join_config_bucket_resolution():
    cfg = JoinConfig(epsilon=1.0)
    assert cfg.resolve_num_buckets(1_000_000) == 1000  # paper's 1‰
    assert cfg.resolve_num_buckets(100) >= 2
