"""End-to-end behaviour tests for the paper's system.

The flagship flow: on-disk vectors → DiskJoin (bucketize → prune →
orchestrate → verify) → semantic dedup → LM training on the deduplicated
stream — the full pipeline the paper motivates (§1, training-data
deduplication), exercised through the public API only.
"""
import os

import numpy as np
import pytest


def test_end_to_end_join_dedup_train(tmp_path):
    from repro.core import JoinConfig, recall, similarity_self_join
    from repro.data import brute_force_pairs, clustered_vectors, \
        epsilon_for_avg_neighbors
    from repro.data.dedup import semantic_dedup
    from repro.store.vector_store import FlatVectorStore
    from repro.configs import get_config, smoke_config
    from repro.train import AdamWConfig, TrainConfig, train

    # 1. corpus embeddings with planted near-duplicates
    rng = np.random.default_rng(0)
    base = clustered_vectors(2500, 32, seed=11)
    dups = base[:600] + rng.normal(scale=1e-3, size=(600, 32)).astype(
        np.float32)
    emb = np.concatenate([base, dups])

    # 2. the join itself meets its contract
    store = FlatVectorStore.from_array(str(tmp_path / "emb.bin"), emb)
    cfg = JoinConfig(epsilon=0.05, recall_target=0.9, pad_align=64,
                     memory_budget_bytes=max(1 << 20, emb.nbytes // 10))
    res = similarity_self_join(store, cfg, workdir=str(tmp_path))
    truth = brute_force_pairs(emb, 0.05)
    assert recall(res.pairs, truth) >= 0.88
    assert res.io_stats["read_amplification"] <= 1.15

    # 3. dedup drops the planted duplicates
    rep = semantic_dedup(emb, epsilon=0.05, recall_target=0.9,
                         workdir=str(tmp_path / "dedup"))
    assert rep.num_dropped >= 520

    # 4. the pipeline consumes the drop list and the LM trains on it
    cfg_lm = smoke_config(get_config("qwen3-0.6b"))
    out = train(cfg_lm, TrainConfig(
        steps=4, log_every=10, global_batch=2, seq_len=16,
        optimizer=AdamWConfig(learning_rate=1e-3, warmup_steps=1,
                              total_steps=4)))
    assert np.isfinite(out["final_loss"])


def test_join_is_deterministic_given_seed(tmp_path):
    from repro.core import JoinConfig, similarity_self_join
    from repro.data import clustered_vectors
    from repro.store.vector_store import FlatVectorStore

    x = clustered_vectors(3000, 32, seed=3)
    pair_sets = []
    for run in range(2):
        store = FlatVectorStore.from_array(
            str(tmp_path / f"x{run}.bin"), x)
        cfg = JoinConfig(epsilon=0.3, recall_target=0.9, seed=7,
                         pad_align=64, memory_budget_bytes=1 << 20)
        res = similarity_self_join(store, cfg,
                                   workdir=str(tmp_path / f"w{run}"))
        pair_sets.append(res.pairs)
    assert np.array_equal(pair_sets[0], pair_sets[1])


def test_spatial_order_beats_or_matches_gorder_on_loads(tmp_path):
    """Beyond-paper claim (EXPERIMENTS §Perf/J3) as a regression gate."""
    from repro.core import (JoinConfig, bucketize, build_bucket_graph,
                            simulate_belady)
    from repro.core import ordering
    from repro.data import clustered_vectors, epsilon_for_avg_neighbors
    from repro.store.vector_store import FlatVectorStore

    x = clustered_vectors(10000, 64, seed=1)
    eps = epsilon_for_avg_neighbors(x, 20)
    store = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
    cfg = JoinConfig(epsilon=eps, memory_budget_bytes=x.nbytes // 10,
                     num_buckets=100, pad_align=64)
    bs, meta, _ = bucketize(store, str(tmp_path / "b"), cfg)
    g = build_bucket_graph(meta, cfg)
    cap = max(2, (x.nbytes // 10)
              // ((((int(meta.sizes.max()) + 63) // 64) * 64) * 64 * 4))

    def loads(order):
        _, seq, pins = ordering.edge_schedule(g, order)
        return simulate_belady(seq, g.num_nodes, cap, pins).misses

    l_gorder = loads(ordering.gorder(g, ordering.window_size(cap, g)))
    l_spatial = loads(ordering.spatial_order(meta.centers))
    assert l_spatial <= l_gorder * 1.02


def test_dryrun_single_cell_on_one_device():
    """lower_cell works on whatever mesh exists (1 CPU device here) —
    the production-mesh variant is covered by results/dryrun.json."""
    import jax
    from repro.configs import SHAPES, get_config, smoke_config
    from repro.launch.steps import lower_cell
    from repro.models import build_model
    import dataclasses

    cfg = smoke_config(get_config("qwen3-0.6b"))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32,
                                global_batch=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    bundle = build_model(cfg)
    with mesh:
        lowered, info = lower_cell(bundle, shape, mesh)
        compiled = lowered.compile()
    assert info["kind"] == "train_step"
    from repro.launch.hlo_analysis import summarize_cost
    assert summarize_cost(compiled.cost_analysis())["flops"] > 0
