"""Device-resident verify pipeline (repro.compute): host/device parity
matrix (pairs AND distances byte-identical), device slab-pool residency
accounting (transfers == residencies, not edges), on-device compaction
vs np.nonzero, verify_batch config, batched Pallas dispatch, distributed
device mode + next-window prefetch, and the device query path."""
import numpy as np
import pytest


def _store(x, tmp_path, name):
    from repro.store.vector_store import FlatVectorStore
    return FlatVectorStore.from_array(str(tmp_path / name), x)


# ---------------------------------------------------------------------------
# host/device parity matrix — the engines must agree byte for byte
# ---------------------------------------------------------------------------
class TestHostDeviceParity:
    @pytest.mark.parametrize("io_mode,devices", [
        ("sync", 1), ("prefetch", 1), ("sync", 4), ("prefetch", 4)])
    def test_self_join_byte_identical(self, small_dataset, tmp_path,
                                      io_mode, devices):
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        base = dict(epsilon=eps, pad_align=64, num_buckets=24,
                    memory_budget_bytes=1 << 20, io_mode=io_mode,
                    io_devices=devices,
                    io_batch_reads=devices > 1, io_coalesce=devices > 1)
        rh = similarity_self_join(_store(x, tmp_path, "h.bin"),
                                  JoinConfig(compute_mode="host", **base))
        rd = similarity_self_join(_store(x, tmp_path, "d.bin"),
                                  JoinConfig(compute_mode="device", **base))
        assert rh.pairs.shape[0] > 0
        assert np.array_equal(rh.pairs, rd.pairs)
        assert np.array_equal(rh.distances, rd.distances)  # byte-identical
        assert rh.num_distance_computations == rd.num_distance_computations
        assert rh.bucket_loads == rd.bucket_loads  # same schedule replay

    @pytest.mark.parametrize("io_mode,devices", [
        ("sync", 1), ("prefetch", 1), ("prefetch", 4)])
    def test_cross_join_byte_identical(self, tmp_path, io_mode, devices):
        from repro.core import JoinConfig
        from repro.core.join import similarity_cross_join
        from repro.data import clustered_vectors

        rng = np.random.default_rng(3)
        x = clustered_vectors(2000, 32, seed=5)
        y = (x[:1200] + rng.normal(scale=0.05, size=(1200, 32))
             ).astype(np.float32)
        base = dict(epsilon=0.3, pad_align=64, num_buckets=16,
                    memory_budget_bytes=1 << 20, io_mode=io_mode,
                    io_devices=devices,
                    io_batch_reads=devices > 1, io_coalesce=devices > 1)
        rh = similarity_cross_join(_store(x, tmp_path, "xh"),
                                   _store(y, tmp_path, "yh"),
                                   JoinConfig(compute_mode="host", **base))
        rd = similarity_cross_join(_store(x, tmp_path, "xd"),
                                   _store(y, tmp_path, "yd"),
                                   JoinConfig(compute_mode="device",
                                              **base))
        assert rh.pairs.shape[0] > 0
        assert np.array_equal(rh.pairs, rd.pairs)
        assert np.array_equal(rh.distances, rd.distances)

    def test_attribute_mask_parity(self, small_dataset, tmp_path):
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        mask = np.arange(x.shape[0]) % 3 != 0
        base = dict(epsilon=eps, pad_align=64, num_buckets=16,
                    memory_budget_bytes=1 << 20)
        rh = similarity_self_join(_store(x, tmp_path, "ah"),
                                  JoinConfig(**base), attribute_mask=mask)
        rd = similarity_self_join(_store(x, tmp_path, "ad"),
                                  JoinConfig(compute_mode="device", **base),
                                  attribute_mask=mask)
        assert rh.pairs.shape[0] > 0
        assert mask[rd.pairs].all()
        assert np.array_equal(rh.pairs, rd.pairs)
        assert np.array_equal(rh.distances, rd.distances)

    @pytest.mark.parametrize("vb", [1, 5, 32])
    def test_verify_batch_sizes_agree(self, small_dataset, tmp_path, vb):
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        x = x[:1500]
        base = dict(epsilon=eps, pad_align=64, num_buckets=12,
                    memory_budget_bytes=1 << 20)
        ref = similarity_self_join(_store(x, tmp_path, f"r{vb}"),
                                   JoinConfig(**base))
        for cm in ("host", "device"):
            r = similarity_self_join(
                _store(x, tmp_path, f"{cm}{vb}"),
                JoinConfig(compute_mode=cm, verify_batch=vb, **base))
            assert np.array_equal(ref.pairs, r.pairs)
            assert np.array_equal(ref.distances, r.distances)

    def test_pallas_path_parity(self, tmp_path):
        """Pallas (interpret) and device mode share the batched dispatch:
        use_pallas host vs use_pallas device must stay byte-identical."""
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join
        from repro.data import clustered_vectors, epsilon_for_avg_neighbors

        x = clustered_vectors(900, 32, seed=5)
        eps = epsilon_for_avg_neighbors(x, 8)
        base = dict(epsilon=eps, pad_align=64, num_buckets=8,
                    memory_budget_bytes=1 << 19, use_pallas=True)
        rp = similarity_self_join(_store(x, tmp_path, "p"),
                                  JoinConfig(**base))
        rd = similarity_self_join(_store(x, tmp_path, "pd"),
                                  JoinConfig(compute_mode="device", **base))
        rr = similarity_self_join(_store(x, tmp_path, "pr"),
                                  JoinConfig(**{**base,
                                               "use_pallas": False}))
        assert np.array_equal(rp.pairs, rd.pairs)
        assert np.array_equal(rp.distances, rd.distances)
        # pallas vs reference kernel: same pair set (bit-level d2 may
        # differ between the two accumulation orders)
        assert set(map(tuple, rp.pairs.tolist())) == \
            set(map(tuple, rr.pairs.tolist()))

    def test_config_validation(self):
        from repro.core import JoinConfig
        from repro.core.types import QueryConfig

        with pytest.raises(ValueError, match="compute_mode"):
            JoinConfig(epsilon=0.1, compute_mode="gpu")
        with pytest.raises(ValueError, match="verify_batch"):
            JoinConfig(epsilon=0.1, verify_batch=0)
        with pytest.raises(ValueError, match="verify_batch"):
            QueryConfig(epsilon=0.1, verify_batch=-1)
        # both are query-time: per-call overrides must be accepted
        from repro.core.types import QUERY_TIME_FIELDS
        assert {"compute_mode", "verify_batch",
                "emulate_xfer_gb_s"} <= QUERY_TIME_FIELDS


# ---------------------------------------------------------------------------
# device slab pool: transfers bounded by residencies, not edges
# ---------------------------------------------------------------------------
class TestDeviceSlabPool:
    def test_operand_transfers_once_per_residency(self):
        from repro.compute import DeviceSlabPool

        pool = DeviceSlabPool()
        slab = np.ones((8, 4), np.float32)
        pool.operand(3, slab)
        for _ in range(5):
            pool.operand(3, slab)      # resident: no new transfer
        assert (pool.transfers, pool.hits) == (1, 5)
        pool.evict(3)
        pool.operand(3, slab)          # new residency: one new transfer
        assert pool.transfers == 2
        assert pool.h2d_bytes == 2 * slab.nbytes

    def test_staged_operand_harvested_to_device(self):
        import jax

        from repro.compute import DeviceSlabPool

        pool = DeviceSlabPool()
        slab = np.arange(12, dtype=np.float32).reshape(3, 4)
        first = pool.operand(7, slab)
        assert isinstance(first, np.ndarray)  # staged host copy
        assert pool.needs_harvest(7)
        dev = jax.device_put(slab)
        pool.harvest(7, dev)
        assert not pool.needs_harvest(7)
        assert pool.operand(7, slab) is dev   # later batches go device

    def test_executor_transfers_equal_residencies(self, tmp_path):
        """End to end under a tight budget: every verified residency is
        exactly one H2D transfer — edges re-touching a resident bucket
        hit the device pool instead of re-staging."""
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join
        from repro.data import clustered_vectors, epsilon_for_avg_neighbors

        x = clustered_vectors(3000, 32, seed=7, clusters=6)
        eps = epsilon_for_avg_neighbors(x, 15)
        cfg = JoinConfig(epsilon=eps, pad_align=64, num_buckets=10,
                         memory_budget_bytes=200_000,  # forces evictions
                         compute_mode="device")
        res = similarity_self_join(_store(x, tmp_path, "t"), cfg)
        p = res.io_stats["pipeline"]
        # with ~300-row buckets every residency carries an intra edge, so
        # every load is verified: transfers == loads == residencies
        assert p["h2d_transfers"] == res.bucket_loads
        assert res.bucket_loads > cfg.num_buckets  # evictions + reloads
        assert p["h2d_transfers_saved"] > 0
        assert p["device_slab_hits"] == p["h2d_transfers_saved"]
        # and strictly below the per-edge staging baseline: 2 operand
        # stagings per edge reference
        refs = p["h2d_transfers"] + p["h2d_transfers_saved"]
        assert p["h2d_transfers"] < refs

    def test_host_vs_device_h2d_bytes(self, small_dataset, tmp_path):
        """Acceptance gate: device h2d volume strictly below the host
        per-edge staging baseline on the same join."""
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        base = dict(epsilon=eps, pad_align=64, num_buckets=24,
                    memory_budget_bytes=1 << 20, io_mode="prefetch")
        rh = similarity_self_join(_store(x, tmp_path, "bh"),
                                  JoinConfig(compute_mode="host", **base))
        rd = similarity_self_join(_store(x, tmp_path, "bd"),
                                  JoinConfig(compute_mode="device", **base))
        ph = rh.io_stats["pipeline"]
        pd = rd.io_stats["pipeline"]
        assert 0 < pd["h2d_bytes"] < ph["h2d_bytes"]
        assert 0 < pd["d2h_bytes"] < ph["d2h_bytes"]


# ---------------------------------------------------------------------------
# on-device compaction kernel
# ---------------------------------------------------------------------------
class TestCompaction:
    def _mask_case(self, seed=0, E=3, M=24, N=17, thresh=0.2):
        rng = np.random.default_rng(seed)
        d2 = rng.random((E, M, N)).astype(np.float32)
        mask = d2 <= thresh
        return d2, mask

    def test_matches_nonzero_order_and_values(self):
        import jax.numpy as jnp

        from repro.compute import compact_pairs

        d2, mask = self._mask_case()
        E, M, N = d2.shape
        na = np.array([M, M - 5, 0], np.int32)   # lane 2 masked out
        nb = np.array([N, N - 3, N], np.int32)
        intra = np.array([False, True, False])
        counts, r, c, d = [np.asarray(o) for o in compact_pairs(
            jnp.asarray(d2), jnp.asarray(mask), jnp.asarray(na),
            jnp.asarray(nb), jnp.asarray(intra), 256)]
        for e in range(E):
            m = mask[e][:na[e], :nb[e]]
            if intra[e]:
                m = np.triu(m, k=1)
            rows, cols = np.nonzero(m)
            k = rows.size
            assert counts[e] == k
            assert np.array_equal(r[e, :k], rows)
            assert np.array_equal(c[e, :k], cols)
            np.testing.assert_array_equal(
                d[e, :k], np.sqrt(d2[e][rows, cols]))
        assert counts[2] == 0  # na = 0 kills the padded lane

    def test_overflow_reports_true_count(self):
        import jax.numpy as jnp

        from repro.compute import compact_pairs

        d2, mask = self._mask_case(thresh=0.9)  # dense: many pairs
        E, M, N = d2.shape
        k_cap = 8
        na = np.full(E, M, np.int32)
        nb = np.full(E, N, np.int32)
        counts, r, c, d = [np.asarray(o) for o in compact_pairs(
            jnp.asarray(d2), jnp.asarray(mask), jnp.asarray(na),
            jnp.asarray(nb), jnp.asarray(np.zeros(E, bool)), k_cap)]
        true_counts = mask.sum((1, 2))
        assert np.array_equal(counts, true_counts)  # exact despite overflow
        assert (true_counts > k_cap).all()
        # the k_cap entries that did land are the FIRST pairs in
        # row-major order
        rows, cols = np.nonzero(mask[0])
        assert np.array_equal(r[0], rows[:k_cap])
        assert np.array_equal(c[0], cols[:k_cap])

    def test_executor_overflow_recovery(self, tmp_path):
        """A pair-dense workload whose first batches overflow the initial
        compaction capacity must still match host results exactly."""
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join
        from repro.compute import engine as eng

        rng = np.random.default_rng(11)
        # one tight clump: nearly all pairs within ε of each other
        x = (rng.normal(scale=0.02, size=(600, 16))).astype(np.float32)
        base = dict(epsilon=1.0, pad_align=64, num_buckets=4,
                    memory_budget_bytes=1 << 19, prune=False)
        rh = similarity_self_join(_store(x, tmp_path, "oh"),
                                  JoinConfig(**base))
        old = eng.PAIR_CAP_INIT
        try:
            eng.PAIR_CAP_INIT = 8  # force the overflow path
            rd = similarity_self_join(
                _store(x, tmp_path, "od"),
                JoinConfig(compute_mode="device", **base))
        finally:
            eng.PAIR_CAP_INIT = old
        assert rh.pairs.shape[0] > 1000
        assert np.array_equal(rh.pairs, rd.pairs)
        assert np.array_equal(rh.distances, rd.distances)


# ---------------------------------------------------------------------------
# batched kernel dispatch (the use_pallas per-edge loop fix)
# ---------------------------------------------------------------------------
class TestBatchedKernel:
    def test_batched_pallas_matches_reference(self):
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        rng = np.random.default_rng(0)
        u = rng.normal(size=(4, 64, 32)).astype(np.float32)
        v = rng.normal(size=(4, 64, 32)).astype(np.float32)
        d2r, mr = kops.verify_pairs_batch(jnp.asarray(u), jnp.asarray(v),
                                          1.2)
        d2p, mp = kops.verify_pairs_batch(jnp.asarray(u), jnp.asarray(v),
                                          1.2, use_pallas=True)
        np.testing.assert_allclose(np.asarray(d2r), np.asarray(d2p),
                                   atol=1e-4)
        assert np.array_equal(np.asarray(mr), np.asarray(mp))

    def test_batched_pallas_pads_odd_shapes(self):
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        rng = np.random.default_rng(1)
        u = rng.normal(size=(2, 192, 160)).astype(np.float32)
        v = rng.normal(size=(2, 192, 160)).astype(np.float32)
        d2r, mr = kops.verify_pairs_batch(jnp.asarray(u), jnp.asarray(v),
                                          4.0)
        d2p, mp = kops.verify_pairs_batch(jnp.asarray(u), jnp.asarray(v),
                                          4.0, use_pallas=True)
        assert d2p.shape == (2, 192, 192)
        np.testing.assert_allclose(np.asarray(d2r), np.asarray(d2p),
                                   atol=1e-3)


# ---------------------------------------------------------------------------
# distributed join: device slabs + next-window prefetch
# ---------------------------------------------------------------------------
class TestDistributedDevice:
    def _setup(self, tmp_path, budget):
        from repro.core import JoinConfig, build_bucket_graph, bucketize
        from repro.data import clustered_vectors, epsilon_for_avg_neighbors

        x = clustered_vectors(3000, 32, seed=5)
        eps = epsilon_for_avg_neighbors(x, 10)
        cfg = dict(epsilon=eps, recall_target=0.95, pad_align=64,
                   memory_budget_bytes=budget, num_buckets=24)
        store = _store(x, tmp_path, "x.bin")
        bs, meta, _ = bucketize(store, str(tmp_path / "bk"),
                                JoinConfig(**cfg))
        graph = build_bucket_graph(meta, JoinConfig(**cfg))
        return bs, meta, graph, cfg

    def test_device_mode_identical_pairs(self, tmp_path):
        from repro.core import JoinConfig
        from repro.core.distributed import DistributedJoin

        bs, meta, graph, cfg = self._setup(tmp_path, 150_000)
        ph, ih = DistributedJoin(bs, meta, JoinConfig(**cfg)).run(graph)
        pd, idv = DistributedJoin(
            bs, meta, JoinConfig(compute_mode="device", **cfg)).run(graph)
        assert np.array_equal(ph, pd)
        assert ih["supersteps"] > 1
        assert ih["host_loads"] == idv["host_loads"]
        # device transfers bounded by host residencies
        assert idv["h2d_transfers"] <= idv["host_loads"]
        assert idv["device_slab_hits"] > 0

    def test_next_window_prefetch_overlaps(self, tmp_path):
        """ROADMAP item: window w+1's missing buckets are pulled while
        window w verifies — loads unchanged, most issued as prefetch."""
        from repro.core import JoinConfig
        from repro.core.distributed import DistributedJoin

        bs, meta, graph, cfg = self._setup(tmp_path, 150_000)
        _, info = DistributedJoin(bs, meta, JoinConfig(**cfg)).run(graph)
        assert info["supersteps"] > 1
        assert info["prefetched_buckets"] > 0
        # prefetched loads are a subset of total loads (never extra I/O)
        assert info["prefetched_buckets"] <= info["host_loads"]


# ---------------------------------------------------------------------------
# online queries through the device path
# ---------------------------------------------------------------------------
class TestQueryDevice:
    def test_query_batch_device_parity(self, small_dataset, tmp_path):
        from repro.core import DiskJoinIndex, JoinConfig

        x, eps = small_dataset
        store = _store(x, tmp_path, "q.bin")
        cfg = JoinConfig(epsilon=eps, pad_align=64, num_buckets=32,
                         memory_budget_bytes=1 << 20)
        with DiskJoinIndex.build(store, cfg,
                                 str(tmp_path / "idx")) as index:
            Q = x[:30] + 0.001
            host = index.query_batch(Q)
            base = index.pipeline_snapshot()
            dev = index.query_batch(Q, compute_mode="device")
            snap = index.pipeline_snapshot()
            for (ih, dh), (idv, ddv) in zip(host, dev):
                oh, od = np.argsort(ih), np.argsort(idv)
                assert np.array_equal(np.sort(ih), np.sort(idv))
                # device distances are f32 (host is f64): close, not
                # byte-identical — documented in _make_device_verify
                np.testing.assert_allclose(np.asarray(dh)[oh],
                                           np.asarray(ddv)[od], atol=1e-3)
            # the wave's query block crossed once; bucket slabs reused it
            assert snap["h2d_transfers"] > base["h2d_transfers"]
            assert snap["h2d_transfers_saved"] > base["h2d_transfers_saved"]
