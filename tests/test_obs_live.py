"""Live observability (repro.obs.live): streaming rollups, SLO
burn-rate monitors, live cost calibration, tracer sinks/ring stats, the
fleet merge through IndexRouter.metrics_snapshot (exact histogram-merge
path, zero-traffic shard included), periodic in-run residency snapshots,
the text dashboard, and the perf-regression comparator."""
import io
import json
import os
import sys
import time

import numpy as np
import pytest

from repro.core import DiskJoinIndex, JoinConfig
from repro.data import clustered_vectors
from repro.obs import (Histogram, MetricsRegistry, disable_tracing,
                       enable_tracing, get_tracer, trace_session)
from repro.obs import dash
from repro.obs.live import (Alert, LiveCalibrator, LiveObserver, Slo,
                            SloMonitor, TimeSeries, default_serving_slos,
                            merge_live_sections)
from repro.plan import CostModel
from repro.serve import IndexRouter
from repro.store.vector_store import FlatVectorStore

# benchmarks/ is a namespace package rooted at the repo top; regress.py's
# pure comparison functions are unit-tested here
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


# -- synthetic tracer-event tuples (the exact shapes Tracer._record sees) ----

def X(name, ts, dur, **args):
    return ("X", name, ts, dur, args or None, None)


def I(name, ts, **args):  # noqa: E743 - mirrors the Chrome phase letter
    return ("i", name, ts, 0.0, args or None, None)


def C(name, ts, value):
    return ("C", name, ts, 0.0, {"value": value}, None)


def B(name, ts, aid, **args):
    return ("b", name, ts, 0.0, args or None, aid)


def E(name, ts, aid, **args):
    return ("e", name, ts, 0.0, args or None, aid)


@pytest.fixture(autouse=True)
def _no_global_tracer():
    """Every test starts and ends with tracing disabled."""
    disable_tracing()
    yield
    disable_tracing()


def _build_index(tmp_path, n=3000, dim=16, seed=3, sub="idx", **cfg_kw):
    x = clustered_vectors(n, dim, seed=seed)
    store = FlatVectorStore.from_array(str(tmp_path / f"{sub}.bin"), x)
    base = dict(epsilon=0.35, recall_target=0.9, pad_align=64,
                num_buckets=max(16, n // 150),
                memory_budget_bytes=max(1 << 20, x.nbytes // 10))
    base.update(cfg_kw)
    return DiskJoinIndex.build(store, JoinConfig(**base),
                               str(tmp_path / sub)), x


# ---------------------------------------------------------------------------
# TimeSeries: folding, pairing, windowing
# ---------------------------------------------------------------------------

class TestTimeSeries:
    def test_folds_spans_with_exact_counts_and_units(self):
        ts = TimeSeries(window_s=10.0)
        for dur in (1e-4, 2e-4, 3e-4):
            ts.on_event(X("io.read", 0.1, dur, buckets=2))
        ts.on_event(X("io.read", 0.2, 4e-4, buckets=1, dropped=True))
        ts.poll(now=100.0)  # close the window
        agg = ts.span_aggregate("io.read")
        assert agg["count"] == 4
        assert agg["total_s"] == pytest.approx(1e-3)
        assert agg["units"] == pytest.approx(7.0)   # 2+2+2+1 buckets
        assert agg["bad"] == 1
        assert agg["min"] == pytest.approx(1e-4)
        assert agg["max"] == pytest.approx(4e-4)
        assert sum(agg["buckets"]) == 4

    def test_percentiles_agree_with_histogram_percentile_from(self):
        ts = TimeSeries(window_s=10.0)
        durs = [1e-5 * (i + 1) for i in range(50)]
        for d in durs:
            ts.on_event(X("s", 0.5, d))
        ts.poll(now=100.0)
        agg = ts.span_aggregate("s")
        assert agg["p95"] == Histogram.percentile_from(
            ts.bounds, agg["buckets"], 95)
        assert ts.percentile("s", 50) == agg["p50"]

    def test_async_pairs_fold_as_latency_spans(self):
        ts = TimeSeries(window_s=10.0)
        ts.on_event(B("serve.request", 1.0, 7))
        ts.on_event(E("serve.request", 1.25, 7))
        ts.on_event(E("serve.request", 1.5, 999))  # unmatched end: dropped
        ts.poll(now=100.0)
        agg = ts.span_aggregate("serve.request")
        assert agg["count"] == 1
        assert agg["total_s"] == pytest.approx(0.25)

    def test_async_end_args_mark_bad(self):
        ts = TimeSeries(window_s=10.0)
        ts.on_event(B("serve.request", 1.0, 1))
        ts.on_event(E("serve.request", 1.1, 1, dropped=True))
        ts.on_event(B("serve.request", 1.0, 2))
        ts.on_event(E("serve.request", 1.2, 2))
        ts.poll(now=100.0)
        agg = ts.span_aggregate("serve.request")
        assert agg["count"] == 2 and agg["bad"] == 1

    def test_counters_and_instants_roll_up(self):
        ts = TimeSeries(window_s=10.0)
        for v in (3, 9, 5):
            ts.on_event(C("io.depth", 0.1, v))
        ts.on_event(I("slo.alert", 0.2, slo="x"))
        ts.on_event(I("slo.alert", 0.3, slo="x"))
        ts.poll(now=100.0)
        sec = ts.section()
        assert sec["counters"]["io.depth"] == {"last": 5, "max": 9, "n": 3}
        assert sec["instants"]["slo.alert"] == 2

    def test_windows_close_in_order_and_notify_subscribers(self):
        ts = TimeSeries(window_s=1.0, windows=8)
        closed = []
        ts.subscribe(closed.append)
        ts.on_event(X("s", 0.5, 1e-3))
        ts.on_event(X("s", 1.6, 1e-3))   # closes [0.5, 1.5)
        ts.on_event(X("s", 2.7, 1e-3))   # closes [1.5, 2.5)
        assert [w.t0 for w in closed] == [0.5, 1.5]
        assert closed[0].spans["s"].count == 1
        assert len(ts.recent()) == 2

    def test_long_gap_snaps_grid_instead_of_looping(self):
        ts = TimeSeries(window_s=0.01, windows=4)
        ts.on_event(X("s", 0.0, 1e-3))
        t0 = time.perf_counter()
        ts.on_event(X("s", 1e6, 1e-3))   # ~1e8 windows of idle gap
        assert time.perf_counter() - t0 < 0.5
        assert ts.current.t0 <= 1e6 < ts.current.t1

    def test_broken_subscriber_does_not_stop_folding(self):
        ts = TimeSeries(window_s=1.0)

        def bad(_):
            raise RuntimeError("boom")
        got = []
        ts.subscribe(bad)
        ts.subscribe(got.append)
        ts.on_event(X("s", 0.1, 1e-3))
        ts.on_event(X("s", 5.0, 1e-3))   # closes 4 windows incl. empties
        assert len(got) == 4
        assert got[0].spans["s"].count == 1

    def test_rate_and_unit_cost_series(self):
        ts = TimeSeries(window_s=1.0)
        for t in (0.1, 0.2, 0.3):
            ts.on_event(X("io.read", t, 2e-4, buckets=2))
        ts.poll(now=1.2)
        assert ts.rate("io.read") == pytest.approx(3.0)
        [(s_per_unit, cnt)] = ts.unit_cost_series("io.read")
        assert s_per_unit == pytest.approx(1e-4)   # 6e-4 s over 6 buckets
        assert cnt == 3


# ---------------------------------------------------------------------------
# Tracer sinks + ring stats + export drop warning
# ---------------------------------------------------------------------------

class TestTracerSink:
    def test_sink_receives_all_phases_and_remove_stops_delivery(self):
        tr = enable_tracing()
        ts = TimeSeries(window_s=1e9)
        tr.add_sink(ts.on_event)
        with tr.span("a"):
            pass
        tr.instant("i1")
        tr.counter("c1", 4)
        tr.async_begin("r", 1)
        tr.async_end("r", 1)
        assert ts.events_folded == 5
        tr.remove_sink(ts.on_event)   # bound-method equality removal
        tr.instant("i2")
        assert ts.events_folded == 5

    def test_broken_sink_does_not_break_recording(self):
        tr = enable_tracing()

        def bad(_ev):
            raise ValueError("sink bug")
        tr.add_sink(bad)
        tr.instant("x")
        assert any(e["name"] == "x" for e in tr.events())

    def test_ring_stats_counts_drops(self):
        tr = enable_tracing(ring_capacity=16)
        for i in range(50):
            tr.instant("e", i=i)
        rs = tr.ring_stats()
        assert rs["dropped"] == 50 - 16 and tr.dropped == 34
        assert rs["ring_capacity"] == 16
        assert rs["threads"][0]["occupancy"] == 16

    def test_export_warns_on_dropped_events(self, tmp_path):
        tr = enable_tracing(ring_capacity=16)
        for i in range(40):
            tr.instant("e", i=i)
        with pytest.warns(UserWarning, match="ring wrap-around"):
            tr.export(str(tmp_path / "t.json"))

    def test_export_quiet_without_drops(self, tmp_path):
        import warnings as w
        tr = enable_tracing()
        tr.instant("e")
        with w.catch_warnings():
            w.simplefilter("error")
            tr.export(str(tmp_path / "t.json"))


# ---------------------------------------------------------------------------
# SLO burn-rate monitor
# ---------------------------------------------------------------------------

def _drive_windows(ts, name, t0, n_windows, dur, per_window=4, **args):
    """Feed ``per_window`` spans into each of ``n_windows`` consecutive
    windows, then close through the last one. Returns the next t0."""
    t = t0
    for _ in range(n_windows):
        for k in range(per_window):
            ts.on_event(X(name, t + 0.1 + 0.01 * k, dur, **args))
        t += ts.window_s
    ts.poll(now=t + ts.window_s)
    return t


class TestSloMonitor:
    def _latency_slo(self, **kw):
        base = dict(fast_windows=2, slow_windows=4, burn_threshold=2.0)
        base.update(kw)
        return Slo.latency("lat", "q", 0.01, objective=0.5, **base)

    def test_fires_only_when_fast_and_slow_burn(self):
        ts = TimeSeries(window_s=1.0)
        mon = SloMonitor(ts, [self._latency_slo()])
        t = _drive_windows(ts, "q", 0.0, 4, dur=1e-3)   # healthy
        assert mon.status()["lat"]["state"] == "ok"
        # one bad window: fast burn spikes, slow still diluted
        t = _drive_windows(ts, "q", t, 1, dur=0.1)
        fired_after_one = mon.fired
        t = _drive_windows(ts, "q", t, 3, dur=0.1)      # sustained
        assert mon.fired >= 1
        assert mon.status()["lat"]["state"] == "firing"
        assert mon.active_alerts()[0]["slo"] == "lat"
        # recovery: fast window drains below threshold -> resolves
        _drive_windows(ts, "q", t, 4, dur=1e-3)
        assert mon.status()["lat"]["state"] == "ok"
        assert mon.resolved == mon.fired == 1
        assert fired_after_one <= 1

    def test_zero_traffic_burns_nothing(self):
        ts = TimeSeries(window_s=1.0)
        mon = SloMonitor(ts, [self._latency_slo()])
        ts.on_event(X("other", 0.1, 1e-3))
        ts.poll(now=10.0)    # several empty windows close
        st = mon.status()["lat"]
        assert st["state"] == "ok"
        assert st["fast_burn"] == 0.0 and st["good_fraction"] is None
        assert mon.fired == 0

    def test_bad_fraction_slo_counts_dropped_requests(self):
        ts = TimeSeries(window_s=1.0)
        slo = Slo.drop_rate("avail", span="serve.request", objective=0.5,
                            fast_windows=1, slow_windows=2,
                            burn_threshold=1.5)
        mon = SloMonitor(ts, [slo])
        t = 0.0
        for w in range(3):
            for k in range(4):
                ts.on_event(X("serve.request", t + 0.1 + 0.01 * k, 1e-3,
                              dropped=(w > 0)))
            t += 1.0
        ts.poll(now=t + 1.0)
        assert mon.status()["avail"]["state"] == "firing"

    def test_pipeline_ratio_slo_uses_window_deltas(self):
        ts = TimeSeries(window_s=1.0)
        pipe = {"hits": 0, "reads": 0}
        slo = Slo.ratio("hit_rate", ("hits",), ("hits", "reads"),
                        objective=0.5, fast_windows=1, slow_windows=2,
                        burn_threshold=1.5)
        mon = SloMonitor(ts, [slo], pipeline_source=lambda: dict(pipe))
        # window 1: 100% hits cumulative
        pipe.update(hits=10, reads=0)
        ts.on_event(X("q", 0.5, 1e-3))
        ts.on_event(X("q", 1.5, 1e-3))
        assert mon.status()["hit_rate"]["state"] == "ok"
        # window 2: cumulative still looks fine (14/18) but the DELTA
        # is 4 hits / 18 reads — the monitor must see the regression
        pipe.update(hits=14, reads=18)
        ts.on_event(X("q", 2.5, 1e-3))
        pipe.update(hits=18, reads=36)
        ts.on_event(X("q", 3.5, 1e-3))
        st = mon.status()["hit_rate"]
        assert st["state"] == "firing"
        assert st["good_fraction"] == pytest.approx(4 / 22, abs=0.05)

    def test_alert_plumbing_callbacks_tracer_metrics(self):
        tr = enable_tracing()
        reg = MetricsRegistry()
        got = []
        ts = TimeSeries(window_s=1.0)
        mon = SloMonitor(ts, [self._latency_slo(fast_windows=1,
                                                slow_windows=1)],
                         tracer=tr, metrics=reg, on_alert=got.append)
        t = _drive_windows(ts, "q", 0.0, 2, dur=0.1)
        assert got and isinstance(got[0], Alert)
        assert got[0].state == "firing" and got[0].slo == "lat"
        assert json.dumps(got[0].to_dict())   # JSON-able
        snap = reg.snapshot()
        assert snap["counters"]["slo.alerts_fired"] == 1
        assert snap["gauges"]["slo.firing"] == 1
        assert any(e["name"] == "slo.alert" for e in tr.events())
        _drive_windows(ts, "q", t, 2, dur=1e-3)
        assert reg.snapshot()["counters"]["slo.alerts_resolved"] == 1
        assert reg.snapshot()["gauges"]["slo.firing"] == 0

    def test_slo_spec_validation(self):
        with pytest.raises(ValueError, match="objective"):
            Slo.latency("x", "s", 0.1, objective=1.0)
        with pytest.raises(ValueError, match="threshold_s"):
            Slo("x", 0.9, "latency", span="s")
        with pytest.raises(ValueError, match="total_fields"):
            Slo("x", 0.9, "pipeline_ratio", good_fields=("a",))
        with pytest.raises(ValueError, match="fast_windows"):
            Slo.latency("x", "s", 0.1, fast_windows=9, slow_windows=3)
        assert len(default_serving_slos()) == 5


# ---------------------------------------------------------------------------
# LiveCalibrator + CostModel live tier
# ---------------------------------------------------------------------------

class TestLiveCalibration:
    def _ts_with_reads(self, per_window_s, buckets=1, per_window=3):
        ts = TimeSeries(window_s=1.0)
        t = 0.0
        for dur in per_window_s:
            for k in range(per_window):
                ts.on_event(X("io.read", t + 0.1 + 0.01 * k, dur,
                              buckets=buckets))
            t += 1.0
        ts.poll(now=t + 1.0)
        return ts

    def test_median_of_per_window_ratios(self):
        ts = self._ts_with_reads([1e-3, 2e-3, 8e-3])
        cal = LiveCalibrator(ts, windows=8, min_samples=4)
        got = cal.read_s_per_bucket()
        assert got["value"] == pytest.approx(2e-3)   # median, not mean
        assert got["samples"] == 9 and got["windows"] == 3

    def test_min_samples_gate(self):
        ts = self._ts_with_reads([1e-3], per_window=2)
        cal = LiveCalibrator(ts, windows=8, min_samples=4)
        assert cal.read_s_per_bucket() is None
        assert cal.constants() == {}

    def test_rolling_horizon_forgets_old_regime(self):
        ts = self._ts_with_reads([1e-3] * 6 + [5e-3] * 4)
        cal = LiveCalibrator(ts, windows=4, min_samples=4)
        assert cal.read_s_per_bucket()["value"] == pytest.approx(5e-3)

    def test_link_gb_s_from_bytes(self):
        ts = TimeSeries(window_s=1.0)
        nbytes = 1 << 20
        for t in (0.1, 0.2, 0.3, 0.4):
            ts.on_event(X("link.xfer", t, nbytes / 2e9, bytes=nbytes))
        ts.poll(now=2.0)
        cal = LiveCalibrator(ts, min_samples=4)
        assert cal.link_gb_s()["value"] == pytest.approx(2.0, rel=1e-6)
        assert "h2d_gb_s" in cal.constants()

    def test_cost_model_live_tier_and_provenance(self):
        live = {"read_s_per_bucket": {"value": 3e-3, "samples": 12,
                                      "windows": 4},
                "h2d_gb_s": {"value": 8.0, "samples": 6, "windows": 4}}
        m = CostModel.from_telemetry(None, None, live=live)
        assert m.read_s_per_bucket == pytest.approx(3e-3)
        assert m.h2d_gb_s == pytest.approx(8.0)
        assert m.provenance["read_s_per_bucket"] == \
            "live(12 spans/4 windows)"
        assert "live" in m.provenance["link"]
        assert "live" in m.describe()

    def test_measured_beats_live_beats_config(self):
        class Cfg:
            emulate_read_latency_s = 7e-3
            emulate_xfer_gb_s = 1.0
        live = {"read_s_per_bucket": {"value": 3e-3, "samples": 2,
                                      "windows": 1},
                "h2d_gb_s": {"value": 8.0, "samples": 2, "windows": 1}}
        pipeline = {"loads": 10, "read_s": 0.05}
        m = CostModel.from_telemetry(Cfg(), pipeline, live=live)
        assert m.read_s_per_bucket == pytest.approx(5e-3)  # measured
        assert m.provenance["read_s_per_bucket"].startswith("measured")
        # no counter measures the link: live IS its top tier
        assert m.h2d_gb_s == pytest.approx(8.0)
        m2 = CostModel.from_telemetry(Cfg(), None, live=None)
        assert m2.read_s_per_bucket == pytest.approx(7e-3)  # config
        assert m2.h2d_gb_s == pytest.approx(1.0)

    def test_cost_model_accepts_calibrator_object(self):
        ts = self._ts_with_reads([2e-3, 2e-3])
        cal = LiveCalibrator(ts, min_samples=4)
        m = CostModel.from_telemetry(None, None, live=cal)
        assert m.read_s_per_bucket == pytest.approx(2e-3)


# ---------------------------------------------------------------------------
# attach_live end-to-end on a real session
# ---------------------------------------------------------------------------

class TestAttachLive:
    def test_attach_serves_and_detach_restores(self, tmp_path):
        index, x = _build_index(tmp_path)
        assert not get_tracer().enabled
        obs = index.attach_live(window_s=0.05)
        assert get_tracer().enabled     # attach owns tracing when off
        assert index.live is obs
        with pytest.raises(RuntimeError, match="already attached"):
            index.attach_live()
        for i in range(20):
            index.query(x[i])
        time.sleep(0.06)
        obs.poll()
        snap = index.metrics_snapshot()
        assert "io.read" in snap["live"]["spans"]
        assert "query.execute" in snap["live"]["spans"]
        assert snap["live"]["slos"]     # default serving SLOs watched
        assert snap["tracer"]["enabled"] and snap["tracer"]["dropped"] == 0
        index.detach_live()
        assert index.live is None
        assert not get_tracer().enabled  # owned tracing turned back off
        assert "live" not in index.metrics_snapshot()
        index.close()

    def test_respects_existing_tracer(self, tmp_path):
        index, x = _build_index(tmp_path)
        with trace_session() as tr:
            obs = index.attach_live(window_s=0.05)
            assert obs.tracer is tr and not obs.owns_tracing
            index.query(x[0])
            index.detach_live()
            assert get_tracer() is tr   # not ours: left enabled
        index.close()

    def test_live_constants_reach_planner(self, tmp_path):
        index, x = _build_index(tmp_path)
        index.attach_live(window_s=0.02, calibrate_min_samples=1,
                          calibrate_windows=16)
        for i in range(30):
            index.query(x[i], emulate_read_latency_s=2e-3)
            index.drop_warm_cache()
        time.sleep(0.03)
        index.live.poll()
        consts = index.live.live_constants()
        assert consts.get("read_s_per_bucket"), consts
        cfg = index._resolve({"epsilon": 0.35})
        # serving feeds no cumulative `loads` counter (batch joins do),
        # so the live tier is the top candidate for the read constant
        cost = index._planner_for(cfg).cost
        assert "live(" in cost.provenance["read_s_per_bucket"]
        assert cost.read_s_per_bucket == pytest.approx(
            consts["read_s_per_bucket"]["value"])
        index.detach_live()
        index.close()

    def test_close_detaches_live(self, tmp_path):
        index, x = _build_index(tmp_path)
        index.attach_live(window_s=0.05)
        index.query(x[0])
        index.close()
        assert index.live is None
        assert not get_tracer().enabled


# ---------------------------------------------------------------------------
# Periodic in-run residency snapshots
# ---------------------------------------------------------------------------

class TestPeriodicResidency:
    def test_snapshots_during_serving_not_only_at_close(self, tmp_path):
        index, x = _build_index(tmp_path)
        res_path = os.path.join(index.workdir, "residency.json")
        assert not os.path.exists(res_path)
        index.enable_residency_snapshots(interval_s=0.0)
        for i in range(8):
            index.query(x[i])
        index._residency_committer.drain()
        assert os.path.exists(res_path), \
            "no residency snapshot written mid-run"
        with open(res_path) as f:
            doc = json.load(f)
        assert doc["format"] == "diskjoin-residency/v1"
        assert doc["buckets"]            # warm buckets captured
        assert index.stats.snapshot()["residency_snapshots"] >= 1
        index.disable_residency_snapshots()
        n = index.stats.snapshot()["residency_snapshots"]
        index.query(x[0])
        assert index.stats.snapshot()["residency_snapshots"] == n
        index.close()

    def test_interval_gates_submissions(self, tmp_path):
        index, x = _build_index(tmp_path)
        index.enable_residency_snapshots(interval_s=3600.0)
        for i in range(5):
            index.query(x[i])
        # interval far in the future: boundary hook must not submit
        assert index.stats.snapshot()["residency_snapshots"] == 0
        index.close()

    def test_attach_live_can_enable_residency(self, tmp_path):
        index, x = _build_index(tmp_path)
        index.attach_live(window_s=0.05, residency_interval_s=0.0)
        index.query(x[0])
        index._residency_committer.drain()
        assert index.stats.snapshot()["residency_snapshots"] >= 1
        index.close()


# ---------------------------------------------------------------------------
# Fleet merge: router metrics_snapshot + merge_live_sections
# ---------------------------------------------------------------------------

class TestFleetMerge:
    def test_merge_live_sections_is_exact(self):
        ts1 = TimeSeries(window_s=1.0)
        ts2 = TimeSeries(window_s=1.0)
        all_durs = []
        for i, d in enumerate([1e-4, 3e-4, 9e-4, 2.7e-3]):
            ts1.on_event(X("io.read", 0.1 + i * 0.01, d, buckets=1))
            all_durs.append(d)
        for i, d in enumerate([5e-4, 1.5e-3]):
            ts2.on_event(X("io.read", 0.1 + i * 0.01, d, buckets=2))
            all_durs.append(d)
        ts1.poll(now=10.0)
        ts2.poll(now=10.0)
        merged = merge_live_sections([ts1.section(), ts2.section()])
        agg = merged["spans"]["io.read"]
        assert agg["count"] == 6
        assert agg["units"] == pytest.approx(8.0)
        assert agg["sum"] == pytest.approx(sum(all_durs))
        # exactness: percentiles re-derived from summed buckets, equal to
        # folding every sample into one histogram directly
        one = TimeSeries(window_s=1.0)
        for i, (d, u) in enumerate(zip(all_durs, [1, 1, 1, 1, 2, 2])):
            one.on_event(X("io.read", 0.1 + i * 0.01, d, buckets=u))
        one.poll(now=10.0)
        ref = one.span_aggregate("io.read")
        assert agg["buckets"] == ref["buckets"]
        assert agg["p50"] == ref["p50"] and agg["p99"] == ref["p99"]

    def test_merge_handles_zero_traffic_and_alerts(self):
        ts = TimeSeries(window_s=1.0)
        ts.on_event(X("q", 0.1, 1e-3))
        ts.poll(now=5.0)
        busy = ts.section()
        busy["slos"] = {"lat": {"state": "firing", "fast_burn": 9.0,
                                "slow_burn": 5.0}}
        busy["alerts"] = {"fired": 2, "resolved": 1,
                          "active": [{"slo": "lat"}]}
        idle = TimeSeries(window_s=1.0).section()   # zero-traffic shard
        idle["slos"] = {"lat": {"state": "ok", "fast_burn": 0.0,
                                "slow_burn": 0.0}}
        idle["alerts"] = {"fired": 0, "resolved": 0, "active": []}
        merged = merge_live_sections([idle, busy])
        assert merged["spans"]["q"]["count"] == 1
        assert merged["slos"]["lat"]["state"] == "firing"
        assert merged["slos"]["lat"]["fast_burn"] == 9.0
        assert merged["alerts"] == {"fired": 2, "resolved": 1,
                                    "active": [{"slo": "lat"}]}

    def test_router_metrics_snapshot_merges_shard_rollups(self, tmp_path):
        """Satellite acceptance: two live shards (one zero-traffic), the
        router's metrics_snapshot re-merges the live sections through the
        exact histogram-merge path."""
        i1, x1 = _build_index(tmp_path, n=2000, seed=3, sub="s0")
        i2, _ = _build_index(tmp_path, n=2000, seed=4, sub="s1")
        router = IndexRouter([i1, i2], epsilon=0.35, close_shards=True)
        router.attach_live(window_s=0.05, slos=())
        # traffic pinned to shard 0's space: shard 1 may see zero spans
        for i in range(15):
            i1.query(x1[i])
        time.sleep(0.06)
        merged = router.metrics_snapshot()["live"]
        assert merged["spans"]["query.execute"]["count"] >= 15
        s0 = i1.metrics_snapshot()["live"]
        s1 = i2.metrics_snapshot()["live"]
        direct = merge_live_sections([s0, s1])
        assert merged["spans"]["query.execute"]["buckets"] == \
            direct["spans"]["query.execute"]["buckets"]
        assert merged["events"] == s0["events"] + s1["events"]
        router.detach_live()
        assert i1.live is None and i2.live is None
        router.close()


# ---------------------------------------------------------------------------
# Dashboard
# ---------------------------------------------------------------------------

class TestDash:
    def _observer_with_traffic(self):
        # render() re-polls with the real clock, so the synthetic spans
        # must sit on the perf_counter timeline or they'd be evicted
        tr = enable_tracing()
        obs = LiveObserver(tr, window_s=0.05,
                           slos=(Slo.latency("lat", "q", 0.01,
                                             objective=0.5),))
        base = time.perf_counter()
        for k in range(6):
            tr.complete("q", base - 0.5 + 0.01 * k, 1e-3)
        tr.counter("io.depth", 3)
        time.sleep(0.06)        # counter's window must close too
        obs.timeseries.poll()
        return obs

    def test_render_shows_spans_slos_counters(self):
        obs = self._observer_with_traffic()
        text = dash.render(obs)
        assert "q" in text and "p95" in text
        assert "lat" in text and "OK" in text
        assert "io.depth" in text
        obs.close()

    def test_render_rejects_bare_objects(self):
        with pytest.raises(TypeError, match="attach_live"):
            dash.render(object())

    def test_watch_bounded_iterations(self):
        obs = self._observer_with_traffic()
        out = io.StringIO()
        dash.watch(obs, interval_s=0.01, iterations=2, out=out,
                   clear=False)
        assert out.getvalue().count("DiskJoin live") == 2
        obs.close()

    def test_render_index_and_router_targets(self, tmp_path):
        index, x = _build_index(tmp_path)
        index.attach_live(window_s=0.05)
        index.query(x[0])
        time.sleep(0.06)
        assert "query.execute" in dash.render(index)
        index.detach_live()
        index.close()


# ---------------------------------------------------------------------------
# Perf-regression comparator (benchmarks/regress.py)
# ---------------------------------------------------------------------------

class TestRegress:
    @pytest.fixture()
    def regress(self):
        from benchmarks import regress
        return regress

    def test_classify_directions(self, regress):
        assert regress.classify("overlap_efficiency") == "higher"
        assert regress.classify("live_overhead_frac") == "lower"
        assert regress.classify("ckpt_overhead") == "lower"
        assert regress.classify("some_novel_stat") == "unknown"

    def test_fraction_band_absolute(self, regress):
        r = regress.compare_stat("hidden_fraction", 0.9, 0.7)
        assert r["verdict"] == "regression"
        assert regress.compare_stat("hidden_fraction", 0.9,
                                    0.85)["verdict"] == "ok"
        assert regress.compare_stat("overhead_frac", 0.01,
                                    0.3)["verdict"] == "regression"

    def test_multiplicative_band(self, regress):
        assert regress.compare_stat("request_latency_us", 100.0,
                                    150.0)["verdict"] == "ok"
        assert regress.compare_stat("request_latency_us", 100.0,
                                    500.0)["verdict"] == "regression"
        assert regress.compare_stat("reads_saved", 100.0,
                                    500.0)["verdict"] == "improvement"

    def test_unknown_stats_report_only(self, regress):
        assert regress.compare_stat("novel", 1.0, 99.0)["verdict"] == \
            "info"

    def test_compare_records_status_and_wall(self, regress):
        base = {"figure": "f", "status": "ok", "wall_s": 10.0,
                "trace_stats": {"goodput": 0.95}}
        fresh = {"figure": "f", "status": "error", "wall_s": 50.0,
                 "trace_stats": {"goodput": 0.4}}
        d = regress.compare_records(base, fresh)
        names = {r["name"] for r in d["regressions"]}
        assert names == {"status", "wall_s", "goodput"}

    def test_compare_dirs_and_check_exit(self, regress, tmp_path):
        bdir, fdir = tmp_path / "base", tmp_path / "fresh"
        bdir.mkdir(), fdir.mkdir()
        rec = {"figure": "figX", "status": "ok", "wall_s": 1.0,
               "trace_stats": {"goodput": 0.9, "novel": 1.0}}
        (bdir / "BENCH_figX.json").write_text(json.dumps(rec))
        good = dict(rec, wall_s=1.2)
        (fdir / "BENCH_figX.json").write_text(json.dumps(good))
        diff = regress.compare_dirs(str(fdir), str(bdir))
        assert diff["num_regressions"] == 0
        assert regress.main([str(fdir), "--baselines", str(bdir),
                             "--check"]) == 0
        bad = dict(rec, trace_stats={"goodput": 0.2, "novel": 5.0})
        (fdir / "BENCH_figX.json").write_text(json.dumps(bad))
        out = str(tmp_path / "diff.json")
        assert regress.main([str(fdir), "--baselines", str(bdir),
                             "--check", "--diff-out", out]) == 1
        saved = json.load(open(out))
        assert saved["num_regressions"] == 1
        assert "figX" in regress.render(saved)

    def test_committed_baselines_pass_against_themselves(self, regress):
        diff = regress.compare_dirs(regress.BASELINE_DIR,
                                    regress.BASELINE_DIR)
        assert diff["compared"], "no committed baselines found"
        assert diff["num_regressions"] == 0


# ---------------------------------------------------------------------------
# run.py record fields (perf-trajectory satellites)
# ---------------------------------------------------------------------------

class TestBenchRecord:
    def test_record_carries_provenance_fields(self, tmp_path):
        from benchmarks import run as bench_run
        path = bench_run._write_record(
            str(tmp_path), "figT", rows=[{"name": "r"}],
            stats={"goodput": 1.0}, elapsed=1.25, status="ok",
            fingerprint={"small": True})
        rec = json.load(open(path))
        assert rec["wall_s"] == 1.25
        assert isinstance(rec["seed"], int)
        assert rec["git_sha"] is None or len(rec["git_sha"]) == 40
        assert rec["timestamp"].startswith("20")
        assert rec["figure"] == "figT" and rec["status"] == "ok"

    def test_committed_baselines_carry_the_fields(self, regress=None):
        from benchmarks.regress import BASELINE_DIR, load_records
        recs = load_records(BASELINE_DIR)
        assert recs, "benchmarks/baselines is empty"
        for rec in recs.values():
            assert rec["wall_s"] > 0
            assert "seed" in rec and "timestamp" in rec
