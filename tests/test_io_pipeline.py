"""repro.io subsystem: sync/prefetch result parity, buffer-pool pin/unpin
invariants, prefetcher ordering + backpressure, thread-safe IOStats."""
import os
import threading

import numpy as np
import pytest


def _pair_keys(pairs):
    return set(map(tuple, np.asarray(pairs).tolist()))


# ---------------------------------------------------------------------------
# end-to-end parity: the prefetch pipeline must change WHEN reads happen,
# never WHICH pairs come out
# ---------------------------------------------------------------------------
class TestParity:
    @pytest.mark.parametrize("lookahead,pool", [(4, None), (16, None),
                                                (32, 6)])
    def test_self_join_identical_pairs(self, small_dataset, tmp_store,
                                       lookahead, pool):
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        cfg = JoinConfig(epsilon=eps, pad_align=64, num_buckets=24,
                         memory_budget_bytes=1 << 20,
                         io_lookahead=lookahead, io_pool_slabs=pool)
        r_sync = similarity_self_join(tmp_store(x), cfg, io_mode="sync")
        r_pre = similarity_self_join(tmp_store(x[:, :]), cfg,
                                     io_mode="prefetch")
        assert _pair_keys(r_sync.pairs) == _pair_keys(r_pre.pairs)
        assert r_pre.bucket_loads == r_sync.bucket_loads  # same schedule
        pipe = r_pre.io_stats["pipeline"]
        assert pipe["loads"] == r_pre.bucket_loads
        assert pipe["max_queue_depth"] >= 1

    def test_cross_join_identical_pairs(self, tmp_path):
        from repro.core import JoinConfig
        from repro.core.join import similarity_cross_join
        from repro.data import clustered_vectors
        from repro.store.vector_store import FlatVectorStore

        rng = np.random.default_rng(3)
        x = clustered_vectors(2500, 32, seed=5)
        y = (x[:1500] + rng.normal(scale=0.05, size=(1500, 32))
             ).astype(np.float32)

        def mk(a, name):
            return FlatVectorStore.from_array(str(tmp_path / name), a)

        cfg = JoinConfig(epsilon=0.3, pad_align=64, num_buckets=16,
                         memory_budget_bytes=1 << 20, io_lookahead=4)
        r_sync = similarity_cross_join(mk(x, "x1"), mk(y, "y1"), cfg,
                                       io_mode="sync")
        r_pre = similarity_cross_join(mk(x, "x2"), mk(y, "y2"), cfg,
                                      io_mode="prefetch")
        assert r_sync.pairs.shape[0] > 0  # nontrivial workload
        assert _pair_keys(r_sync.pairs) == _pair_keys(r_pre.pairs)
        assert "pipeline" in r_pre.io_stats

    def test_config_io_mode_knob(self, small_dataset, tmp_store):
        """io_mode can come from JoinConfig itself (no override arg)."""
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        base = dict(epsilon=eps, pad_align=64, num_buckets=16,
                    memory_budget_bytes=1 << 20)
        r_sync = similarity_self_join(
            tmp_store(x), JoinConfig(io_mode="sync", **base))
        r_pre = similarity_self_join(
            tmp_store(x[:, :]), JoinConfig(io_mode="prefetch", **base))
        assert _pair_keys(r_sync.pairs) == _pair_keys(r_pre.pairs)

    def test_attribute_mask_prefetch_parity(self, small_dataset, tmp_store):
        """Prefetch id slabs are capacity-padded; the attribute bitmap must
        index only live rows (regression: broadcast error on first flush)."""
        from repro.core import JoinConfig
        from repro.core.join import similarity_self_join

        x, eps = small_dataset
        mask = np.arange(x.shape[0]) % 3 != 0
        cfg = JoinConfig(epsilon=eps, pad_align=64, num_buckets=16,
                         memory_budget_bytes=1 << 20)
        r_sync = similarity_self_join(tmp_store(x), cfg,
                                      attribute_mask=mask, io_mode="sync")
        r_pre = similarity_self_join(tmp_store(x[:, :]), cfg,
                                     attribute_mask=mask,
                                     io_mode="prefetch")
        assert r_sync.pairs.shape[0] > 0
        assert mask[r_pre.pairs].all()  # no filtered id slips through
        assert _pair_keys(r_sync.pairs) == _pair_keys(r_pre.pairs)

    def test_invalid_io_mode_rejected(self):
        from repro.core import JoinConfig
        with pytest.raises(ValueError, match="io_mode"):
            JoinConfig(epsilon=0.1, io_mode="mmap")


# ---------------------------------------------------------------------------
# buffer pool invariants
# ---------------------------------------------------------------------------
class TestBufferPool:
    def test_pin_unpin_refcounting(self):
        from repro.io import BufferPool

        pool = BufferPool(2, capacity_rows=8, dim=4)
        s = pool.acquire()
        assert pool.refcount(s) == 1
        pool.pin(s)
        assert pool.refcount(s) == 2
        pool.unpin(s)          # still held by the residency pin
        assert pool.in_use == 1
        pool.unpin(s)          # now free
        assert pool.in_use == 0

    def test_pin_on_free_slab_raises(self):
        from repro.io import BufferPool

        pool = BufferPool(1, capacity_rows=8, dim=4)
        s = pool.acquire()
        pool.unpin(s)
        with pytest.raises(RuntimeError, match="pin on free"):
            pool.pin(s)
        with pytest.raises(RuntimeError, match="under-run"):
            pool.unpin(s)

    def test_pinned_slab_not_reused_until_released(self):
        """Eviction (one unpin) must not recycle a slab a pending verify
        batch still pins — the core safety property under eviction."""
        from repro.io import BufferPool

        pool = BufferPool(1, capacity_rows=4, dim=2)
        s = pool.acquire()
        pool.pin(s)              # verify-batch reference
        pool.vecs(s)[:] = 7.0
        pool.unpin(s)            # "evict": drop the residency pin

        got = []

        def taker():
            got.append(pool.acquire(timeout=5))

        t = threading.Thread(target=taker)
        t.start()
        t.join(timeout=0.2)
        assert t.is_alive(), "slab was recycled while still pinned"
        assert float(pool.vecs(s)[0, 0]) == 7.0
        pool.unpin(s)            # flush: drop the batch pin
        t.join(timeout=5)
        assert got == [s]

    def test_acquire_blocks_until_free(self):
        from repro.io import BufferPool

        pool = BufferPool(1, capacity_rows=4, dim=2)
        s = pool.acquire()
        with pytest.raises(TimeoutError):
            pool.acquire(timeout=0.05)
        pool.unpin(s)
        assert pool.acquire(timeout=1) == s
        assert pool.blocked_acquires >= 1


# ---------------------------------------------------------------------------
# prefetcher: ordering, lookahead bound, backpressure
# ---------------------------------------------------------------------------
def _bucketed_store(tmp_path, num_buckets=12, rows=40, dim=8, seed=0):
    from repro.store.vector_store import BucketedVectorStore

    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, rows, size=num_buckets)
    centers = rng.normal(size=(num_buckets, dim)).astype(np.float32)
    radii = np.ones(num_buckets, np.float32)
    w = BucketedVectorStore.create(str(tmp_path / "bk"), dim, np.float32,
                                   sizes, centers, radii)
    vid = 0
    for b, n in enumerate(sizes):
        for _ in range(int(n)):
            w.append(b, rng.normal(size=dim).astype(np.float32), vid)
            vid += 1
    return w.finalize(), sizes


class TestPrefetcher:
    def test_delivers_schedule_order_with_content(self, tmp_path):
        from repro.io import BufferPool, SchedulePrefetcher

        store, sizes = _bucketed_store(tmp_path)
        cap = int(sizes.max())
        # miss-only schedule visiting every bucket twice, interleaved hits
        order = list(range(12)) + list(range(11, -1, -1))
        actions = [(b, False, None) for b in order]
        pool = BufferPool(4, cap, store.dim)
        pf = SchedulePrefetcher(store, actions, pool, lookahead=3,
                                num_threads=3)
        try:
            for b in order:
                bucket, slot, n = pf.pop_next()
                assert bucket == b
                assert n == int(sizes[b])
                ref_vecs, ref_ids = store.read_bucket(b)
                np.testing.assert_array_equal(pool.vecs(slot)[:n], ref_vecs)
                np.testing.assert_array_equal(pool.ids(slot)[:n], ref_ids)
                pool.unpin(slot)
        finally:
            pf.close()

    def test_backpressure_lookahead_exceeds_pool(self, tmp_path):
        """lookahead >> pool: the issue thread must block on the pool (not
        crash, not drop loads) and drain correctly as slabs free up."""
        from repro.io import BufferPool, SchedulePrefetcher

        store, sizes = _bucketed_store(tmp_path)
        cap = int(sizes.max())
        order = list(range(12)) * 3
        actions = [(b, False, None) for b in order]
        pool = BufferPool(2, cap, store.dim)   # tiny pool
        pf = SchedulePrefetcher(store, actions, pool, lookahead=64,
                                num_threads=2)
        try:
            import time
            time.sleep(0.05)  # let the issue thread hit the pool limit
            assert pool.in_use <= 2
            for b in order:
                bucket, slot, n = pf.pop_next()
                assert bucket == b
                pool.unpin(slot)
            assert pool.blocked_acquires > 0  # backpressure engaged
        finally:
            pf.close()

    def test_lookahead_bounds_queue_depth(self, tmp_path):
        from repro.io import BufferPool, PipelineStats, SchedulePrefetcher

        store, sizes = _bucketed_store(tmp_path)
        cap = int(sizes.max())
        order = list(range(12)) * 2
        actions = [(b, False, None) for b in order]
        stats = PipelineStats()
        pool = BufferPool(32, cap, store.dim)  # pool never the limit
        pf = SchedulePrefetcher(store, actions, pool, lookahead=3,
                                num_threads=2, stats=stats)
        try:
            for _ in order:
                _, slot, _ = pf.pop_next()
                pool.unpin(slot)
        finally:
            pf.close()
        assert 1 <= stats.max_queue_depth <= 3


# ---------------------------------------------------------------------------
# IOStats thread safety + batched accounting
# ---------------------------------------------------------------------------
class TestIOStats:
    def test_record_reads_batched_equivalence(self):
        from repro.store.io_stats import IOStats

        a, b = IOStats(), IOStats()
        for _ in range(100):
            a.record_read(100)
        b.record_reads(100, 100)
        assert a.snapshot() == b.snapshot()

    def test_concurrent_accounting_is_exact(self):
        from repro.store.io_stats import IOStats

        stats = IOStats()

        def worker():
            for _ in range(2000):
                stats.record_read(10)

        threads = [threading.Thread(target=worker) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert stats.read_ops == 16000
        assert stats.bytes_read_useful == 160000

    def test_read_rows_uses_batched_accounting(self, tmp_path):
        from repro.store.vector_store import FlatVectorStore

        x = np.arange(200, dtype=np.float32).reshape(50, 4)
        store = FlatVectorStore.from_array(str(tmp_path / "f.bin"), x)
        before = store.stats.read_ops
        out = store.read_rows([1, 7, 3])
        np.testing.assert_array_equal(out, x[[1, 7, 3]])
        assert store.stats.read_ops - before == 3  # one op per row, batched


def test_read_bucket_into_matches_read_bucket(tmp_path):
    from repro.store.vector_store import BucketedVectorStore  # noqa: F401

    store, sizes = _bucketed_store(tmp_path)
    cap = int(sizes.max()) + 5
    vecs = np.empty((cap, store.dim), np.float32)
    ids = np.empty(cap, np.int64)
    for b in range(len(sizes)):
        n = store.read_bucket_into(b, vecs, ids, pad_value=1e15)
        rv, ri = store.read_bucket(b)
        assert n == rv.shape[0]
        np.testing.assert_array_equal(vecs[:n], rv)
        np.testing.assert_array_equal(ids[:n], ri)
        assert (vecs[n:] == 1e15).all()
        assert (ids[n:] == -1).all()
