"""Wave-batched serving subsystem: QueryScheduler probe-sharing, deadline
drops, admission control, IndexRouter scatter/gather parity, deterministic
result ordering, query input validation, and scheduler waves racing batch
joins on one session pool."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import DiskJoinIndex, JoinConfig
from repro.data import clustered_vectors
from repro.serve import (DeadlineExceeded, IndexRouter, QueryScheduler,
                         SchedulerClosed, SchedulerQueueFull,
                         VectorQueryService)
from repro.store.vector_store import FlatVectorStore

EPS = 0.35


@pytest.fixture(scope="module")
def data():
    return clustered_vectors(2500, 24, seed=9)


@pytest.fixture()
def flat_store(tmp_path):
    def make(x, name="x.bin"):
        return FlatVectorStore.from_array(str(tmp_path / name), x)
    return make


def _cfg(**kw):
    base = dict(epsilon=EPS, recall_target=0.9, pad_align=64,
                num_buckets=20, memory_budget_bytes=1 << 20)
    base.update(kw)
    return JoinConfig(**base)


def _build(flat_store, tmp_path, x, name="idx", **kw):
    return DiskJoinIndex.build(flat_store(x, f"{name}.bin"), _cfg(**kw),
                               str(tmp_path / name))


def _truth(x, q, eps=EPS):
    return np.linalg.norm(x - q[None, :], axis=1) <= eps


# ---------------------------------------------------------------------------
# plan/execute split on the index
# ---------------------------------------------------------------------------
class TestPlanExecuteSplit:
    def test_execute_planned_probes_matches_query_batch(self, data,
                                                        flat_store,
                                                        tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x)
        Q = x[:6] + 0.01
        plan = index.plan_probes(Q)
        assert len(plan) == 6 and all(p.dtype == np.int64 for p in plan)
        split = index.execute_probes(Q, plan)
        fused = index.query_batch(Q)
        for (i1, d1), (i2, d2) in zip(split, fused):
            assert set(i1.tolist()) == set(i2.tolist())
        index.close()

    def test_plan_is_pure_metadata_no_reads(self, data, flat_store,
                                            tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x)
        before = index.io_snapshot()["read_ops"]
        index.plan_probes(x[:4])
        assert index.io_snapshot()["read_ops"] == before
        index.close()

    def test_mismatched_plan_rejected(self, data, flat_store, tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x)
        plan = index.plan_probes(x[:3])
        with pytest.raises(ValueError, match="probe plan"):
            index.execute_probes(x[:5], plan)
        index.close()


# ---------------------------------------------------------------------------
# query input validation (satellite)
# ---------------------------------------------------------------------------
class TestQueryValidation:
    @pytest.fixture()
    def index(self, data, flat_store, tmp_path):
        ix = _build(flat_store, tmp_path, data)
        yield ix
        ix.close()

    def test_wrong_dim_rejected(self, index):
        with pytest.raises(ValueError, match="incompatible"):
            index.query(np.zeros(7, np.float32))
        with pytest.raises(ValueError, match="incompatible"):
            index.query_batch(np.zeros((2, 7), np.float32))

    def test_nan_inf_rejected(self, index, data):
        q = data[0].copy()
        q[3] = np.nan
        with pytest.raises(ValueError, match="NaN/Inf"):
            index.query(q)
        q[3] = np.inf
        with pytest.raises(ValueError, match="NaN/Inf"):
            index.query_batch(q[None, :])

    def test_scheduler_submit_validates_eagerly(self, index, data):
        sched = QueryScheduler(index)
        bad = data[0].copy()
        bad[0] = np.nan
        with pytest.raises(ValueError, match="NaN/Inf"):
            sched.submit(bad)
        with pytest.raises(ValueError, match="build-time"):
            sched.submit(data[0], num_buckets=5)
        with pytest.raises(TypeError, match="unknown"):
            sched.submit(data[0], bogus=1)
        with pytest.raises(ValueError, match="one query vector"):
            sched.submit(data[:2])
        with pytest.raises(ValueError, match="k must be"):
            sched.submit(data[0], k=-1)
        sched.close()

    def test_cancelled_future_does_not_poison_wave(self, index, data):
        """A client cancel() on one pending request must not fail its
        wave mates or skip the wave counters."""
        sched = QueryScheduler(index, wave_size=4, max_wait_s=0.2)
        f1 = sched.submit(data[0])
        f2 = sched.submit(data[1])
        assert f2.cancel()
        f3 = sched.submit(data[2])
        assert len(f1.result(timeout=30)[0]) >= 1
        assert len(f3.result(timeout=30)[0]) >= 1
        assert f2.cancelled()
        assert sched.snapshot()["waves"] >= 1
        sched.close()


# ---------------------------------------------------------------------------
# wave scheduler
# ---------------------------------------------------------------------------
class TestQueryScheduler:
    def test_wave_sharing_parity_and_counters(self, data, flat_store,
                                              tmp_path):
        """64 concurrent overlapping queries: identical results to the
        per-request path, measurably fewer reads (the acceptance
        criterion's reads_saved_by_sharing > 0)."""
        x = data
        index = _build(flat_store, tmp_path, x)
        rng = np.random.default_rng(0)
        qs = x[rng.choice(x.shape[0], 64)] + 0.001
        with QueryScheduler(index, wave_size=32, max_wait_s=0.05) as sched:
            futs = [sched.submit(q) for q in qs]
            res = [f.result(timeout=60) for f in futs]
            snap = sched.snapshot()
        assert snap["completed"] == 64
        assert snap["waves"] >= 1
        assert snap["pipeline"]["waves"] == snap["waves"]
        # overlapping probes were merged: strictly fewer reads than refs
        assert snap["pipeline"]["reads_saved_by_sharing"] > 0
        assert snap["pipeline"]["shared_probe_reads"] > 0
        for q, (ids, dists) in zip(qs, res):
            want = set(np.flatnonzero(_truth(x, q)).tolist())
            got = set(ids.tolist())
            assert got <= want
            np.testing.assert_allclose(
                dists, np.linalg.norm(x[ids] - q[None, :], axis=1),
                atol=1e-4)
            assert np.all(np.diff(dists) >= 0)
        assert all(f.latency_s is not None and f.latency_s > 0
                   for f in futs)
        index.close()

    def test_mixed_epsilon_requests_group_within_wave(self, data,
                                                      flat_store,
                                                      tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x)
        with QueryScheduler(index, wave_size=16, max_wait_s=0.05) as sched:
            f1 = sched.submit(x[5], epsilon=EPS)
            f2 = sched.submit(x[5], epsilon=EPS * 0.5)
            wide = set(f1.result(timeout=30)[0].tolist())
            narrow = set(f2.result(timeout=30)[0].tolist())
        assert narrow <= wide
        assert 5 in narrow                       # the query itself
        truth_narrow = set(np.flatnonzero(
            _truth(x, x[5], EPS * 0.5)).tolist())
        assert narrow <= truth_narrow            # exact distances
        index.close()

    def test_admission_control_bounded_queue(self, data, flat_store,
                                             tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x,
                       emulate_read_latency_s=0.02)
        sched = QueryScheduler(index, wave_size=1, max_wait_s=0.0,
                               max_queue=2)
        try:
            sched.submit(x[0])          # drain thread picks this up
            time.sleep(0.01)            # let it start its (slow) reads
            sched.submit(x[1])
            sched.submit(x[2])          # queue now at max_queue
            with pytest.raises(SchedulerQueueFull):
                sched.submit(x[3])
            assert sched.snapshot()["rejected"] == 1
        finally:
            sched.close()
        index.close()

    def test_deadline_expired_requests_drop_pre_read(self, data,
                                                     flat_store,
                                                     tmp_path):
        """Under an emulated-latency store, a request whose deadline
        passes while queued resolves as deadline_exceeded without
        touching the disk."""
        x = data
        index = _build(flat_store, tmp_path, x,
                       emulate_read_latency_s=0.02)
        sched = QueryScheduler(index, wave_size=8, max_wait_s=0.05)
        filler = sched.submit(x[0])             # keeps the wave open
        doomed = sched.submit(x[1], deadline_s=1e-4)
        reads_before = index.stats.query_reads
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=30)
        assert doomed.latency_s is not None
        filler.result(timeout=30)               # unaffected member
        sched.close()
        snap = sched.snapshot()
        assert snap["deadline_drops"] == 1
        assert snap["pipeline"]["deadline_drops"] == 1
        assert snap["completed"] == 1
        # the drop happened before any read for the doomed request: only
        # the filler's candidate buckets were read in that wave
        filler_buckets = len(index.plan_probes(x[:1])[0])
        assert index.stats.query_reads - reads_before <= filler_buckets
        index.close()

    def test_close_drains_pending_then_rejects(self, data, flat_store,
                                               tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x)
        sched = QueryScheduler(index, wave_size=4, max_wait_s=0.2)
        futs = [sched.submit(q) for q in x[:10]]
        sched.close()                   # drains, never abandons
        assert all(f.done() for f in futs)
        assert all(len(f.result()[0]) >= 1 for f in futs)  # self-match
        with pytest.raises(SchedulerClosed):
            sched.submit(x[0])
        index.close()

    def test_share_probes_off_reads_more(self, data, flat_store, tmp_path):
        """A/B: the same overlapping workload, shared vs per-request
        execution — sharing must issue strictly fewer pooled reads."""
        x = data
        rng = np.random.default_rng(3)
        qs = x[rng.choice(200, 48)] + 0.001   # clustered → heavy overlap
        reads = {}
        for share in (True, False):
            index = _build(flat_store, tmp_path, x,
                           name=f"idx_share_{share}")
            with QueryScheduler(index, wave_size=48, max_wait_s=0.2,
                                share_probes=share) as sched:
                futs = [sched.submit(q) for q in qs]
                for f in futs:
                    f.result(timeout=60)
            snap = index.pipeline_snapshot()
            reads[share] = (snap["query_reads"]
                            + snap["query_fallback_reads"]
                            + snap["query_warm_hits"])
            index.close()
        assert reads[True] < reads[False]


# ---------------------------------------------------------------------------
# deterministic result ordering (satellite)
# ---------------------------------------------------------------------------
class TestDeterministicOrdering:
    def test_ties_ordered_by_id_across_io_modes_and_striping(self,
                                                             tmp_path):
        """Duplicate vectors force exact distance ties; the full (ids,
        dists) sequence must be identical across io_mode × striping."""
        base = clustered_vectors(1200, 16, seed=4)
        x = np.concatenate([base, base[:200]])  # ids 1200.. dup ids 0..199
        q = base[7] + 0.004
        seqs = {}
        for name, kw in (
                ("sync_1dev", dict()),
                ("prefetch_1dev", dict(io_mode="prefetch")),
                ("sync_3dev", dict(io_devices=3)),
                ("prefetch_3dev_coalesce", dict(io_mode="prefetch",
                                                io_devices=3,
                                                io_coalesce=True,
                                                io_batch_reads=True))):
            store = FlatVectorStore.from_array(
                str(tmp_path / f"{name}.bin"), x)
            index = DiskJoinIndex.build(
                store, _cfg(num_buckets=16, **kw),
                str(tmp_path / f"ix_{name}"))
            svc = VectorQueryService(index)
            ids, dists = svc.query(q)
            seqs[name] = (ids.tolist(), np.round(dists, 5).tolist())
            # ties resolve by ascending id
            for i in range(len(ids) - 1):
                if dists[i] == dists[i + 1]:
                    assert ids[i] < ids[i + 1]
            index.close()
        ref = seqs["sync_1dev"]
        assert ref[0], "query must have matches"
        for name, seq in seqs.items():
            assert seq == ref, f"{name} ordering diverged"

    def test_duplicate_rows_tie_break(self, tmp_path):
        x = np.zeros((40, 8), np.float32)
        x[::2] = 1.0    # two point masses, 20 exact duplicates each
        store = FlatVectorStore.from_array(str(tmp_path / "t.bin"), x)
        index = DiskJoinIndex.build(
            store, _cfg(epsilon=0.5, num_buckets=2, prune=False),
            str(tmp_path / "ix_t"))
        svc = VectorQueryService(index)
        ids, dists = svc.query(np.zeros(8, np.float32))
        assert ids.tolist() == list(range(1, 40, 2))  # all ties: id order
        assert np.all(dists == dists[0])
        index.close()


# ---------------------------------------------------------------------------
# latency accounting (satellite)
# ---------------------------------------------------------------------------
class TestLatencyAccounting:
    def test_direct_batch_members_record_full_wall_time(self, data,
                                                        flat_store,
                                                        tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x)
        svc = VectorQueryService(index)
        svc.query_batch(x[:8] + 0.01)
        snap = svc.snapshot()
        assert snap["requests"] == 8
        # every member records the batch wall, NOT wall/8: p95 == p50
        assert snap["latency_p95_ms"] == pytest.approx(
            snap["latency_p50_ms"])
        assert snap["wave"]["count"] == 1
        assert snap["wave"]["size_mean"] == 8
        assert snap["wave"]["service_p95_ms"] == pytest.approx(
            snap["latency_p50_ms"])
        index.close()

    def test_scheduled_service_records_true_per_request_latency(
            self, data, flat_store, tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x)
        svc = VectorQueryService(index, scheduler=True)
        svc.query(x[3])
        svc.query_batch(x[:4] + 0.01)
        snap = svc.snapshot()
        assert snap["requests"] == 5
        assert snap["scheduler"]["completed"] == 5
        assert snap["latency_p95_ms"] > 0
        assert snap["wave"]["count"] >= 1
        svc.close()
        index.close()


# ---------------------------------------------------------------------------
# scheduler racing a batch join on one session pool (satellite)
# ---------------------------------------------------------------------------
class TestConcurrentServing:
    def test_scheduler_waves_race_self_join_no_deadlock(self, data,
                                                        flat_store,
                                                        tmp_path):
        x = data
        index = _build(flat_store, tmp_path, x, io_mode="prefetch",
                       emulate_read_latency_s=2e-4)
        ref = index.self_join()
        out = {}

        def joiner():
            out["res"] = index.self_join()

        with QueryScheduler(index, wave_size=8, max_wait_s=0.002) as sched:
            t = threading.Thread(target=joiner)
            t.start()
            results = []
            while t.is_alive():
                q = x[11]
                results.append((q, sched.query(q, timeout=60)))
            t.join(timeout=60)
            assert not t.is_alive()
        assert len(results) > 0
        # join result unchanged by the racing waves
        ref_keys = set(map(tuple, ref.pairs.tolist()))
        got_keys = set(map(tuple, out["res"].pairs.tolist()))
        assert got_keys == ref_keys
        expected = set(np.flatnonzero(_truth(x, x[11])).tolist())
        for q, (ids, _) in results:
            assert set(ids.tolist()) <= expected
        index.close()


# ---------------------------------------------------------------------------
# multi-index router
# ---------------------------------------------------------------------------
class TestIndexRouter:
    def _build_shards(self, x, tmp_path, n_shards=4, **kw):
        shards = []
        bounds = np.linspace(0, x.shape[0], n_shards + 1).astype(int)
        for si in range(n_shards):
            part = x[bounds[si]:bounds[si + 1]]
            store = FlatVectorStore.from_array(
                str(tmp_path / f"shard{si}.bin"), part)
            cfg = _cfg(num_buckets=8, **kw)
            shards.append(DiskJoinIndex.build(
                store, cfg, str(tmp_path / f"sh{si}")))
        return shards

    def test_four_shards_exactly_match_unsharded(self, data, flat_store,
                                                 tmp_path):
        """Acceptance: router results over 4 shards == the unsharded
        index's (id, distance) sets. prune=False + full candidate fan-out
        makes both paths exact, so equality is strict, not statistical."""
        x = data
        exact = dict(prune=False, max_candidates=64)
        index = _build(flat_store, tmp_path, x, **exact)
        shards = self._build_shards(x, tmp_path, 4, **exact)
        router = IndexRouter(shards, scheduler=dict(max_wait_s=0.005))
        rng = np.random.default_rng(1)
        for qi in rng.choice(x.shape[0], 24, replace=False):
            q = x[qi] + 0.001
            r_ids, r_d = router.query(q)
            u_ids, u_d = index.query(q)          # unsorted by contract
            order = np.lexsort((u_ids, u_d))
            assert r_ids.tolist() == u_ids[order].tolist()
            np.testing.assert_allclose(r_d, u_d[order], atol=1e-5)
        router.close()
        index.close()
        for s in shards:
            s.close()

    def test_router_k_and_ordering(self, data, flat_store, tmp_path):
        x = data
        shards = self._build_shards(x, tmp_path, 2)
        router = IndexRouter(shards, scheduler=dict(max_wait_s=0.005))
        ids, dists = router.query(x[100] + 0.001, k=5)
        assert len(ids) <= 5
        assert np.all(np.diff(dists) >= 0)
        router.close()
        for s in shards:
            s.close()

    def test_router_validates_like_shards(self, data, flat_store,
                                          tmp_path):
        """A NaN query must raise, not silently route to zero shards."""
        x = data
        shards = self._build_shards(x, tmp_path, 2)
        router = IndexRouter(shards, scheduler=dict(max_wait_s=0.005))
        bad = x[0].copy()
        bad[0] = np.nan
        with pytest.raises(ValueError, match="NaN/Inf"):
            router.query(bad)
        with pytest.raises(ValueError, match="incompatible"):
            router.route(np.zeros(7, np.float32))
        with pytest.raises(ValueError, match="k must be"):
            router.submit(x[0], k=-2)
        router.close()
        for s in shards:
            s.close()

    def test_routing_skips_distant_shards(self, tmp_path):
        """Two well-separated point clouds in separate shards: a query
        deep inside one never scatters to the other."""
        rng = np.random.default_rng(5)
        a = rng.normal(0.0, 0.05, (400, 12)).astype(np.float32)
        b = (rng.normal(0.0, 0.05, (400, 12)) + 50.0).astype(np.float32)
        shards = []
        for name, part in (("a", a), ("b", b)):
            store = FlatVectorStore.from_array(
                str(tmp_path / f"{name}.bin"), part)
            shards.append(DiskJoinIndex.build(
                store, _cfg(epsilon=0.2, num_buckets=4),
                str(tmp_path / f"ix_{name}")))
        router = IndexRouter(shards, scheduler=dict(max_wait_s=0.005))
        assert router.route(a[0]) == [0]
        assert router.route(b[0]) == [1]
        ids, _ = router.query(a[0])
        assert len(ids) > 0 and int(ids.max()) < 400   # global id space
        ids_b, _ = router.query(b[0])
        assert len(ids_b) > 0 and int(ids_b.min()) >= 400
        snap = router.snapshot()
        assert snap["fanout_mean"] == 1.0
        router.close()
        for s in shards:
            s.close()

    def test_router_deadline_propagates(self, data, flat_store, tmp_path):
        x = data
        shards = self._build_shards(x, tmp_path, 2,
                                    emulate_read_latency_s=0.02)
        router = IndexRouter(shards,
                             scheduler=dict(wave_size=8, max_wait_s=0.05))
        filler = router.submit(x[0])
        doomed = router.submit(x[1], deadline_s=1e-4)
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=30)
        filler.result(timeout=30)
        router.close()
        for s in shards:
            s.close()
