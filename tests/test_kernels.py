"""Per-kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import pairwise_l2 as pk
from repro.kernels import bucket_assign as ak
from repro.kernels import flash_attention as fk


RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,n,d", [(128, 128, 128), (256, 128, 128),
                                   (200, 150, 96), (64, 300, 33),
                                   (1, 1, 8), (130, 2, 130)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_pairwise_l2_matches_oracle(m, n, d, dtype):
    a = RNG.normal(size=(m, d)).astype(dtype)
    b = RNG.normal(size=(n, d)).astype(dtype)
    eps = 1.5
    d2r, mr = ops.pairwise_l2_threshold(a, b, eps, use_pallas=False)
    d2p, mp = ops.pairwise_l2_threshold(a, b, eps, use_pallas=True)
    np.testing.assert_allclose(np.asarray(d2p), np.asarray(d2r),
                               rtol=1e-4, atol=1e-3)
    # threshold disagreement only possible within float tolerance of eps²
    dis = np.asarray(mr) != np.asarray(mp)
    if dis.any():
        assert np.abs(np.asarray(d2r)[dis] - eps * eps).max() < 1e-2


@pytest.mark.parametrize("m,b,d", [(128, 128, 64), (100, 37, 96),
                                   (256, 130, 128), (5, 3, 16)])
def test_bucket_assign_matches_oracle(m, b, d):
    x = RNG.normal(size=(m, d)).astype(np.float32)
    c = RNG.normal(size=(b, d)).astype(np.float32)
    dr, ir = ops.bucket_assign(x, c, use_pallas=False)
    dp, ip = ops.bucket_assign(x, c, use_pallas=True)
    assert np.array_equal(np.asarray(ir), np.asarray(ip))
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("b,h,sq,skv,hd", [
    (1, 2, 128, 128, 64), (2, 4, 256, 256, 64),
    (1, 1, 128, 384, 32), (2, 2, 384, 128, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(b, h, sq, skv, hd, causal):
    if causal and sq != skv:
        pytest.skip("kernel causal convention requires sq == skv "
                    "(ops falls back to ref for offset-causal)")
    q = RNG.normal(size=(b, h, sq, hd)).astype(np.float32)
    k = RNG.normal(size=(b, h, skv, hd)).astype(np.float32)
    v = RNG.normal(size=(b, h, skv, hd)).astype(np.float32)
    o_ref = ops.flash_attention(q, k, v, causal=causal, use_pallas=False)
    o_pal = ops.flash_attention(q, k, v, causal=causal, use_pallas=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)


def test_pairwise_raw_kernel_blockspec_alignment():
    """The raw kernel demands exact block divisibility — guard the contract.
    (Dims smaller than a block auto-shrink; non-divisible larger dims fail.)"""
    a = jnp.zeros((130, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(ValueError):
        pk.pairwise_l2_threshold(a, b, 1.0, interpret=True)


def test_flash_attention_kernel_raw_alignment():
    q = jnp.zeros((2, 130, 64), jnp.float32)
    with pytest.raises(ValueError):
        fk.flash_attention(q, q, q, interpret=True)


def test_bucket_assign_padding_never_wins():
    """Padded far-away centers must not be selected."""
    x = RNG.normal(size=(10, 8)).astype(np.float32)
    c = RNG.normal(size=(3, 8)).astype(np.float32)
    _, idx = ops.bucket_assign(x, c, use_pallas=True)
    assert int(np.asarray(idx).max()) < 3


def test_extract_pairs_upper_triangle():
    d2 = np.asarray([[0.0, 1.0], [1.0, 0.0]])
    mask = d2 <= 1.5
    ids = np.asarray([7, 9])
    pairs, dists = ops.extract_pairs(d2, mask, ids, ids, upper_triangle=True)
    assert pairs.tolist() == [[7, 9]]
    np.testing.assert_allclose(dists, [1.0])
