"""Integration tests: end-to-end joins vs brute-force ground truth —
validating the paper's own claims at laptop scale (DESIGN §7 targets)."""
import os

import numpy as np
import pytest

from repro.core import (JoinConfig, build_bucket_graph, bucketize,
                        candidate_pair_count, recall, similarity_cross_join,
                        similarity_self_join)
from repro.core.distributed import DistributedJoin
from repro.data import brute_force_pairs, clustered_vectors


def _join(x, eps, tmp_path, **kw):
    from repro.store.vector_store import FlatVectorStore
    store = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
    defaults = dict(epsilon=eps, recall_target=0.9,
                    memory_budget_bytes=max(1 << 20, x.nbytes // 10),
                    num_buckets=max(16, x.shape[0] // 300), pad_align=64)
    defaults.update(kw)
    cfg = JoinConfig(**defaults)
    return similarity_self_join(store, cfg, workdir=str(tmp_path)), store


class TestSelfJoin:
    def test_recall_meets_target(self, small_dataset, tmp_path):
        x, eps = small_dataset
        truth = brute_force_pairs(x, eps)
        res, _ = _join(x, eps, tmp_path)
        r = recall(res.pairs, truth)
        assert r >= 0.88, f"recall {r} < target-with-slack"

    def test_perfect_precision(self, small_dataset, tmp_path):
        """Approximate SSJ has perfect precision (paper §1)."""
        x, eps = small_dataset
        res, _ = _join(x, eps, tmp_path)
        d = np.linalg.norm(x[res.pairs[:, 0]] - x[res.pairs[:, 1]], axis=1)
        assert (d <= eps + 1e-4).all()

    def test_read_amplification_near_one(self, small_dataset, tmp_path):
        """Paper Fig. 16: DiskJoin amp ≈ 1.003."""
        x, eps = small_dataset
        res, _ = _join(x, eps, tmp_path)
        assert res.io_stats["read_amplification"] <= 1.10

    def test_higher_recall_target_more_candidates(self, small_dataset,
                                                  tmp_path):
        x, eps = small_dataset
        res_lo, _ = _join(x, eps, tmp_path / "lo" if False else tmp_path,
                          recall_target=0.8)
        res_hi, _ = _join(x, eps, tmp_path, recall_target=0.99)
        assert res_hi.num_candidate_pairs >= res_lo.num_candidate_pairs

    def test_pruning_reduces_candidates_and_respects_recall(self, tmp_path):
        """Paper Fig. 18 mechanism: Eq. 3 pruning removes candidates
        monotonically in the budget 1−λ, and measured recall stays ≥ λ.

        Pruning's bite needs heterogeneous bucket radii (dense cores +
        diffuse regions — real-embedding geometry); on well-separated
        tight clusters the Eq. 1 triangle prefilter already removes
        everything prunable (recorded in DESIGN §9)."""
        from repro.data import clustered_vectors, epsilon_for_avg_neighbors
        x = clustered_vectors(5000, 96, seed=5,
                              cluster_std_range=(0.03, 0.9),
                              intrinsic_dim=12, clusters=20)
        eps = epsilon_for_avg_neighbors(x, 20)
        truth = brute_force_pairs(x, eps)
        counts = {}
        for lam in (None, 0.9, 0.6):
            res, _ = _join(x, eps, tmp_path, prune=lam is not None,
                           recall_target=lam or 0.9,
                           num_buckets=100, max_candidates=99)
            counts[lam] = res.num_candidate_pairs
            if lam is not None:
                assert recall(res.pairs, truth) >= lam - 0.02
        assert counts[0.9] < counts[None]
        assert counts[0.6] < counts[0.9]

    def test_eviction_ablation_belady_ge_lru(self, small_dataset, tmp_path):
        """Paper Fig. 17: Belady ≥ LRU on cache hit rate."""
        x, eps = small_dataset
        res_b, _ = _join(x, eps, tmp_path, eviction_policy="belady",
                         memory_budget_bytes=x.nbytes // 20)
        res_l, _ = _join(x, eps, tmp_path, eviction_policy="lru",
                         memory_budget_bytes=x.nbytes // 20)
        assert res_b.cache_hit_rate >= res_l.cache_hit_rate - 1e-9

    def test_reorder_improves_hit_rate(self, small_dataset, tmp_path):
        x, eps = small_dataset
        res_r, _ = _join(x, eps, tmp_path, reorder=True,
                         memory_budget_bytes=x.nbytes // 20)
        res_n, _ = _join(x, eps, tmp_path, reorder=False,
                         memory_budget_bytes=x.nbytes // 20)
        assert res_r.cache_hit_rate >= res_n.cache_hit_rate - 0.02

    def test_results_independent_of_policy(self, small_dataset, tmp_path):
        """Cache policy/ordering affect I/O only, never the result set."""
        x, eps = small_dataset
        res_a, _ = _join(x, eps, tmp_path, eviction_policy="belady",
                         reorder=True)
        res_b, _ = _join(x, eps, tmp_path, eviction_policy="lru",
                         reorder=False)
        assert np.array_equal(res_a.pairs, res_b.pairs)


class TestCrossJoin:
    def _data(self):
        x = clustered_vectors(5000, 32, seed=2)
        y = clustered_vectors(3000, 32, seed=3, clusters=24)
        y[:1500] = x[:1500] + np.random.default_rng(0).normal(
            scale=0.02, size=(1500, 32)).astype(np.float32)
        eps = 0.35
        xf, yf = x.astype(np.float64), y.astype(np.float64)
        d2 = (np.sum(xf ** 2, 1)[:, None] - 2 * xf @ yf.T
              + np.sum(yf ** 2, 1)[None])
        rows, cols = np.nonzero(d2 <= eps * eps)
        truth = np.stack([rows, cols + x.shape[0]], 1).astype(np.int64)
        return x, y, eps, truth

    @pytest.mark.parametrize("reorder_larger", [True, False])
    def test_cross_join_recall(self, tmp_path, reorder_larger):
        from repro.store.vector_store import FlatVectorStore
        x, y, eps, truth = self._data()
        sx = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
        sy = FlatVectorStore.from_array(str(tmp_path / "y.bin"), y)
        cfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                         memory_budget_bytes=2 << 20, num_buckets=24)
        res = similarity_cross_join(sx, sy, cfg, workdir=str(tmp_path),
                                    reorder_larger=reorder_larger)
        assert recall(res.pairs, truth) >= 0.88
        # only cross pairs, tagged by offset
        isx = res.pairs < x.shape[0]
        assert (isx[:, 0] != isx[:, 1]).all()


class TestDistributedJoin:
    def test_matches_ground_truth(self, small_dataset, tmp_path):
        from repro.store.vector_store import FlatVectorStore
        x, eps = small_dataset
        store = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
        cfg = JoinConfig(epsilon=eps, recall_target=0.95, pad_align=64,
                         memory_budget_bytes=4 << 20, num_buckets=24)
        bs, meta, _ = bucketize(store, str(tmp_path / "bk"), cfg)
        graph = build_bucket_graph(meta, cfg)
        pairs, info = DistributedJoin(bs, meta, cfg).run(graph)
        truth = brute_force_pairs(x, eps)
        assert recall(pairs, truth) >= 0.9
        assert info["supersteps"] >= 1

    def test_matches_single_device_executor(self, small_dataset, tmp_path):
        """Distributed superstep execution = sequential executor results."""
        x, eps = small_dataset
        res, store = _join(x, eps, tmp_path, recall_target=0.95,
                           num_buckets=24, memory_budget_bytes=4 << 20)
        cfg = JoinConfig(epsilon=eps, recall_target=0.95, pad_align=64,
                         memory_budget_bytes=4 << 20, num_buckets=24)
        bs, meta, _ = bucketize(store, str(tmp_path / "bk2"), cfg)
        graph = build_bucket_graph(meta, cfg)
        pairs, _ = DistributedJoin(bs, meta, cfg).run(graph)
        assert np.array_equal(pairs, res.pairs)
