"""shard_map all-to-all MoE: numerical equivalence + differentiability
(8-device subprocess; EXPERIMENTS §Perf cell-2 endpoint)."""
import subprocess
import sys
import textwrap


def _run(code: str, timeout: int = 900) -> str:
    prelude = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "import sys\n"
        "sys.path.insert(0, 'src')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout,
        cwd=__file__.rsplit("/", 2)[0])
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_a2a_matches_reference_and_differentiates():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config, smoke_config
        from repro.models import moe as moe_mod
        from repro.models.moe_a2a import moe_ffn_a2a
        from repro.dist import sharding as shd

        cfg = smoke_config(get_config('olmoe-1b-7b'))
        # ample capacity: neither path drops tokens -> exact equality
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                              jnp.float32)
        y_ref, _ = moe_mod.moe_ffn(params, cfg, x)
        shd.set_mesh(mesh)
        with mesh:
            y, aux = jax.jit(lambda p, v: moe_ffn_a2a(p, cfg, v))(params, x)
            g = jax.jit(jax.grad(lambda p: jnp.sum(
                moe_ffn_a2a(p, cfg, x)[0] ** 2)))(params)
        shd.set_mesh(None)
        err = float(jnp.max(jnp.abs(y_ref - y)))
        assert err < 2e-4, err
        gn = sum(float(jnp.sum(jnp.abs(v)))
                 for v in jax.tree_util.tree_leaves(g))
        assert np.isfinite(gn) and gn > 0
        print('A2A-OK', err)
    """)
    assert "A2A-OK" in out


def test_a2a_shared_experts_and_deepseek_family():
    out = _run("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, smoke_config
        from repro.models import moe as moe_mod
        from repro.models.moe_a2a import moe_ffn_a2a
        from repro.dist import sharding as shd

        cfg = smoke_config(get_config('deepseek-moe-16b'))
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        params = moe_mod.init_moe(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model),
                              jnp.float32)
        y_ref, _ = moe_mod.moe_ffn(params, cfg, x)
        shd.set_mesh(mesh)
        with mesh:
            y, _ = jax.jit(lambda p, v: moe_ffn_a2a(p, cfg, v))(params, x)
        shd.set_mesh(None)
        err = float(jnp.max(jnp.abs(y_ref - y)))
        assert err < 2e-4, err
        print('A2A-SHARED-OK', err)
    """)
    assert "A2A-SHARED-OK" in out
