"""Fault tolerance (repro.ft): atomic commit primitives, join
checkpoint/restore byte-parity under kill injection (host and device
verify), transient read-error retry, serving residency snapshots and
resumable index builds."""
import json
import os
import sys
import threading

import numpy as np
import pytest

from repro.core import (DiskJoinIndex, JoinConfig, bucketize,
                        build_bucket_graph)
from repro.core.distributed import DistributedJoin
from repro.data import clustered_vectors
from repro.ft import (AsyncCommitter, FaultInjector, FlakyStore,
                      InjectedKill, JoinCheckpointer, PhaseLog,
                      atomic_commit_dir, atomic_write_json, fingerprint,
                      reap_tmp)
from repro.store.vector_store import FlatVectorStore


# ---------------------------------------------------------------------------
# atomic commit primitives (shared by train ckpt, join ckpt, phase log)
# ---------------------------------------------------------------------------
class TestAtomic:
    def test_commit_dir_is_atomic_and_tmp_free(self, tmp_path):
        d = str(tmp_path)

        def writer(tmp):
            with open(os.path.join(tmp, "a.txt"), "w") as f:
                f.write("hello")

        out = atomic_commit_dir(d, "thing", writer)
        assert os.path.basename(out) == "thing"
        assert open(os.path.join(out, "a.txt")).read() == "hello"
        assert not [n for n in os.listdir(d) if n.endswith(".tmp")]

    def test_failed_writer_leaves_no_committed_dir(self, tmp_path):
        d = str(tmp_path)

        def writer(tmp):
            raise RuntimeError("disk full")

        with pytest.raises(RuntimeError, match="disk full"):
            atomic_commit_dir(d, "thing", writer)
        assert not os.path.exists(os.path.join(d, "thing"))

    def test_reap_tmp_removes_torn_dirs_only(self, tmp_path):
        d = str(tmp_path)
        os.makedirs(os.path.join(d, "good"))
        os.makedirs(os.path.join(d, "torn.tmp"))
        with open(os.path.join(d, "torn.tmp", "x"), "w") as f:
            f.write("partial")
        reaped = reap_tmp(d)
        assert len(reaped) == 1
        assert not os.path.exists(os.path.join(d, "torn.tmp"))
        assert os.path.exists(os.path.join(d, "good"))

    def test_atomic_write_json_roundtrip(self, tmp_path):
        p = str(tmp_path / "m.json")
        atomic_write_json(p, {"k": [1, 2]})
        assert json.load(open(p)) == {"k": [1, 2]}
        atomic_write_json(p, {"k": 3})  # replace, not append
        assert json.load(open(p)) == {"k": 3}

    def test_fingerprint_stable_and_sensitive(self):
        a = fingerprint({"eps": 0.3, "n": 16})
        assert a == fingerprint({"n": 16, "eps": 0.3})  # key order
        assert a != fingerprint({"eps": 0.31, "n": 16})

    def test_async_committer_runs_and_surfaces_errors(self, tmp_path):
        box = []
        c = AsyncCommitter(name="t")
        c.submit(lambda: box.append(1))
        c.drain()
        assert box == [1]
        c.submit(lambda: (_ for _ in ()).throw(OSError("boom")))
        with pytest.raises(RuntimeError, match="async checkpoint failed"):
            c.drain()
        c.submit(lambda: box.append(2))  # committer recovered
        c.drain()
        assert box == [1, 2]
        c.close()

    def test_try_submit_never_blocks(self):
        gate = threading.Event()
        c = AsyncCommitter(name="t")
        c.submit(gate.wait)           # occupy the writer
        assert c.try_submit(lambda: None) in (True, False)
        # queue (maxsize 1) may already hold one; a second must be refused
        c.try_submit(lambda: None)
        assert c.try_submit(lambda: None) is False
        gate.set()
        c.close()


# ---------------------------------------------------------------------------
# join checkpoint/restore
# ---------------------------------------------------------------------------
def _dist_setup(tmp_path, **cfg_kw):
    x = clustered_vectors(3000, 32, seed=4)
    store = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
    # budget chosen so the planned join spans many supersteps (a kill
    # mid-run must land between commits, not after the only step)
    base = dict(epsilon=0.3, recall_target=0.95, pad_align=64,
                memory_budget_bytes=128 << 10, num_buckets=24)
    base.update(cfg_kw)
    cfg = JoinConfig(**base)
    bs, meta, _ = bucketize(store, str(tmp_path / "bk"), cfg)
    graph = build_bucket_graph(meta, cfg)
    return DistributedJoin(bs, meta, cfg), graph


class TestJoinCheckpointer:
    def test_checkpointed_run_matches_plain(self, tmp_path):
        dj, graph = _dist_setup(tmp_path)
        base_pairs, base_info = dj.run(graph)
        ck = JoinCheckpointer(str(tmp_path / "ck"))
        pairs, info = dj.run(graph, checkpointer=ck)
        assert np.array_equal(pairs, base_pairs)
        assert np.array_equal(info["dists"], base_info["dists"])
        assert info["ckpt"]["saves"] > 0

    @pytest.mark.parametrize("mode", ["host", "device"])
    def test_kill_and_resume_byte_parity(self, tmp_path, mode):
        dj, graph = _dist_setup(tmp_path, compute_mode=mode)
        base_pairs, base_info = dj.run(graph)
        assert base_info["supersteps"] > 3
        kill_at = max(1, int(base_info["supersteps"] * 0.6))

        ckdir = str(tmp_path / "ck")
        ck = JoinCheckpointer(ckdir)
        fi = FaultInjector(kill_at_superstep=kill_at)
        with pytest.raises(InjectedKill):
            dj.run(graph, checkpointer=ck, fault=fi)
        assert fi.kills == 1
        ck.finish()  # flush the async writer before reopening the dir

        ck2 = JoinCheckpointer(ckdir)
        pairs, info = dj.run(graph, checkpointer=ck2, resume_from=ckdir)
        assert info["resumed_at"] > 0
        assert info["restore_s"] >= 0.0
        # byte-identical output: pairs AND distances AND raw-stream
        # watermark (no row emitted twice across the kill boundary)
        assert np.array_equal(pairs, base_pairs)
        assert np.array_equal(info["dists"], base_info["dists"])
        assert info["watermark_rows"] == base_info["watermark_rows"]

    def test_resume_skips_committed_supersteps(self, tmp_path):
        dj, graph = _dist_setup(tmp_path)
        _, base_info = dj.run(graph)
        kill_at = max(1, int(base_info["supersteps"] * 0.6))
        ckdir = str(tmp_path / "ck")
        ck = JoinCheckpointer(ckdir)
        with pytest.raises(InjectedKill):
            dj.run(graph, checkpointer=ck,
                   fault=FaultInjector(kill_at_superstep=kill_at))
        ck.finish()
        _, info = dj.run(graph, resume_from=ckdir)
        # at least the committed prefix is skipped, and the cursor can
        # never pass the kill point (nothing beyond it was committed)
        assert 0 < info["resumed_at"] <= kill_at

    def test_restore_refuses_fingerprint_mismatch(self, tmp_path):
        dj, graph = _dist_setup(tmp_path)
        ckdir = str(tmp_path / "ck")
        dj.run(graph, checkpointer=JoinCheckpointer(ckdir))
        with pytest.raises(ValueError, match="fingerprint"):
            JoinCheckpointer.restore(ckdir, fingerprint="deadbeef")
        # and through the run() entrypoint with a different config
        dj2 = DistributedJoin(dj.store, dj.meta,
                              JoinConfig(epsilon=0.31, recall_target=0.95,
                                         pad_align=64,
                                         memory_budget_bytes=128 << 10,
                                         num_buckets=24))
        with pytest.raises(ValueError, match="refusing to resume"):
            dj2.run(graph, resume_from=ckdir)

    def test_torn_tmp_checkpoint_ignored_and_reaped(self, tmp_path):
        dj, graph = _dist_setup(tmp_path)
        ckdir = str(tmp_path / "ck")
        base_pairs, _ = dj.run(graph, checkpointer=JoinCheckpointer(ckdir))
        FaultInjector.tear_checkpoint(ckdir)
        assert any(n.endswith(".tmp") for n in os.listdir(ckdir))
        rs = JoinCheckpointer.restore(ckdir, fingerprint=dj.fingerprint())
        assert rs is not None
        assert not any(n.endswith(".tmp") for n in os.listdir(ckdir))
        pairs, _ = dj.run(graph, resume_from=ckdir)
        assert np.array_equal(pairs, base_pairs)

    def test_restore_empty_dir_returns_none(self, tmp_path):
        assert JoinCheckpointer.restore(str(tmp_path / "nope"),
                                        fingerprint="x") is None


# ---------------------------------------------------------------------------
# transient read-error retry
# ---------------------------------------------------------------------------
def _build_index(tmp_path, name="idx", **cfg_kw):
    x = clustered_vectors(2500, 24, seed=9)
    flat = FlatVectorStore.from_array(str(tmp_path / f"{name}.bin"), x)
    base = dict(epsilon=0.35, recall_target=0.9, pad_align=64,
                num_buckets=20, memory_budget_bytes=1 << 20)
    base.update(cfg_kw)
    return x, DiskJoinIndex.build(flat, JoinConfig(**base),
                                  str(tmp_path / name))


class TestRetry:
    @pytest.mark.parametrize("io_mode", ["sync", "prefetch"])
    def test_transient_errors_retried_and_counted(self, tmp_path, io_mode):
        x, idx = _build_index(tmp_path, name=f"r_{io_mode}",
                              io_mode=io_mode,
                              io_coalesce=(io_mode == "prefetch"))
        q = x[:16]
        expect = idx.query_batch(q, io_retries=2)
        idx.drop_warm_cache()
        idx.store = FlakyStore(idx.store, read_error_every=3)
        got = idx.query_batch(q, io_retries=2, io_retry_backoff_s=1e-4)
        snap = idx.pipeline_snapshot()
        assert snap["io_read_errors"] > 0
        assert snap["io_retries"] == snap["io_read_errors"]
        assert "io_retries" in idx.metrics_snapshot()["pipeline"]
        for (i1, d1), (i2, d2) in zip(expect, got):
            o1, o2 = np.argsort(i1), np.argsort(i2)
            assert np.array_equal(i1[o1], i2[o2])
            assert np.allclose(d1[o1], d2[o2])
        idx.close()

    def test_permanent_failure_still_raises(self, tmp_path):
        x, idx = _build_index(tmp_path, name="perm")
        idx.drop_warm_cache()
        idx.store = FlakyStore(idx.store, read_error_every=1)
        with pytest.raises(OSError, match="injected"):
            idx.query_batch(x[:4], io_retries=2, io_retry_backoff_s=1e-5)
        assert idx.pipeline_snapshot()["io_read_errors"] >= 3
        idx.close()

    def test_join_read_path_retries(self, tmp_path):
        x, idx = _build_index(tmp_path, name="jr")
        expect = idx.self_join()
        idx.store = FlakyStore(idx.store, read_error_every=4)
        got = idx.self_join(io_retries=3, io_retry_backoff_s=1e-4)
        assert np.array_equal(expect.pairs, got.pairs)
        assert idx.pipeline_snapshot()["io_retries"] > 0
        idx.close()


# ---------------------------------------------------------------------------
# serving residency snapshot / warm restart
# ---------------------------------------------------------------------------
class TestResidency:
    def test_snapshot_roundtrip_and_warm_restart(self, tmp_path):
        x, idx = _build_index(tmp_path, name="warm")
        q = x[:12]
        cold = idx.query_batch(q)
        warm = set(idx.warm_buckets())
        assert warm
        idx.close()  # persists residency.json
        snap_path = tmp_path / "warm" / "residency.json"
        assert snap_path.exists()
        assert set(json.load(open(snap_path))["buckets"]) == warm

        idx2 = DiskJoinIndex.open(str(tmp_path / "warm"), warm_start=True)
        assert idx2.pipeline_snapshot()["warm_prefaults"] > 0
        assert set(idx2.warm_buckets()) <= warm
        out = idx2.query_batch(q)  # first post-restart wave
        assert idx2.pipeline_snapshot()["query_warm_hits"] > 0
        for (i1, d1), (i2, d2) in zip(cold, out):
            o1, o2 = np.argsort(i1), np.argsort(i2)
            assert np.array_equal(i1[o1], i2[o2])
            assert np.allclose(d1[o1], d2[o2])
        idx2.close()

    def test_cold_open_without_snapshot_is_noop(self, tmp_path):
        x, idx = _build_index(tmp_path, name="cold")
        p = os.path.join(idx.workdir, "residency.json")
        if os.path.exists(p):
            os.unlink(p)
        idx.close()
        if os.path.exists(p):
            os.unlink(p)  # close() may have written an (empty) snapshot
        idx2 = DiskJoinIndex.open(str(tmp_path / "cold"), warm_start=True)
        assert idx2.pipeline_snapshot().get("warm_prefaults", 0) == 0
        assert idx2.warm_buckets() == []
        idx2.close()

    def test_pinned_slots_excluded_from_snapshot(self, tmp_path):
        x, idx = _build_index(tmp_path, name="pin")
        idx.query_batch(x[:12])
        warm = idx.warm_buckets()
        assert len(warm) >= 2
        pinned_b = warm[0]
        slot, _ = idx._warm[pinned_b]
        idx._pool.pin(slot)  # an in-flight verify holds this slab
        try:
            n = idx.save_residency_snapshot()
            snap = json.load(open(os.path.join(idx.workdir,
                                               "residency.json")))
            assert pinned_b not in snap["buckets"]
            assert n == len(warm) - 1
        finally:
            idx._pool.unpin(slot)
        idx.close()

    def test_snapshot_during_concurrent_join_is_safe(self, tmp_path):
        x, idx = _build_index(tmp_path, name="conc")
        idx.query_batch(x[:12])
        idx._begin_join()  # join running: warm slabs were dropped
        try:
            assert idx.save_residency_snapshot() == 0
        finally:
            idx._end_join()
        # warm set repopulates and the next snapshot sees it again
        idx.query_batch(x[:12])
        assert idx.save_residency_snapshot() > 0
        idx.close()


# ---------------------------------------------------------------------------
# resumable builds (phase log)
# ---------------------------------------------------------------------------
def _kill_write_scan(monkeypatch, n_kills=1):
    bz = sys.modules["repro.core.bucketize"]
    orig = bz.write_buckets
    calls = {"n": 0}

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] <= n_kills:
            raise InjectedKill("kill during write scan")
        return orig(*a, **k)

    monkeypatch.setattr(bz, "write_buckets", flaky)


class TestResumableBuild:
    CFG = dict(epsilon=0.35, recall_target=0.9, pad_align=64,
               num_buckets=20, memory_budget_bytes=1 << 20,
               io_coalesce=True, io_mode="prefetch")

    def test_killed_build_resumes_without_rescanning(self, tmp_path,
                                                     monkeypatch):
        x = clustered_vectors(2500, 24, seed=9)
        flat = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
        cfg = JoinConfig(**self.CFG)
        wd = str(tmp_path / "idx")
        _kill_write_scan(monkeypatch)
        with pytest.raises(InjectedKill):
            DiskJoinIndex.build(flat, cfg, wd)
        assert os.path.isdir(os.path.join(wd, "build_phases"))
        ops0 = flat.stats.snapshot()["read_ops"]
        idx = DiskJoinIndex.build(flat, cfg, wd)
        resumed_ops = flat.stats.snapshot()["read_ops"] - ops0
        # sample + assign scans were loaded from phase markers
        assert idx.build_timings["sample"] == 0.0
        assert idx.build_timings["assign"] == 0.0
        assert not os.path.isdir(os.path.join(wd, "build_phases"))

        idx2 = DiskJoinIndex.build(flat, cfg, str(tmp_path / "fresh"))
        fresh_ops = flat.stats.snapshot()["read_ops"] \
            - ops0 - resumed_ops
        assert resumed_ops < fresh_ops  # skipped scans saved real reads
        r1, r2 = idx.self_join(), idx2.self_join()
        assert np.array_equal(r1.pairs, r2.pairs)
        idx.close()
        idx2.close()

    def test_config_change_discards_stale_phases(self, tmp_path,
                                                 monkeypatch):
        x = clustered_vectors(2500, 24, seed=9)
        flat = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
        wd = str(tmp_path / "idx")
        _kill_write_scan(monkeypatch)
        with pytest.raises(InjectedKill):
            DiskJoinIndex.build(flat, JoinConfig(**self.CFG), wd)
        changed = dict(self.CFG, num_buckets=24)
        idx = DiskJoinIndex.build(flat, JoinConfig(**changed), wd)
        # stale markers were discarded: the scans actually re-ran
        assert idx.build_timings["sample"] > 0.0
        assert idx.num_buckets >= 24 - 4  # built under the NEW config
        idx.close()

    def test_phase_log_fingerprint_isolation(self, tmp_path):
        log = PhaseLog(str(tmp_path / "ph"), "fp-a")
        log.commit_arrays("sample", centers=np.ones((3, 2), np.float32))
        assert log.has("sample")
        # same fingerprint: a new handle still sees the phase
        assert PhaseLog(str(tmp_path / "ph"), "fp-a").has("sample")
        # different fingerprint: the committed phase is discarded
        assert not PhaseLog(str(tmp_path / "ph"), "fp-b").has("sample")
