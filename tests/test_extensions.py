"""Paper §3 extensions: attribute filtering; Fig. 14 fragmentation store."""
import os

import numpy as np

from repro.core import JoinConfig, recall, similarity_self_join
from repro.data import brute_force_pairs, clustered_vectors, \
    epsilon_for_avg_neighbors
from repro.store.vector_store import BucketedVectorStore, FlatVectorStore


def test_attribute_filtered_join(tmp_path):
    """Only pairs where both sides pass the predicate are returned —
    and recall over the *filtered* truth set still meets the target."""
    x = clustered_vectors(4000, 32, seed=5)
    eps = epsilon_for_avg_neighbors(x, 10)
    rng = np.random.default_rng(0)
    mask = rng.random(4000) < 0.5

    store = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
    cfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                     memory_budget_bytes=1 << 20,
                     num_buckets=40)
    res = similarity_self_join(store, cfg, workdir=str(tmp_path),
                               attribute_mask=mask)
    # every returned pair passes on both sides
    assert mask[res.pairs[:, 0]].all() and mask[res.pairs[:, 1]].all()
    truth = brute_force_pairs(x, eps)
    keep = mask[truth[:, 0]] & mask[truth[:, 1]]
    assert recall(res.pairs, truth[keep]) >= 0.88


def test_fragmentation_amplification_curve(tmp_path):
    """Fig. 14: amplification ≈1 for large extents, grows as extents
    shrink toward the 4 KB page."""
    from repro.core import bucketize, build_bucket_graph
    from repro.core.executor import JoinExecutor

    x = clustered_vectors(4000, 64, seed=5)
    eps = epsilon_for_avg_neighbors(x, 10)
    store = FlatVectorStore.from_array(str(tmp_path / "x.bin"), x)
    cfg = JoinConfig(epsilon=eps, pad_align=64,
                     memory_budget_bytes=1 << 20, num_buckets=40)
    bstore, meta, _ = bucketize(store, str(tmp_path / "bk"), cfg)
    graph = build_bucket_graph(meta, cfg)

    amps = []
    for frag in (None, 64, 8):   # contiguous / 16 KB extents / 2 KB extents
        fs = BucketedVectorStore(str(tmp_path / "bk"), fragment_rows=frag)
        res = JoinExecutor(fs, meta, cfg).run(graph)
        amps.append(res.io_stats["read_amplification"])
    # paper Fig. 14: page-multiple extents are free (SSDs don't seek);
    # amplification returns only when extents drop below the 4 KB page
    assert abs(amps[0] - amps[1]) < 0.02
    assert amps[0] < 1.1
    assert amps[2] > 1.5

    # results identical regardless of fragmentation (accounting only)
    fs0 = BucketedVectorStore(str(tmp_path / "bk"))
    fs1 = BucketedVectorStore(str(tmp_path / "bk"), fragment_rows=16)
    r0 = JoinExecutor(fs0, meta, cfg).run(graph)
    r1 = JoinExecutor(fs1, meta, cfg).run(graph)
    assert np.array_equal(r0.pairs, r1.pairs)
