"""Multi-device tests in subprocesses (8 forced host devices): pipeline
parallelism, sharded train step with collectives, distributed join on a
mesh. Subprocesses keep the main test session at 1 device."""
import subprocess
import sys
import textwrap

import pytest


def _run(code: str, timeout: int = 900) -> str:
    prelude = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "import sys\n"
        "sys.path.insert(0, 'src')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout,
        cwd=__file__.rsplit("/", 2)[0])
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_pipeline_parallel_gpipe_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import (gpipe_forward, make_pp_mesh,
                                         split_stages, bubble_fraction)
        S, L, M, mb, dim = 4, 8, 4, 2, 16
        mesh = make_pp_mesh(S)
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(scale=0.3, size=(L, dim, dim)),
                        jnp.float32)

        def layer(wi, x):
            return jnp.tanh(x @ wi)

        def stage_fn(params, x):   # params: (L/S, dim, dim)
            for i in range(params.shape[0]):
                x = layer(params[i], x)
            return x

        x = jnp.asarray(rng.normal(size=(M, mb, dim)), jnp.float32)
        stage_params = split_stages(w, S)
        fwd = gpipe_forward(stage_fn, mesh, M)
        y_pp = fwd(stage_params, x)
        # sequential reference
        y_ref = x
        for i in range(L):
            y_ref = layer(w[i], y_ref)
        np.testing.assert_allclose(np.asarray(y_pp), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
        assert 0 < bubble_fraction(S, M) < 1
        print('PP-OK')
    """)
    assert "PP-OK" in out


def test_sharded_train_step_runs_with_collectives():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, smoke_config
        from repro.models import build_model
        from repro.launch.steps import make_train_step, batch_shardings
        from repro.dist import sharding as shd
        from repro.train.optimizer import AdamW, AdamWConfig

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        cfg = smoke_config(get_config('qwen3-0.6b'))
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        opt = AdamW(AdamWConfig(learning_rate=1e-3))
        opt_state = opt.init(params)
        step = make_train_step(m, opt)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                    cfg.vocab)
        batch = {'tokens': tokens, 'labels': tokens}
        shd.set_mesh(mesh)
        with mesh:
            p_sh = shd.param_shardings(params, mesh)
            params = jax.device_put(params, p_sh)
            jitted = jax.jit(step)
            new_params, new_state, metrics = jitted(params, opt_state, batch)
            loss_sharded = float(metrics['loss'])
        shd.set_mesh(None)
        # single-device reference
        params1 = m.init(jax.random.PRNGKey(0))
        _, _, metrics1 = jax.jit(step)(params1, opt.init(params1), batch)
        assert abs(loss_sharded - float(metrics1['loss'])) < 1e-2, \\
            (loss_sharded, float(metrics1['loss']))
        print('SHARD-OK', loss_sharded)
    """)
    assert "SHARD-OK" in out


def test_distributed_join_on_mesh_matches_truth():
    out = _run("""
        import jax, numpy as np, tempfile, os
        from repro.core import (JoinConfig, bucketize, build_bucket_graph,
                                recall)
        from repro.core.distributed import DistributedJoin
        from repro.data import clustered_vectors, brute_force_pairs
        from repro.store.vector_store import FlatVectorStore

        mesh = jax.make_mesh((8,), ('data',))
        x = clustered_vectors(3000, 32, seed=4)
        eps = 0.3
        d = tempfile.mkdtemp()
        store = FlatVectorStore.from_array(os.path.join(d, 'x.bin'), x)
        cfg = JoinConfig(epsilon=eps, recall_target=0.95, pad_align=64,
                         memory_budget_bytes=2 << 20, num_buckets=16)
        bs, meta, _ = bucketize(store, os.path.join(d, 'bk'), cfg)
        graph = build_bucket_graph(meta, cfg)
        pairs, info = DistributedJoin(bs, meta, cfg, mesh=mesh).run(graph)
        truth = brute_force_pairs(x, eps)
        r = recall(pairs, truth)
        assert r >= 0.9, r
        print('DISTJOIN-OK', r, info['supersteps'])
    """)
    assert "DISTJOIN-OK" in out


def test_fsdp_param_sharding_shards_embedding():
    out = _run("""
        import jax, numpy as np
        from repro.configs import get_config, smoke_config
        from repro.models import build_model
        from repro.dist import sharding as shd

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        cfg = smoke_config(get_config('chatglm3-6b'))
        m = build_model(cfg)
        shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        sh = shd.param_shardings(shapes, mesh, fsdp=True)
        emb = sh['embed']['table']
        spec = emb.spec
        assert 'model' in str(spec) and 'data' in str(spec), spec
        print('FSDP-OK', spec)
    """)
    assert "FSDP-OK" in out
