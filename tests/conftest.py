import os
import sys

# tests see ONE device (the dry-run sets 512 in its own subprocess only)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# property tests need hypothesis; skip collection where it isn't installed
collect_ignore = []
try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore.append("test_properties.py")


@pytest.fixture(scope="session")
def clustered_20k():
    from repro.data import clustered_vectors, epsilon_for_avg_neighbors
    x = clustered_vectors(20000, 64, seed=1)
    eps = epsilon_for_avg_neighbors(x, 20)
    return x, eps


@pytest.fixture(scope="session")
def small_dataset():
    from repro.data import clustered_vectors, epsilon_for_avg_neighbors
    x = clustered_vectors(4000, 32, seed=5)
    eps = epsilon_for_avg_neighbors(x, 10)
    return x, eps


@pytest.fixture()
def tmp_store(tmp_path):
    from repro.store.vector_store import FlatVectorStore

    def make(x):
        return FlatVectorStore.from_array(
            str(tmp_path / f"data_{x.shape[0]}.bin"), np.asarray(x))

    return make
