"""Hypothesis property tests on system invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (BucketGraph, cap_constant, edge_schedule, gorder,
                        miss_bound_terms, prune_candidates, simulate_belady,
                        simulate_policy)
from repro.core.types import canonicalize_pairs, recall
from repro.runtime.elastic import plan_mesh
from repro.store.io_stats import IOStats


# ---------------------------------------------------------------------------
# cache policy invariants
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(
    seq=st.lists(st.integers(0, 14), min_size=1, max_size=300),
    cap=st.integers(2, 12),
)
def test_belady_optimality_property(seq, cap):
    """Belady never does more misses than any online policy (MIN theorem)."""
    s = np.asarray(seq)
    b = simulate_belady(s, 15, cap)
    for policy in ("lru", "fifo", "lfu"):
        assert b.misses <= simulate_policy(s, 15, cap, policy).misses


@settings(max_examples=50, deadline=None)
@given(
    seq=st.lists(st.integers(0, 9), min_size=1, max_size=200),
    cap=st.integers(2, 8),
)
def test_cache_miss_lower_bound(seq, cap):
    """Misses ≥ number of distinct buckets (each loaded at least once)."""
    s = np.asarray(seq)
    distinct = len(set(seq))
    for policy in ("belady", "lru", "fifo", "lfu"):
        r = simulate_policy(s, 10, cap, policy)
        assert r.misses >= distinct
        assert r.hits + r.misses == len(seq)


# ---------------------------------------------------------------------------
# ordering invariants
# ---------------------------------------------------------------------------
def _random_graph(draw_edges, n):
    if not draw_edges:
        return BucketGraph(num_nodes=n, edges=np.zeros((0, 2), np.int64))
    e = np.asarray([(min(a, b), max(a, b)) for a, b in draw_edges
                    if a != b], np.int64)
    if e.size == 0:
        return BucketGraph(num_nodes=n, edges=np.zeros((0, 2), np.int64))
    return BucketGraph(num_nodes=n, edges=np.unique(e, axis=0))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 20),
    edges=st.lists(st.tuples(st.integers(0, 19), st.integers(0, 19)),
                   max_size=60),
    window=st.integers(1, 8),
)
def test_gorder_always_permutation(n, edges, window):
    g = _random_graph([(a % n, b % n) for a, b in edges], n)
    order = gorder(g, window)
    assert sorted(order.tolist()) == list(range(n))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 16),
    edges=st.lists(st.tuples(st.integers(0, 15), st.integers(0, 15)),
                   max_size=40),
)
def test_edge_schedule_complete_cover(n, edges):
    """Every edge processed exactly once; every node touched exactly once;
    pins always name the other endpoint of the in-flight edge."""
    g = _random_graph([(a % n, b % n) for a, b in edges], n)
    tasks, access, pins = edge_schedule(g, np.arange(n))
    etasks = [(min(u, v), max(u, v)) for k, u, v in
              [t for t in tasks if t[0] == "edge"]]
    assert sorted(etasks) == sorted(map(tuple, g.edges.tolist()))
    assert sorted(t[1] for t in tasks if t[0] == "touch") == list(range(n))
    i = 0
    for t in tasks:
        if t[0] == "touch":
            assert pins[i] == -1
            i += 1
        else:
            assert pins[i] == access[i + 1] and pins[i + 1] == access[i]
            i += 2


# ---------------------------------------------------------------------------
# pruning invariants
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(
    dists=st.lists(st.floats(0.01, 10.0), min_size=1, max_size=30),
    radius=st.floats(0.1, 5.0),
    dim=st.integers(2, 512),
    lam=st.floats(0.5, 1.0),
)
def test_prune_budget_respected(dists, radius, dim, lam):
    """Σ terms of pruned candidates ≤ 1 − λ (the Eq. 3 guarantee)."""
    d = np.asarray(dists)
    keep = prune_candidates(d, radius, dim, lam)
    terms = miss_bound_terms(d, radius, dim)
    assert terms[~keep].sum() <= (1 - lam) + 1e-9


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(2, 2048))
def test_cap_constant_positive_finite(dim):
    v = cap_constant(dim)
    assert 0 < v < 10


# ---------------------------------------------------------------------------
# pair algebra invariants
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(pairs=st.lists(st.tuples(st.integers(0, 50), st.integers(0, 50)),
                      max_size=100))
def test_canonicalize_idempotent_and_selfless(pairs):
    p = np.asarray(pairs, np.int64).reshape(-1, 2)
    c1 = canonicalize_pairs(p)
    c2 = canonicalize_pairs(c1)
    assert np.array_equal(c1, c2)
    if c1.size:
        assert (c1[:, 0] < c1[:, 1]).all()
    assert recall(c1, c1) == 1.0


# ---------------------------------------------------------------------------
# elastic planning invariants
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(chips=st.integers(0, 2048),
       batch=st.sampled_from([32, 128, 256, 512]))
def test_plan_mesh_valid(chips, batch):
    plan = plan_mesh(chips, global_batch=batch)
    if plan is not None:
        assert plan.chips <= max(chips, 1)
        assert batch % (plan.data * plan.pod) == 0
        assert plan.model >= 1 and (plan.model & (plan.model - 1)) == 0


# ---------------------------------------------------------------------------
# io accounting invariants
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(sizes=st.lists(st.integers(1, 100_000), min_size=1, max_size=50))
def test_read_amplification_ge_one(sizes):
    s = IOStats()
    for n in sizes:
        s.record_read(n)
    assert s.read_amplification >= 1.0
    assert s.bytes_read_total >= s.bytes_read_useful
