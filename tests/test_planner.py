"""Cost-based adaptive planner (repro.plan): estimator calibration,
plan-driven capacities/batching/routing (byte-identical results planner
on/off, zero compaction overflows), host/device routing flip under link
emulation, pool-budget split, estimate-based admission, mid-wave deadline
cancellation, query-path f32 threshold parity, and manifest back-compat
for indexes built before sketches existed."""
import json
import math
import os

import numpy as np
import pytest

from repro.core import DiskJoinIndex, JoinConfig
from repro.plan import (CardinalityEstimator, CostModel, Planner,
                        SKETCH_FILE)
from repro.store.vector_store import FlatVectorStore


def _flat(x, tmp_path, name="x.bin"):
    return FlatVectorStore.from_array(str(tmp_path / name),
                                      np.asarray(x, np.float32))


def _truth_edges(x, assignment, edges, eps):
    """Brute-force result-pair count per bucket edge (intra: unordered)."""
    out = np.zeros(len(edges), np.int64)
    members = {b: np.flatnonzero(assignment == b)
               for b in np.unique(assignment)}
    for i, (u, v) in enumerate(edges):
        mu, mv = members.get(u, []), members.get(v, [])
        if len(mu) == 0 or len(mv) == 0:
            continue
        d = np.linalg.norm(x[mu][:, None, :] - x[mv][None, :, :], axis=2)
        hit = d <= eps
        if u == v:
            out[i] = int(np.triu(hit, k=1).sum())
        else:
            out[i] = int(hit.sum())
    return out


def _assign_nearest(x, centers):
    d = np.linalg.norm(x[:, None, :] - centers[None, :, :], axis=2)
    return np.argmin(d, axis=1).astype(np.int64)


def _all_edges(num_buckets):
    edges = [(u, u) for u in range(num_buckets)]
    edges += [(u, v) for u in range(num_buckets)
              for v in range(u + 1, num_buckets)]
    return np.asarray(edges, np.int64)


# ---------------------------------------------------------------------------
# estimator: exactness when fully sampled, calibrated bounds otherwise
# ---------------------------------------------------------------------------
class TestEstimator:
    def test_fully_sampled_buckets_estimate_exactly(self, tmp_path):
        """Buckets at or below sample_rows are the sample: the 'estimate'
        is a full verify of the sketch and must equal the ground truth."""
        rng = np.random.default_rng(0)
        x = rng.normal(size=(80, 8)).astype(np.float32)
        assignment = np.repeat(np.arange(8), 10)   # every bucket: 10 rows
        est = CardinalityEstimator.sample_flat(_flat(x, tmp_path),
                                               assignment, 8, seed=3)
        eps = 1.8
        edges = _all_edges(8)
        truth = _truth_edges(x, assignment, edges, eps)
        got, lo, hi = est.est_edges(edges, eps)
        assert np.allclose(got, truth)
        assert (lo <= truth).all() and (truth <= hi).all()
        assert truth.sum() > 0      # the check above wasn't vacuous

    @pytest.mark.parametrize("dist", ["uniform", "clustered", "skewed"])
    @pytest.mark.parametrize("avg_neighbors", [2, 10, 30])
    def test_bounds_calibrated_across_distributions(self, tmp_path, dist,
                                                    avg_neighbors):
        from repro.data import clustered_vectors, epsilon_for_avg_neighbors

        rng = np.random.default_rng(11)
        n, d = 1200, 16
        if dist == "uniform":
            x = rng.uniform(-1, 1, size=(n, d)).astype(np.float32)
        elif dist == "clustered":
            x = clustered_vectors(n, d, seed=7)
        else:  # skewed: one dominant tight cluster + a diffuse tail
            dense = rng.normal(scale=0.05, size=(n * 3 // 4, d))
            tail = rng.normal(scale=1.0, size=(n - dense.shape[0], d)) + 2.0
            x = np.concatenate([dense, tail]).astype(np.float32)
        eps = epsilon_for_avg_neighbors(x, avg_neighbors)
        centers = x[rng.choice(n, size=10, replace=False)]
        assignment = _assign_nearest(x, centers)
        est = CardinalityEstimator.sample_flat(
            _flat(x, tmp_path, f"{dist}{avg_neighbors}.bin"),
            assignment, 10, seed=5)
        edges = _all_edges(10)
        truth = _truth_edges(x, assignment, edges, eps)
        got, lo, hi = est.est_edges(edges, eps)
        # z=2 Wilson upper bounds: ≳97% one-sided coverage per edge
        covered = float((truth <= hi + 1e-9).mean())
        assert covered >= 0.9, f"hi-bound coverage {covered:.2f}"
        # the aggregate estimate tracks the true join size
        if truth.sum() >= 200:
            ratio = got.sum() / truth.sum()
            assert 1 / 3 <= ratio <= 3, f"est/truth ratio {ratio:.2f}"

    def test_sketch_roundtrip_and_version_guard(self, tmp_path):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(120, 6)).astype(np.float32)
        assignment = np.repeat(np.arange(6), 20)
        est = CardinalityEstimator.sample_flat(_flat(x, tmp_path),
                                               assignment, 6, seed=9)
        p = str(tmp_path / "sk.npz")
        est.save(p)
        back = CardinalityEstimator.load(p)
        assert np.array_equal(back.samples, est.samples)
        assert np.array_equal(back.rows, est.rows)
        assert np.array_equal(back.sizes, est.sizes)
        e1 = est.est_pairs((0, 1), 1.5)
        e2 = back.est_pairs((0, 1), 1.5)
        assert e1 == e2
        with np.load(p) as f:
            bad = {k: f[k] for k in f.files}
        bad["version"] = np.int64(99)
        np.savez(p, **bad)
        with pytest.raises(ValueError, match="version"):
            CardinalityEstimator.load(p)


# ---------------------------------------------------------------------------
# planner: plan-driven joins — parity, overflow elimination, routing flip
# ---------------------------------------------------------------------------
def _force_tiny_default_pair_cap(monkeypatch, cap=64):
    """Make the device engine's *default* compaction capacity tiny, as a
    hand-mistuned baseline. Planner-passed caps (pair_cap != None) are
    untouched — exactly the knob the JoinPlan replaces."""
    from repro.compute import engine as eng
    orig = eng.DeviceVerifyEngine.__init__

    def patched(self, cache, **kw):
        if kw.get("pair_cap") is None:
            kw["pair_cap"] = cap
        orig(self, cache, **kw)

    monkeypatch.setattr(eng.DeviceVerifyEngine, "__init__", patched)


class TestJoinPlanning:
    @pytest.mark.parametrize("io_mode,devices", [
        ("sync", 1), ("prefetch", 1), ("prefetch", 4)])
    def test_plan_on_off_byte_parity_and_zero_overflow(
            self, small_dataset, tmp_path, monkeypatch, io_mode, devices):
        """The planner only sizes and places work: planner-on results are
        byte-identical to planner-off, and the planned pair_cap absorbs
        the dense units a mistuned default overflows on."""
        _force_tiny_default_pair_cap(monkeypatch)
        x, eps = small_dataset
        base = dict(epsilon=eps, pad_align=64, num_buckets=24,
                    memory_budget_bytes=1 << 20, io_mode=io_mode,
                    io_devices=devices, compute_mode="device",
                    io_batch_reads=devices > 1, io_coalesce=devices > 1)
        wd = str(tmp_path / f"off_{io_mode}{devices}")
        with DiskJoinIndex.build(_flat(x, tmp_path, "a.bin"),
                                 JoinConfig(**base), wd) as idx:
            r_off = idx.self_join()
            off_snap = idx.pipeline_snapshot()
        wd = str(tmp_path / f"on_{io_mode}{devices}")
        with DiskJoinIndex.build(_flat(x, tmp_path, "b.bin"),
                                 JoinConfig(**base), wd) as idx:
            r_on = idx.self_join(plan_mode="on")
            on_snap = idx.pipeline_snapshot()
        assert r_off.pairs.shape[0] > 0
        assert np.array_equal(r_off.pairs, r_on.pairs)
        assert np.array_equal(r_off.distances, r_on.distances)
        # the mistuned baseline overflowed; the planned cap never does
        assert off_snap["device_compact_overflows"] > 0
        assert on_snap["device_compact_overflows"] == 0
        plan = r_on.plan
        assert plan is not None and plan.pair_cap > 64
        assert on_snap["planned_pair_cap"] == plan.pair_cap
        assert on_snap["plans"] == 1

    @pytest.mark.parametrize("mode", ["host", "device"])
    def test_cross_join_plan_parity(self, tmp_path, mode):
        rng = np.random.default_rng(21)
        a = rng.normal(size=(500, 8)).astype(np.float32)
        b = (a[:400] + rng.normal(scale=0.2, size=(400, 8))
             ).astype(np.float32)
        kw = dict(epsilon=0.9, num_buckets=8, pad_align=64,
                  memory_budget_bytes=1 << 20, compute_mode=mode)
        with DiskJoinIndex.build(_flat(a, tmp_path, "a.bin"),
                                 JoinConfig(**kw),
                                 str(tmp_path / "ia")) as ia, \
             DiskJoinIndex.build(_flat(b, tmp_path, "b.bin"),
                                 JoinConfig(**kw),
                                 str(tmp_path / "ib")) as ib:
            r_off = ia.cross_join(ib)
            r_on = ia.cross_join(ib, plan_mode="on")
        assert r_off.pairs.shape[0] > 0
        assert np.array_equal(r_off.pairs, r_on.pairs)
        assert np.array_equal(r_off.distances, r_on.distances)
        assert r_on.plan is not None and not r_on.plan.mixed

    def test_plan_shape_and_explain(self, tmp_path):
        rng = np.random.default_rng(2)
        x = rng.normal(size=(900, 8)).astype(np.float32)
        cfg = JoinConfig(epsilon=1.0, num_buckets=12, pad_align=64,
                         memory_budget_bytes=1 << 20, verify_batch=16)
        with DiskJoinIndex.build(_flat(x, tmp_path), cfg,
                                 str(tmp_path / "idx")) as idx:
            r = idx.self_join(plan_mode="on")
        plan = r.plan
        assert plan.num_units == len(plan.unit_params)
        assert plan.num_units > 0
        for route, batch in plan.unit_params:
            assert route in ("host", "device")
            assert 1 <= batch <= cfg.verify_batch
        # pair_cap: pow2, floored, bounded by cap²
        assert plan.pair_cap & (plan.pair_cap - 1) == 0
        assert plan.pair_cap >= 64
        text = plan.explain()
        for needle in ("pair_cap", "verify_batch", "compute", "JoinPlan"):
            assert needle in text

    def test_route_flips_with_link_emulation(self, tmp_path):
        """compute_mode="auto": free link → host (device compaction is
        pure overhead); slow emulated link → device (the host path's full
        mask+d² readback dominates). Same pair set either way."""
        rng = np.random.default_rng(4)
        x = rng.normal(size=(600, 8)).astype(np.float32)
        cfg = JoinConfig(epsilon=1.1, num_buckets=10, pad_align=64,
                         memory_budget_bytes=1 << 20)
        with DiskJoinIndex.build(_flat(x, tmp_path), cfg,
                                 str(tmp_path / "idx")) as idx:
            r_ref = idx.self_join()
            r_free = idx.self_join(plan_mode="on", compute_mode="auto")
            r_slow = idx.self_join(plan_mode="on", compute_mode="auto",
                                   emulate_xfer_gb_s=0.01)
        assert r_free.plan.compute_mode == "host"
        assert r_slow.plan.compute_mode in ("device", "mixed")
        assert any(rt == "device" for rt, _ in r_slow.plan.unit_params)
        for r in (r_free, r_slow):
            assert np.array_equal(r_ref.pairs, r.pairs)
            assert np.array_equal(r_ref.distances, r.distances)

    def test_cost_model_provenance(self):
        cfg = JoinConfig(epsilon=0.5, emulate_read_latency_s=0.004,
                         emulate_xfer_gb_s=2.0)
        m = CostModel.from_telemetry(cfg, None)
        assert m.read_s_per_bucket == pytest.approx(0.004)
        assert m.h2d_gb_s == 2.0
        assert "config" in m.provenance["read_s_per_bucket"]
        measured = CostModel.from_telemetry(
            None, {"loads": 10, "read_s": 0.05})
        assert measured.read_s_per_bucket == pytest.approx(0.005)
        assert "measured" in measured.provenance["read_s_per_bucket"]
        assert CostModel.from_telemetry(None, None).h2d_gb_s == 0.0


# ---------------------------------------------------------------------------
# pool-budget split
# ---------------------------------------------------------------------------
class TestPoolPlanning:
    def _planner(self):
        est = CardinalityEstimator(np.zeros((4, 2, 3), np.float32),
                                   np.array([2, 2, 2, 2]),
                                   np.array([5, 5, 5, 5]))
        return Planner(est, CostModel())

    def test_warm_quota_from_observed_reuse(self):
        p = self._planner()
        cfg = JoinConfig(epsilon=0.5)
        pp = p.plan_pool(cfg, cap_buckets=6, lookahead=4,
                         stats={"waves": 10, "shared_probe_reads": 38})
        assert pp.warm_quota == 4            # ceil(3.8), within [2, 6]
        assert pp.num_slabs == 6 + 4 + 4
        assert "reuse" in pp.explain()

    def test_warm_quota_floor_without_traffic(self):
        p = self._planner()
        cfg = JoinConfig(epsilon=0.5)
        pp = p.plan_pool(cfg, cap_buckets=6, lookahead=4, stats={})
        assert pp.warm_quota == 2            # legacy reserve
        pp = p.plan_pool(cfg, cap_buckets=6, lookahead=4,
                         stats={"waves": 3, "shared_probe_reads": 300})
        assert pp.warm_quota == 6            # clamped to cap_buckets

    def test_session_pool_uses_plan(self, tmp_path):
        rng = np.random.default_rng(6)
        x = rng.normal(size=(500, 8)).astype(np.float32)
        cfg = JoinConfig(epsilon=1.0, num_buckets=8, pad_align=64,
                         memory_budget_bytes=1 << 20, plan_mode="on")
        with DiskJoinIndex.build(_flat(x, tmp_path), cfg,
                                 str(tmp_path / "idx")) as idx:
            out = idx.query_batch(x[:3] + 0.01)
            assert len(out) == 3
            assert idx._warm_quota is not None
            assert idx._warm_quota >= 2


# ---------------------------------------------------------------------------
# serving: estimate-based admission + mid-wave deadline cancellation
# ---------------------------------------------------------------------------
class TestServingPlans:
    def _index(self, tmp_path, **cfg_kw):
        rng = np.random.default_rng(8)
        x = rng.normal(size=(800, 8)).astype(np.float32)
        base = dict(epsilon=1.0, num_buckets=16, pad_align=64,
                    memory_budget_bytes=1 << 20)
        base.update(cfg_kw)
        return x, DiskJoinIndex.build(_flat(x, tmp_path),
                                      JoinConfig(**base),
                                      str(tmp_path / "idx"))

    def test_estimate_admission_rejects_doomed_only(self, tmp_path):
        from repro.serve import AdmissionRejected, QueryScheduler

        x, idx = self._index(tmp_path)
        with idx, QueryScheduler(idx, admission="estimate", max_wait_s=0.0,
                                 emulate_read_latency_s=0.05) as s:
            with pytest.raises(AdmissionRejected) as ei:
                s.submit(x[0], deadline_s=0.001)
            assert ei.value.predicted_s > ei.value.deadline_s
            # rejected at the door: nothing was read for it
            assert idx.stats.snapshot()["admission_rejects"] == 1
            assert s.admission_rejects == 1
            # a feasible deadline and a no-deadline request both admit
            ids, _ = s.submit(x[1], deadline_s=30.0).result(timeout=30)
            assert len(ids) >= 1
            s.submit(x[2]).result(timeout=30)
            snap = s.snapshot()
            assert snap["admission_rejects"] == 1
            assert snap["completed"] == 2

    def test_queue_admission_never_estimate_rejects(self, tmp_path):
        from repro.serve import QueryScheduler

        x, idx = self._index(tmp_path)
        with idx, QueryScheduler(idx, max_wait_s=0.0,
                                 emulate_read_latency_s=0.05) as s:
            fut = s.submit(x[0], deadline_s=0.001)
            with pytest.raises(Exception):   # dropped later, not at submit
                fut.result(timeout=30)
            assert idx.stats.snapshot()["admission_rejects"] == 0

    def test_admission_validation(self, tmp_path):
        from repro.serve import QueryScheduler

        _, idx = self._index(tmp_path)
        with idx:
            with pytest.raises(ValueError, match="admission"):
                QueryScheduler(idx, admission="psychic")

    def test_pre_read_vs_midwave_drops_distinguished(self, tmp_path):
        from repro.serve import DeadlineExceeded, QueryScheduler

        x, idx = self._index(tmp_path)
        with idx:
            # pre-read: the deadline expires while waiting for the wave
            # window — dropped before any read, not counted as mid-wave
            with QueryScheduler(idx, wave_size=64, max_wait_s=0.3) as s:
                fut = s.submit(x[0], deadline_s=0.005)
                with pytest.raises(DeadlineExceeded, match="before the"):
                    fut.result(timeout=30)
            snap = idx.stats.snapshot()
            assert snap["deadline_drops"] == 1
            assert snap["deadline_drops_midwave"] == 0

            # mid-wave: reads are slow enough that the deadline passes
            # while its wave is already executing
            with QueryScheduler(idx, max_wait_s=0.0,
                                emulate_read_latency_s=0.05) as s:
                probes = idx.plan_probes(x[3][None, :])[0]
                assert len(probes) >= 2      # enough buckets to cancel in
                fut = s.submit(x[3], deadline_s=0.01)
                with pytest.raises(DeadlineExceeded, match="mid-wave"):
                    fut.result(timeout=30)
            snap = idx.stats.snapshot()
            assert snap["deadline_drops"] == 2
            assert snap["deadline_drops_midwave"] == 1
            # the cancelled request's remaining solo reads were skipped
            assert snap["midwave_skipped_reads"] >= 1

    def test_midwave_peer_unaffected(self, tmp_path):
        from repro.serve import DeadlineExceeded, QueryScheduler

        x, idx = self._index(tmp_path)
        with idx:
            baseline = idx.query_batch(x[5][None, :])[0]
            with QueryScheduler(idx, max_wait_s=0.05,
                                emulate_read_latency_s=0.03) as s:
                doomed = s.submit(x[3], deadline_s=0.005)
                peer = s.submit(x[5])
                with pytest.raises(DeadlineExceeded):
                    doomed.result(timeout=30)
                ids, dists = peer.result(timeout=30)
            order = np.argsort(ids)
            bl_order = np.argsort(baseline[0])
            assert np.array_equal(np.sort(ids), np.sort(baseline[0]))
            np.testing.assert_array_equal(dists[order],
                                          baseline[1][bl_order])


# ---------------------------------------------------------------------------
# query-path dtype parity (satellite): f32 threshold on both paths
# ---------------------------------------------------------------------------
class TestQueryDtypeParity:
    def test_f32_threshold_parity_near_boundary(self, tmp_path):
        """Regression for the host/device query divergence: the host path
        used to apply the ε-threshold in float64 while the device kernel
        applies it in float32. Construct a pair whose exactly-representable
        d² lies between ε² (f64) and its f32 rounding: the f64 rule
        excludes it, the f32 rule includes it — host and device must now
        agree (both f32), and both return float32 distances."""
        d2_exact = 0.25 ** 2 + 0.125 ** 2 + 0.0625 ** 2 + 0.03125 ** 2
        assert np.float32(d2_exact) == d2_exact     # exactly representable
        eps = math.sqrt(d2_exact - 1e-9)
        # the crafted regime: f64 excludes, f32 (both paths) includes
        assert d2_exact > eps * eps
        assert np.float32(d2_exact) <= np.float32(eps * eps)

        p = np.array([0.25, 0.125, 0.0625, 0.03125], np.float32)
        inner = np.array([0.1, 0.0, 0.0, 0.0], np.float32)   # clearly in
        rng = np.random.default_rng(3)
        far = rng.normal(size=(120, 4)).astype(np.float32)
        far = far / np.linalg.norm(far, axis=1, keepdims=True) * 10.0
        x = np.concatenate([p[None], inner[None], far]).astype(np.float32)
        cfg = JoinConfig(epsilon=eps, num_buckets=4, pad_align=64,
                         memory_budget_bytes=1 << 20, prune=False)
        with DiskJoinIndex.build(_flat(x, tmp_path), cfg,
                                 str(tmp_path / "idx")) as idx:
            q = np.zeros((1, 4), np.float32)
            (h_ids, h_d), = idx.query_batch(q)
            (d_ids, d_d), = idx.query_batch(q, compute_mode="device")
        assert set(h_ids.tolist()) == set(d_ids.tolist())
        assert 0 in h_ids and 1 in h_ids        # f32 semantics include p
        assert h_d.dtype == np.float32 and d_d.dtype == np.float32
        hp = float(h_d[list(h_ids).index(0)])
        dp = float(d_d[list(d_ids).index(0)])
        assert hp == dp == float(np.sqrt(np.float32(d2_exact)))


# ---------------------------------------------------------------------------
# manifest back-compat: pre-sketch indexes open and lazily rebuild
# ---------------------------------------------------------------------------
class TestManifestBackCompat:
    def test_pre_sketch_manifest_rebuilds_once(self, tmp_path):
        from repro.core.index import MANIFEST_NAME

        rng = np.random.default_rng(12)
        x = rng.normal(size=(500, 8)).astype(np.float32)
        wd = str(tmp_path / "idx")
        cfg = JoinConfig(epsilon=1.0, num_buckets=8, pad_align=64,
                         memory_budget_bytes=1 << 20)
        with DiskJoinIndex.build(_flat(x, tmp_path), cfg, wd) as idx:
            r_new = idx.self_join(plan_mode="on")
        # simulate an index written before sketches existed
        os.remove(os.path.join(wd, SKETCH_FILE))
        mpath = os.path.join(wd, MANIFEST_NAME)
        with open(mpath) as f:
            m = json.load(f)
        m.pop("sketch", None)
        with open(mpath, "w") as f:
            json.dump(m, f)

        with DiskJoinIndex.open(wd) as idx:
            with pytest.warns(UserWarning, match="predates planner"):
                r_old = idx.self_join(plan_mode="on")
            assert np.array_equal(r_new.pairs, r_old.pairs)
            assert np.array_equal(r_new.distances, r_old.distances)
        # the rebuilt sketch was re-persisted and noted in the manifest
        assert os.path.exists(os.path.join(wd, SKETCH_FILE))
        with open(mpath) as f:
            assert json.load(f)["sketch"]["file"] == SKETCH_FILE

        # second open: sketch on disk, no warning, no rebuild
        with DiskJoinIndex.open(wd) as idx:
            import warnings as _w
            with _w.catch_warnings():
                _w.simplefilter("error")
                r2 = idx.self_join(plan_mode="on")
            assert np.array_equal(r_new.pairs, r2.pairs)

    def test_plan_off_never_touches_sketch(self, tmp_path):
        """plan_mode="off" (the default) must work with no sketch at all —
        the planner is strictly opt-in."""
        rng = np.random.default_rng(13)
        x = rng.normal(size=(400, 8)).astype(np.float32)
        wd = str(tmp_path / "idx")
        cfg = JoinConfig(epsilon=1.0, num_buckets=8, pad_align=64,
                         memory_budget_bytes=1 << 20)
        with DiskJoinIndex.build(_flat(x, tmp_path), cfg, wd):
            pass
        os.remove(os.path.join(wd, SKETCH_FILE))
        with DiskJoinIndex.open(wd) as idx:
            r = idx.self_join()
            assert r.plan is None
            assert r.pairs.shape[0] >= 0
        assert not os.path.exists(os.path.join(wd, SKETCH_FILE))
