"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps with checkpoint/restart (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

``--tiny`` runs the smoke-scale config (CI); default builds a ~100M model.
"""
import argparse
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, smoke_config  # noqa: E402
from repro.train import AdamWConfig, TrainConfig, train  # noqa: E402


def hundred_m_config():
    """~100M-param member of the qwen3 family (12L × 640 × tied 32k vocab)."""
    base = get_config("qwen3-0.6b")
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=12, d_model=640, n_heads=10,
        n_kv_heads=5, head_dim=64, d_ff=1792, vocab=32768,
        param_dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    args = ap.parse_args()

    cfg = (smoke_config(get_config("qwen3-0.6b")) if args.tiny
           else hundred_m_config())
    steps = 10 if args.tiny else args.steps
    gb = args.global_batch or (8 if not args.tiny else 2)
    sl = args.seq_len or (256 if not args.tiny else 32)
    ckpt = args.ckpt or tempfile.mkdtemp(prefix="train_lm_ckpt_")
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"steps={steps} batch={gb} seq={sl} ckpt={ckpt}")

    out = train(cfg, TrainConfig(
        steps=steps, log_every=max(1, steps // 20),
        checkpoint_every=max(2, steps // 4), checkpoint_dir=ckpt,
        global_batch=gb, seq_len=sl,
        optimizer=AdamWConfig(learning_rate=3e-4,
                              warmup_steps=max(1, steps // 10),
                              total_steps=steps)))
    h = out["loss_history"]
    print(f"loss: {h[0]:.3f} → {h[-1]:.3f} over {len(h)} steps "
          f"({out['mean_step_ms']:.0f} ms/step)")
    print("straggler report:", out["straggler_report"])
    assert h[-1] < h[0], "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
