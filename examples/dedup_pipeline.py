"""Semantic dedup → training pipeline (the paper's flagship application).

1. Embed a synthetic corpus (with planted near-duplicates).
2. DiskJoin-powered semantic dedup produces the drop list.
3. The resumable token pipeline consumes the drop list and feeds a
   reduced-config LM for a few training steps.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs import get_config, smoke_config  # noqa: E402
from repro.data import clustered_vectors  # noqa: E402
from repro.data.dedup import semantic_dedup  # noqa: E402
from repro.data.pipeline import PipelineConfig, TokenPipeline  # noqa: E402
from repro.train import AdamWConfig, TrainConfig, train  # noqa: E402


def main() -> None:
    # -- 1. corpus embeddings with planted duplicates ------------------------
    rng = np.random.default_rng(0)
    base = clustered_vectors(3000, 32, seed=7)
    dups = base[:800] + rng.normal(scale=1e-3, size=(800, 32)).astype(
        np.float32)
    embeddings = np.concatenate([base, dups])
    print(f"corpus: {len(embeddings)} docs ({len(dups)} planted dups)")

    # -- 2. DiskJoin semantic dedup ------------------------------------------
    report = semantic_dedup(embeddings, epsilon=0.05, recall_target=0.95,
                            workdir=tempfile.mkdtemp(prefix="dedup_"))
    print(f"dedup: dropped {report.num_dropped} "
          f"({100*report.dedup_rate:.1f}%), "
          f"{report.num_pairs} similar pairs, "
          f"join cache-hit {report.join_stats['cache_hit_rate']:.2f}, "
          f"amp {report.join_stats['read_amplification']:.4f}")
    assert report.num_dropped >= 700

    # -- 3. train on the deduplicated stream ---------------------------------
    cfg = smoke_config(get_config("qwen3-0.6b"))
    out = train(cfg, TrainConfig(
        steps=8, log_every=2, global_batch=2, seq_len=32,
        optimizer=AdamWConfig(learning_rate=1e-3, warmup_steps=2,
                              total_steps=8)))
    print(f"final loss {out['final_loss']:.3f} "
          f"({out['mean_step_ms']:.0f} ms/step)")
    print("OK")


if __name__ == "__main__":
    main()
