"""Quickstart: billion-scale-shaped similarity self-join at laptop scale.

Builds a clustered synthetic embedding set, stores it on disk, runs the
full DiskJoin pipeline (bucketize → graph+prune → Gorder+Belady → verify)
under a 10% memory budget, and checks recall against brute force.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import JoinConfig, recall, similarity_self_join  # noqa: E402
from repro.data import (brute_force_pairs, clustered_vectors,  # noqa: E402
                        epsilon_for_avg_neighbors)
from repro.store.vector_store import FlatVectorStore  # noqa: E402


def main() -> None:
    n, dim = 20_000, 64
    print(f"building dataset: {n} x {dim} clustered embeddings")
    x = clustered_vectors(n, dim, seed=1)
    eps = epsilon_for_avg_neighbors(x, 20)
    print(f"calibrated ε={eps:.4f} (≈20 neighbors/vector, paper protocol)")

    workdir = tempfile.mkdtemp(prefix="quickstart_")
    store = FlatVectorStore.from_array(os.path.join(workdir, "x.bin"), x)

    cfg = JoinConfig(
        epsilon=eps,
        recall_target=0.9,
        memory_budget_bytes=x.nbytes // 10,   # 10% of data, paper default
        num_buckets=n // 50,   # finer than the paper's 1‰ — N is small here
        pad_align=64,                          # CPU validation alignment
    )
    result = similarity_self_join(store, cfg, workdir=workdir)

    truth = brute_force_pairs(x, eps)
    r = recall(result.pairs, truth)
    print(f"\npairs found: {result.pairs.shape[0]:,} "
          f"(ground truth {truth.shape[0]:,})")
    print(f"recall: {r:.4f}  (target λ=0.9)")
    print(f"cache hit rate: {result.cache_hit_rate:.3f}  "
          f"bucket loads: {result.bucket_loads}")
    print(f"read amplification: "
          f"{result.io_stats['read_amplification']:.4f}  (paper: ≈1.003)")
    print(f"distance computations: {result.num_distance_computations:,} "
          f"(brute force would be {n*(n-1)//2:,})")
    print("timings:", {k: round(v, 3) for k, v in result.timings.items()})
    assert r >= 0.88, "recall below target"
    print("\nOK")


if __name__ == "__main__":
    main()
