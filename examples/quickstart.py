"""Quickstart: build-once / query-many DiskJoin at laptop scale.

Builds a clustered synthetic embedding set, bucketizes it ONCE into a
persistent ``DiskJoinIndex`` (bucketize → disk layout → manifest), then
runs the paper's workflow as cheap queries against that build:

  * two ε-self-joins (graph + Gorder + Belady + verify re-derived per ε,
    bucketing reused — zero extra store writes),
  * the same join through the device-resident verify pipeline
    (``compute_mode="device"`` — byte-identical result, slab transfers
    bounded by cache residencies instead of edge count),
  * online ε-range point lookups through the same BufferPool and
    PipelineStats the batch joins use,
  * concurrent serving through the wave scheduler: overlapping requests
    merged into waves, one read per distinct candidate bucket,
  * a reattach via ``DiskJoinIndex.open`` (no dataset rescan).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import DiskJoinIndex, JoinConfig, recall  # noqa: E402
from repro.data import (brute_force_pairs, clustered_vectors,  # noqa: E402
                        epsilon_for_avg_neighbors)
from repro.serve import QueryScheduler, VectorQueryService  # noqa: E402
from repro.store.vector_store import FlatVectorStore  # noqa: E402


def main() -> None:
    n, dim = 20_000, 64
    print(f"building dataset: {n} x {dim} clustered embeddings")
    x = clustered_vectors(n, dim, seed=1)
    eps = epsilon_for_avg_neighbors(x, 20)
    print(f"calibrated ε={eps:.4f} (≈20 neighbors/vector, paper protocol)")

    workdir = tempfile.mkdtemp(prefix="quickstart_")
    store = FlatVectorStore.from_array(os.path.join(workdir, "x.bin"), x)

    cfg = JoinConfig(
        epsilon=eps,                          # default query-time ε
        recall_target=0.9,
        memory_budget_bytes=x.nbytes // 10,   # 10% of data, paper default
        num_buckets=n // 50,   # finer than the paper's 1‰ — N is small here
        pad_align=64,                          # CPU validation alignment
    )

    # -- build ONCE: bucketize + disk layout + manifest ----------------------
    index = DiskJoinIndex.build(store, cfg, os.path.join(workdir, "index"))
    writes_after_build = index.store.stats.write_ops
    print(f"index built: {index.num_buckets} buckets, "
          f"manifest in {index.workdir}")

    # -- ε-sweep: joins reuse the bucketing (watch the write counter) -------
    truth = brute_force_pairs(x, eps)
    result = index.self_join()                     # default ε
    r = recall(result.pairs, truth)
    print(f"\nself_join(ε={eps:.4f}): {result.pairs.shape[0]:,} pairs "
          f"(truth {truth.shape[0]:,}), recall {r:.4f} (target λ=0.9)")
    tighter = index.self_join(epsilon=eps * 0.7)   # re-query, same build
    print(f"self_join(ε={eps * 0.7:.4f}): {tighter.pairs.shape[0]:,} pairs")
    assert index.store.stats.write_ops == writes_after_build, \
        "ε re-query must not re-bucketize"
    print("store write ops unchanged across the sweep: bucketized ONCE")
    print(f"cache hit rate: {result.cache_hit_rate:.3f}  "
          f"bucket loads: {result.bucket_loads}")
    print(f"read amplification: "
          f"{result.io_stats['read_amplification']:.4f}  (paper: ≈1.003)")
    print("timings:", {k: round(v, 3) for k, v in result.timings.items()})

    # -- device-resident verify: same bytes out, far fewer bytes staged ------
    dev = index.self_join(compute_mode="device")
    assert np.array_equal(dev.pairs, result.pairs)
    assert np.array_equal(dev.distances, result.distances)
    pipe = dev.io_stats["pipeline"]
    refs = pipe["h2d_transfers"] + pipe["h2d_transfers_saved"]
    print(f"\ncompute_mode='device': byte-identical result; "
          f"{pipe['h2d_transfers']} slab transfers served {refs} operand "
          f"references ({pipe['h2d_transfers_saved']} re-stagings avoided, "
          f"{pipe['d2h_bytes'] / 1e6:.1f} MB compacted results fetched)")

    # -- explain the plan: estimate-driven knobs instead of hand tuning ------
    planned = index.self_join(plan_mode="on", compute_mode="auto")
    assert np.array_equal(planned.pairs, result.pairs)   # plans never
    assert np.array_equal(planned.distances, result.distances)  # change results
    print("\nplanner (plan_mode='on', compute_mode='auto'):")
    print(planned.plan.explain())   # pair_cap / routing / batching decisions,
                                    # each with the estimate that drove it

    # -- online point queries: same pool, same telemetry surface -------------
    svc = VectorQueryService(index)
    q = x[1234]
    ids, dists = svc.query(q, k=5)
    print(f"\nonline query (top-5 in ε-ball): ids={ids.tolist()} "
          f"dists={np.round(dists, 4).tolist()}")
    svc.query(q)  # repeat: served from warm pool slabs
    snap = index.pipeline_snapshot()
    print(f"one PipelineStats surface → join loads={snap['loads']}, "
          f"query reads={snap['query_reads']}, "
          f"warm hits={snap['query_warm_hits']}")

    # -- concurrent serving: wave scheduler shares overlapping probes --------
    with QueryScheduler(index, wave_size=32, max_wait_s=0.005) as sched:
        futures = [sched.submit(x[i] + 0.001, k=5, deadline_s=5.0)
                   for i in range(64)]          # 64 concurrent requests
        results = [f.result() for f in futures]
    ssnap = sched.snapshot()
    print(f"\nwave scheduler: {ssnap['waves']} waves for 64 requests, "
          f"{ssnap['pipeline']['reads_saved_by_sharing']} bucket reads "
          f"saved by probe sharing, "
          f"p95={ssnap['latency_p95_ms']:.2f} ms (true enqueue→complete)")
    assert len(results) == 64
    assert ssnap["pipeline"]["reads_saved_by_sharing"] > 0

    # -- observe your join: span trace + Perfetto export + metrics -----------
    from repro.obs import trace_session  # noqa: E402

    with trace_session() as tracer:           # scoped recording tracer
        index.self_join(io_mode="prefetch", emulate_read_latency_s=5e-4)
    trace_path = tracer.export(os.path.join(workdir, "join.trace.json"))
    an = tracer.analysis()
    print(f"\ntraced join → {trace_path} (open at ui.perfetto.dev)")
    print(f"read time hidden behind verify: "
          f"{an.hidden_fraction('io.read', 'io.wait'):.1%} "
          f"(spans: {', '.join(an.names())})")
    metrics = index.metrics_snapshot()        # one surface per session
    print(f"metrics sections: {sorted(metrics)}; "
          f"pipeline overlap_efficiency="
          f"{metrics['pipeline']['overlap_efficiency']:.3f}")

    # -- watch your serving SLOs: live rollups + burn-rate alerts ------------
    from repro.obs import dash  # noqa: E402
    from repro.obs.live import Slo  # noqa: E402

    alerts = []
    index.attach_live(                        # streaming rollups + SLO
        window_s=0.2,                         #   monitor + live cost
        slos=(Slo.latency("query_p95", "query.execute",  # calibration
                          threshold_s=0.05, objective=0.9),),
        on_alert=alerts.append)
    for i in range(40):
        svc.query(x[i], k=5)
    time.sleep(0.25)                          # let a rollup window close
    live = index.metrics_snapshot()["live"]   # same surface as everything
    qx = live["spans"]["query.execute"]
    print(f"\nlive rollup: {qx['count']} queries, "
          f"p95={qx['p95'] * 1e3:.2f} ms, "
          f"{len(alerts)} SLO alert(s) — one-screen view:")
    print(dash.render(index))                 # dash.watch(index) to follow
    index.detach_live()

    # -- survive a replica death: replicated router + failover ---------------
    from repro.ft import FaultInjector  # noqa: E402
    from repro.serve import IndexRouter  # noqa: E402

    idx_dir = os.path.join(workdir, "index")
    router = IndexRouter(                     # 2 replicas of one shard —
        [[DiskJoinIndex.open(idx_dir),        #   same manifest, separate
          DiskJoinIndex.open(idx_dir)]],      #   sessions/pools/schedulers
        epsilon=eps, close_shards=True)
    before, _ = router.query(q, k=5)
    FaultInjector().kill_replica(             # every read on replica 0 now
        router.replica_sets[0].replicas[0])   #   fails; warm cache is lost
    for _ in range(4):                        # routing rotates onto the
        after, _ = router.query(q, k=5)       #   corpse, failover answers
        assert np.array_equal(before, after)  #   anyway, health latches DOWN
    rsnap = router.snapshot()["replica_sets"][0]
    print(f"\nreplica kill survived: failovers="
          f"{rsnap['counters']['failovers']}, replica healths="
          f"{[r['health']['state'] for r in rsnap['replicas']]}")
    router.close()                            # ReplicaSupervisor(router)
                                              #   would restart the dead one

    # -- reattach later without rescanning -----------------------------------
    index.close()
    reopened = DiskJoinIndex.open(os.path.join(workdir, "index"))
    again = reopened.self_join()
    assert np.array_equal(again.pairs, result.pairs)
    print("\nreopened from manifest: identical pair set, zero store writes "
          f"({reopened.store.stats.write_ops})")
    reopened.close()

    assert r >= 0.88, "recall below target"
    print("\nOK")


if __name__ == "__main__":
    main()
