"""Batched serving example: wave-batched greedy decoding with the engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-0.6b]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs import get_config, smoke_config  # noqa: E402
from repro.serve import ServeEngine  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    if cfg.enc_dec:
        raise SystemExit("serve example targets decoder-only archs")
    eng = ServeEngine(cfg, slots=4, max_seq=128)
    rng = np.random.default_rng(0)

    uids = []
    for i in range(args.requests):
        plen = 6 if i % 2 == 0 else 9   # two wave groups
        uids.append(eng.submit(rng.integers(0, cfg.vocab, size=plen),
                               max_new_tokens=args.new_tokens))
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(v) for v in results.values())
    print(f"arch={cfg.name} served {len(results)} requests, "
          f"{tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s, {eng.stats['waves']} waves, "
          f"{eng.stats['steps']} decode steps)")
    for uid in uids[:3]:
        print(f"  req {uid}: {results[uid]}")
    assert set(results) == set(uids)
    print("OK")


if __name__ == "__main__":
    main()
