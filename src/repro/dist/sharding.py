"""Logical-axis sharding (DESIGN §5).

Model code annotates *logical* axes (``batch``, ``heads``, ``mlp``, ...);
this module resolves them to physical mesh axes through a mutable rule
table, flax ``logical_axis_rules`` style:

    shd.set_mesh(mesh)
    with shd.axis_rules(cache_seq=("model",)):
        y = shard(x, "batch", "seq", "embed")

Resolution is permissive by design: a logical axis with no rule, a rule
naming mesh axes that don't exist, or a dimension the mesh axes don't
divide all resolve to *replicated* — model code never has to know the mesh
shape. With no mesh set, ``shard`` is the identity, so all model files run
unmodified on one device.

``param_shardings`` derives a parameter-tree sharding from leaf *path
names* (the same convention ``launch.steps.cache_shardings`` uses for
decode caches): embedding tables and expert stacks shard over ``model``;
``fsdp=True`` additionally shards the largest remaining dim over ``data``
(ZeRO-3 style parameter sharding).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# -- rule table --------------------------------------------------------------
# logical axis -> tuple of physical mesh axes (joint sharding, flax-style).
# () = explicitly replicated. Absent = no rule (has_rule -> False), also
# replicated. Feature-flag rules ("moe_a2a", "moe_tokens") never name an
# array dimension; they gate alternative dataflows via has_rule().
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "embed": (),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert_mlp": ("model",),
    "experts": ("model",),
    "capacity": (),
    "cache_seq": (),
}

_state = threading.local()


def _rules() -> dict[str, tuple[str, ...]]:
    if not hasattr(_state, "rules"):
        _state.rules = dict(DEFAULT_RULES)
    return _state.rules


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def set_mesh(mesh: Optional[Mesh]) -> None:
    """Install (or clear, with None) the ambient mesh ``shard`` targets."""
    _state.mesh = mesh


def current_mesh() -> Optional[Mesh]:
    return _mesh()


def has_rule(name: str) -> bool:
    """True iff logical axis ``name`` has a non-empty rule installed."""
    return bool(_rules().get(name))


@contextlib.contextmanager
def axis_rules(**rules):
    """Override logical→mesh rules within a scope.

    Values may be a mesh-axis name, a tuple of names, True (alias for a
    bare feature flag, resolved to ("model",)), or None/() to disable.
    """
    old = dict(_rules())
    table = _rules()
    for k, v in rules.items():
        table[k] = _tuplize(v)
    try:
        yield
    finally:
        _state.rules = old


def _tuplize(v) -> tuple[str, ...]:
    if v is None or v is False:
        return ()
    if v is True:
        return ("model",)
    if isinstance(v, str):
        return (v,)
    return tuple(v)


# -- resolution --------------------------------------------------------------
def _resolve_dim(mesh: Mesh, dim: int, logical: Optional[str],
                 used: set[str]):
    """Logical axis -> PartitionSpec entry for one dim (or None)."""
    if logical is None:
        return None
    axes = [a for a in _rules().get(logical, ())
            if a in mesh.shape and a not in used]
    if not axes:
        return None
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
    if prod == 0 or dim % prod != 0:
        return None
    used.update(axes)
    return axes[0] if len(axes) == 1 else tuple(axes)


def logical_spec(mesh: Mesh, shape, *axes) -> NamedSharding:
    """NamedSharding for ``shape`` annotated with logical ``axes``.

    Shorter axis lists are right-aligned is NOT assumed — callers pass one
    entry per dim (None for replicated); extra dims beyond the list are
    replicated.
    """
    used: set[str] = set()
    entries = []
    for i, dim in enumerate(shape):
        logical = axes[i] if i < len(axes) else None
        entries.append(_resolve_dim(mesh, int(dim), logical, used))
    return NamedSharding(mesh, P(*entries))


def shard(x: jax.Array, *axes) -> jax.Array:
    """Constrain ``x`` to its logical-axis sharding under the ambient mesh.

    Identity when no mesh is installed (single-device runs) or when the
    constraint is invalid in the current tracing context (e.g. inside a
    fully-manual shard_map body, where collectives own the layout).
    """
    mesh = _mesh()
    if mesh is None:
        return x
    sharding = logical_spec(mesh, x.shape, *axes)
    try:
        return jax.lax.with_sharding_constraint(x, sharding)
    except Exception:
        return x


# -- parameter shardings -----------------------------------------------------
def _key_str(k) -> str:
    """Stringify one jax tree-path key (Dict/Attr/Sequence)."""
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# leaf-name patterns -> which dim carries the ``model`` axis. (-1 = last,
# 0 = first.) Output projections shard their *input* (contracting) dim so
# the preceding activation sharding is consumed without a reshard.
_MODEL_DIM_BY_NAME = {
    "table": 0,      # (V, d) embedding: vocab over model
    "router": -1,    # (d, E): experts over model
    "w_gate": -1, "w_up": -1, "w1": -1,
    "wq": -1, "wk": -1, "wv": -1, "w_in": -1,
    "w_down": 0, "wo": 0, "w2": 0, "w_out": 0,
}


def _param_spec(mesh: Mesh, pstr: str, shape, *, fsdp: bool) -> P:
    nd = len(shape)
    entries: list = [None] * nd
    model_ok = "model" in mesh.shape
    data_ok = "data" in mesh.shape
    name = pstr.rsplit("/", 1)[-1]

    if nd >= 2 and model_ok:
        m = mesh.shape["model"]
        dim = _MODEL_DIM_BY_NAME.get(name)
        if nd == 3 and "experts" in pstr:
            dim = 0  # stacked (E, din, dout): expert-parallel over model
        if dim is None:
            # fallback: largest divisible dim
            order = sorted(range(nd), key=lambda i: -shape[i])
            dim = next((i for i in order if shape[i] % m == 0), None)
        else:
            dim = dim % nd
            if shape[dim] % m != 0:
                dim = None
        if dim is not None:
            entries[dim] = "model"

    if fsdp and nd >= 2 and data_ok:
        d = mesh.shape["data"]
        order = sorted(range(nd), key=lambda i: -shape[i])
        for i in order:
            if entries[i] is None and shape[i] % d == 0:
                entries[i] = "data"
                break
    return P(*entries)


def param_shardings(params, mesh: Mesh, *, fsdp: bool = False):
    """NamedSharding tree for a parameter tree (arrays or ShapeDtypeStructs).

    2-D+ leaves shard over ``model`` by path-name convention; ``fsdp=True``
    additionally spreads the largest remaining dim over ``data``. Scalars,
    vectors (norm scales, biases) and non-divisible dims replicate.
    """
    def one(path, leaf):
        pstr = "/".join(_key_str(k) for k in path)
        return NamedSharding(mesh, _param_spec(mesh, pstr, leaf.shape,
                                               fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(one, params)
