"""Distribution substrate: logical-axis sharding rules + pipeline parallel.

``repro.dist.sharding`` — flax-style logical axis annotations resolved
against an ambient mesh (set_mesh / axis_rules); every model file annotates
activations with ``shard(x, "batch", "seq", ...)`` and the launcher derives
parameter/batch/cache shardings from the same rule table.

``repro.dist.pipeline`` — GPipe-style pipeline parallelism over a
``stage`` mesh axis (shard_map + ppermute rotation).
"""
from repro.dist import sharding  # noqa: F401
