"""GPipe pipeline parallelism over a ``stage`` mesh axis (DESIGN §5).

The layer stack is split into S contiguous stages; M microbatches rotate
through the stages with ``lax.ppermute`` inside a ``shard_map``. Tick t
runs every stage in parallel: stage s computes microbatch (t - s) if it
is in flight, then passes its activation to stage s+1. After
T = M + S - 1 ticks every microbatch has crossed every stage; the bubble
fraction (S-1)/T is the idle-tick share of the schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_pp_mesh(num_stages: int) -> Mesh:
    """1-D mesh whose only axis is ``stage``."""
    return jax.make_mesh((num_stages,), ("stage",))


def split_stages(params: jax.Array, num_stages: int) -> jax.Array:
    """(L, ...) stacked per-layer params -> (S, L/S, ...) stage blocks."""
    L = params.shape[0]
    if L % num_stages:
        raise ValueError(f"{L} layers not divisible into {num_stages} stages")
    return params.reshape((num_stages, L // num_stages) + params.shape[1:])


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """GPipe idle fraction: (S-1) / (M + S - 1)."""
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def gpipe_forward(stage_fn, mesh: Mesh, num_microbatches: int,
                  axis_name: str = "stage"):
    """Build fwd(stage_params, x) running ``stage_fn`` as a GPipe pipeline.

    ``stage_fn(block_params, x)`` applies one stage's layer block to one
    microbatch. ``stage_params``: (S, ...) pytree-leaf array split by
    ``split_stages``. ``x``: (M, mb, ...) microbatched input, replicated.
    Returns (M, mb, ...) outputs, numerically identical to applying all
    stages sequentially.
    """
    S = mesh.shape[axis_name]
    M = num_microbatches
    perm = [(i, (i + 1) % S) for i in range(S)]

    def body(params_local, x):
        # params_local: (1, L/S, ...) — this stage's block. x: (M, mb, ...)
        block = jax.tree_util.tree_map(lambda p: p[0], params_local)
        idx = jax.lax.axis_index(axis_name)
        outputs = jnp.zeros_like(x)
        carry = jnp.zeros_like(x[0])
        for t in range(M + S - 1):
            # stage 0 injects microbatch t (clamped; ticks t >= M feed a
            # dummy whose results never reach the last stage in time)
            inp = jnp.where(idx == 0, x[min(t, M - 1)], carry)
            out = stage_fn(block, inp)
            j = t - (S - 1)
            if j >= 0:
                outputs = outputs.at[j].set(
                    jnp.where(idx == S - 1, out, outputs[j]))
            carry = jax.lax.ppermute(out, axis_name, perm)
        # only the last stage holds real outputs; psum replicates them
        outputs = jnp.where(idx == S - 1, outputs, jnp.zeros_like(outputs))
        return jax.lax.psum(outputs, axis_name)

    fwd = shard_map(body, mesh=mesh,
                    in_specs=(P(axis_name), P()), out_specs=P(),
                    check_rep=False)

    def run(stage_params, x):
        return fwd(stage_params, x)

    return run
