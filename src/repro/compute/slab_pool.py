"""Device-resident bucket slab pool: one H2D transfer per cache residency.

The host ``BufferPool`` already guarantees each bucket is *read* once per
cache residency; this pool extends the same discipline one hop down the
pipeline: each bucket slab crosses H2D ONCE per residency, and every edge
that touches the bucket while it stays resident verifies against the
*already-resident* device operand instead of re-staging the slab.
Eviction mirrors the host cache schedule (the executor forwards its
scheduled ``evict`` calls), so device memory tracks the same
Belady-bounded working set the host budget allows — a re-load after
eviction is a new residency and pays one new transfer.

Transfer staging is *deferred*: ``operand`` takes a private host copy
(the source slab lives in a recyclable ``BufferPool`` slot) and the copy
rides the next fused kernel dispatch as a plain array argument. An eager
``jax.device_put`` here would synchronize with the in-flight previous
batch on single-stream backends and serialize the double buffer; on real
accelerators the argument transfer is the same async DMA. Once the batch
completes, the engine ``harvest``s the device-resident stack slice back
into the pool, so later batches pass a true device array.

Pending verify batches keep evicted slabs alive through their own
references (host copies and immutable JAX arrays alike), so eviction
never races an in-flight kernel — the device analogue of the host pool's
pin refcounts.
"""
from __future__ import annotations

import numpy as np


class DeviceSlabPool:
    """bucket id → device-resident (capacity, dim) float32 operand."""

    def __init__(self, stats=None, on_transfer=None, tracer=None):
        # bucket -> [device array | None, staged host copy | None]
        self._slabs: dict[int, list] = {}
        self.stats = stats
        self.tracer = tracer
        self.on_transfer = on_transfer  # e.g. emulated-link charge (bytes)
        self.transfers = 0       # H2D slab transfers (== residencies used)
        self.hits = 0            # operand lookups served pool-resident
        self.h2d_bytes = 0

    def __contains__(self, b: int) -> bool:
        return b in self._slabs

    @property
    def resident(self) -> int:
        return len(self._slabs)

    def operand(self, b: int, host_vecs: np.ndarray):
        """Operand for bucket ``b``: the harvested device array, or —
        on this residency's first touch — a freshly staged host copy
        whose transfer rides the next dispatch. ``host_vecs`` must be
        the bucket's full padded slab (only consulted on a miss)."""
        ent = self._slabs.get(b)
        if ent is not None:
            self.hits += 1
            if self.stats is not None:
                self.stats.add("device_slab_hits", 1)
                self.stats.add("h2d_transfers_saved", 1)
            return ent[0] if ent[0] is not None else ent[1]
        host = np.array(host_vecs, np.float32)
        self._slabs[b] = [None, host]
        self.transfers += 1
        self.h2d_bytes += int(host.nbytes)
        if self.stats is not None:
            self.stats.add("h2d_transfers", 1)
            self.stats.add("h2d_bytes", int(host.nbytes))
        if self.tracer is not None:
            self.tracer.instant("h2d.stage", bucket=b,
                                bytes=int(host.nbytes))
        if self.on_transfer is not None:
            self.on_transfer(int(host.nbytes))
        return host

    def current(self, b: int):
        """Freshest operand for a resident bucket (device array once
        harvested, else the staged host copy), or None if not resident —
        dispatchers re-query this at flush so batches staged before a
        harvest still pass the device-resident array."""
        ent = self._slabs.get(b)
        if ent is None:
            return None
        return ent[0] if ent[0] is not None else ent[1]

    def needs_harvest(self, b: int) -> bool:
        ent = self._slabs.get(b)
        return ent is not None and ent[0] is None

    def harvest(self, b: int, dev) -> None:
        """Install the device-resident array for a staged bucket (the
        engine slices it out of a completed batch's stacked operand).
        The host staging copy is dropped — later batches pass ``dev``."""
        ent = self._slabs.get(b)
        if ent is not None and ent[0] is None:
            ent[0] = dev
            ent[1] = None

    def evict(self, b: int) -> None:
        """Mirror a host-cache eviction. In-flight batches that captured
        the operand keep it alive; the next residency transfers afresh."""
        self._slabs.pop(b, None)

    def clear(self) -> None:
        self._slabs.clear()
