"""Device-side verify pipeline (ROADMAP "device-side staging").

Extends the I/O pipeline one hop past the host cache: bucket slabs cross
H2D once per cache residency (``DeviceSlabPool``), verify batches are
dispatched double-buffered, and the kernel returns compacted
(row, col, distance) triples instead of full (E, cap, cap) masks
(``DeviceVerifyEngine``). ``HostVerifyEngine`` is the reference host
path; both produce byte-identical results and are selected by
``JoinConfig.compute_mode``. See README.md for the staging pipeline and
slab-pool lifecycle.
"""
from repro.compute.engine import (PAIR_CAP_INIT, DeviceVerifyEngine,
                                  HostVerifyEngine, RoutedVerifyEngine,
                                  compact_pairs, make_verify_engine,
                                  next_pow2, query_verify_compact)
from repro.compute.slab_pool import DeviceSlabPool

__all__ = ["DeviceSlabPool", "DeviceVerifyEngine", "HostVerifyEngine",
           "PAIR_CAP_INIT", "RoutedVerifyEngine", "compact_pairs",
           "make_verify_engine", "next_pow2", "query_verify_compact"]
