"""Verify engines: the executor's batched pair-verification backends.

Both engines replay the same edge stream and produce byte-identical
(pairs, distances) — they differ only in where operands live and where
pair extraction happens (``JoinConfig.compute_mode``):

``HostVerifyEngine`` ("host")
    Stages each batch's operand slabs into a pinned host buffer, runs ONE
    batched kernel dispatch (Pallas grid or vmapped reference — shared
    path, ``kernels.ops.verify_pairs_batch``), fetches the full
    (E, cap, cap) d2/mask arrays and extracts pairs with numpy. Padded
    batch lanes are *masked out* (sliced away per edge), never filled by
    replaying edge 0; partial flushes dispatch at the next power-of-two
    lane count, so a 3-edge final flush pays a 4-lane kernel, not a
    ``verify_batch``-lane one.

``DeviceVerifyEngine`` ("device")
    Operands come from a ``DeviceSlabPool`` that mirrors the host cache
    schedule — each bucket slab crosses H2D once per cache residency, and
    every further edge reference is a ``device_slab_hit``. Host checkout
    pins are released at enqueue (the pool holds an independent copy), so
    pending batches never hold host pool slabs. Dispatch is
    double-buffered: batch k is issued as ONE asynchronous fused jit
    (in-program stack → kernel → compaction; first-touch slabs ride the
    dispatch as plain arguments) and the engine issues no eager device
    work until batch k's results are collected at the head of flush k+1 —
    so the entire enqueue/walk/staging of batch k+1 overlaps batch k's
    kernel (``d2h_overlap_s``). The kernel returns compacted
    (row, col, distance) triples via an on-device mask → prefix-sum →
    gather compaction, so the host never materializes an (E, cap, cap)
    mask and never re-derives sqrt distances.

Distance parity: both modes take d² from the same jitted program and
apply an IEEE float32 sqrt (numpy on host, XLA on device) — bitwise
identical. Pair order parity: the compaction scatter walks the mask in
row-major flat order, exactly ``np.nonzero``'s order.

The compaction capacity (pairs per edge) adapts: a batch whose densest
edge overflows the current capacity is re-compacted from its still-
resident d2/mask at the next power of two (the kernel output was sized
too small, not wrong), and the larger capacity sticks for later batches.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compute.slab_pool import DeviceSlabPool
from repro.kernels import ops as kops
from repro.obs import get_tracer

PAIR_CAP_INIT = 1024  # initial per-edge compaction capacity (pairs)


def next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("k_cap",))
def compact_pairs(d2: jax.Array, mask: jax.Array, na: jax.Array,
                  nb: jax.Array, intra: jax.Array, k_cap: int):
    """On-device pair compaction: mask → prefix-sum → gather.

    d2/mask: (E, M, N); na/nb: (E,) int32 live-row counts (0 kills a
    padded batch lane); intra: (E,) bool — keep strictly-upper pairs only
    (self-join bucket-vs-itself edges). Returns (counts (E,) int32,
    rows (E, k_cap) int32, cols (E, k_cap) int32, dists (E, k_cap) f32);
    entries past an edge's count are zeros, pairs past ``k_cap`` are
    dropped (the caller detects counts > k_cap and re-compacts larger).
    """
    E, M, N = d2.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (M, N), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (M, N), 1)
    live = ((rows[None] < na[:, None, None])
            & (cols[None] < nb[:, None, None]))
    tri = (~intra)[:, None, None] | (rows[None] < cols[None])
    m = mask & live & tri
    flat = m.reshape(E, M * N)
    counts = jnp.sum(flat, axis=1, dtype=jnp.int32)
    # prefix-sum + binary search: the j-th pair's flat position is the
    # first index where the running count reaches j+1 — row-major flat
    # order == np.nonzero extraction order (host parity). k_cap·log(M·N)
    # searches vectorize where an XLA scatter would serialize per update
    # and a full sort would pay M·N·log(M·N).
    cs = jnp.cumsum(flat, axis=1, dtype=jnp.int32)
    ks = jnp.arange(1, k_cap + 1, dtype=jnp.int32)
    order = jax.vmap(lambda c: jnp.searchsorted(c, ks, side="left"))(cs)
    valid = ks[None, :] <= counts[:, None]
    order = jnp.minimum(order, M * N - 1)  # clamp past-count sentinels
    out_r = jnp.where(valid, (order // N).astype(jnp.int32), 0)
    out_c = jnp.where(valid, (order % N).astype(jnp.int32), 0)
    out_d2 = jnp.where(
        valid, jnp.take_along_axis(d2.reshape(E, M * N), order, axis=1),
        0.0)
    return counts, out_r, out_c, jnp.sqrt(out_d2)


@functools.partial(jax.jit, static_argnames=("eps", "k_cap", "use_pallas"))
def device_verify(na, nb, intra, *slabs, eps: float, k_cap: int,
                  use_pallas: bool = False):
    """Fused verify + compaction over individually-resident slabs.

    ``slabs`` is the batch's 2B operand slabs (u lanes then v lanes) as
    separate arguments: the (B, cap, d) stack happens INSIDE the program,
    so the whole batch is ONE asynchronous dispatch — an eager
    ``jnp.stack`` would synchronize with the in-flight previous batch
    and stall the double buffer. First-touch slabs may arrive as numpy
    arrays (their H2D rides the dispatch).
    """
    B = len(slabs) // 2
    u = jnp.stack(slabs[:B])
    v = jnp.stack(slabs[B:])
    d2, mask = kops.verify_pairs_batch(u, v, eps, use_pallas=use_pallas)
    counts, out_r, out_c, out_d = compact_pairs(d2, mask, na, nb, intra,
                                                k_cap)
    # the stacked operands come back as outputs so the engine can harvest
    # first-touch lanes into the device slab pool once the batch lands
    return counts, out_r, out_c, out_d, u, v


@functools.partial(jax.jit, static_argnames=("eps2", "k_cap"))
def query_verify_compact(q_block: jax.Array, qidx: jax.Array, nq,
                         slab: jax.Array, eps2: float, k_cap: int):
    """Online point-query verify (``DiskJoinIndex.execute_probes``,
    ``compute_mode="device"``): the wave's query block is staged on-device
    ONCE and each probed bucket's verify gathers its member rows from it.
    ``qidx`` is pow2-padded (bounded recompiles); ``nq`` live entries —
    padded rows repeat query 0 and are masked out by the row count.
    Returns compacted (counts (1,), q-rows, cols, distances) against the
    (capacity, dim) bucket slab."""
    qs = jnp.take(q_block, qidx, axis=0)             # (Qp, d)
    from repro.kernels import ref
    d2 = ref.pairwise_l2(qs, slab)[None]             # (1, Qp, cap)
    na = jnp.reshape(nq, (1,)).astype(jnp.int32)
    nb = jnp.full((1,), slab.shape[0], jnp.int32)
    intra = jnp.zeros((1,), bool)
    return compact_pairs(d2, d2 <= eps2, na, nb, intra, k_cap)


class _EngineBase:
    """Shared bookkeeping: edge accounting and result accumulation."""

    def __init__(self, cache, *, epsilon: float, capacity_rows: int,
                 dim: int, verify_batch: int, use_pallas: bool = False,
                 attribute_mask: np.ndarray | None = None, pstats=None,
                 xfer_gb_s: float = 0.0, tracer=None):
        self.cache = cache
        self.eps = float(epsilon)
        self.cap = int(capacity_rows)
        self.dim = int(dim)
        self.verify_batch = max(1, int(verify_batch))
        self.use_pallas = bool(use_pallas)
        self.attribute_mask = attribute_mask
        self.pstats = pstats
        self.tracer = tracer if tracer is not None else get_tracer()
        self.xfer_gb_s = float(xfer_gb_s)
        self.dc = 0              # distance computations (live pairs)
        self.compute_s = 0.0     # engine wall time in stage/dispatch/extract
        self.pairs_out: list[np.ndarray] = []
        self.dists_out: list[np.ndarray] = []

    def _count_dc(self, na: int, nb: int, intra: bool) -> None:
        self.dc += na * (na - 1) // 2 if intra else na * nb

    def _stat(self, field: str, amount) -> None:
        if self.pstats is not None:
            self.pstats.add(field, amount)

    def _charge_link(self, nbytes: int) -> None:
        """Emulated host↔device link cost (``emulate_xfer_gb_s``) — the
        transfer-volume analogue of the store's emulated read latency.
        Traced as a ``link.xfer`` span (bytes arg) so the live
        calibrator can derive an observed GB/s for the cost model."""
        if self.xfer_gb_s > 0 and nbytes > 0:
            if self.tracer.enabled:
                t0 = time.perf_counter()
                time.sleep(nbytes / (self.xfer_gb_s * 1e9))
                self.tracer.complete("link.xfer", t0,
                                     time.perf_counter() - t0,
                                     bytes=int(nbytes))
            else:
                time.sleep(nbytes / (self.xfer_gb_s * 1e9))

    def results(self) -> tuple[list[np.ndarray], list[np.ndarray]]:
        return self.pairs_out, self.dists_out

    def evict(self, b: int) -> None:  # device engine overrides
        pass

    def set_verify_batch(self, n: int) -> None:
        """Planner hook: retune the flush threshold between enqueues
        (per schedule region). Only the threshold moves — a pending
        batch larger than the new value flushes at the next enqueue."""
        self.verify_batch = max(1, int(n))

    def set_route(self, route: str) -> None:
        """Planner hook: a single-mode engine ignores routing (the
        routed wrapper overrides)."""

    @property
    def pending(self) -> bool:
        raise NotImplementedError


class HostVerifyEngine(_EngineBase):
    """Host staging + full-mask fetch (the reference compute path)."""

    def __init__(self, cache, **kw):
        super().__init__(cache, **kw)
        self._u = np.empty((self.verify_batch, self.cap, self.dim),
                           np.float32)
        self._v = np.empty_like(self._u)
        self._batch: list[tuple] = []  # (entry_a, entry_b, intra)

    def set_verify_batch(self, n: int) -> None:
        # the staging buffers were sized at construction: a larger plan
        # batch clamps to the allocation rather than reallocating
        self.verify_batch = max(1, min(int(n), self._u.shape[0]))

    @property
    def pending(self) -> bool:
        return bool(self._batch)

    def enqueue(self, bu: int, bv: int, intra: bool) -> None:
        self._batch.append((self.cache.checkout(bu),
                            self.cache.checkout(bv), intra))
        if len(self._batch) >= self.verify_batch:
            self.flush()

    def flush(self) -> None:
        if not self._batch:
            return
        with self.tracer.span("verify.flush", edges=len(self._batch)):
            self._flush()

    def _flush(self) -> None:
        t0 = time.perf_counter()
        E = len(self._batch)
        # partial flushes dispatch at the next pow2 lane count; lanes past
        # E hold stale staging content and are masked out by the per-edge
        # extraction below (no edge-0 replay, no duplicate verification).
        # Clamp to the staging allocation, not the current threshold — a
        # planner region switch may shrink the threshold below a batch
        # accumulated under the previous region's (larger) one.
        B = min(self._u.shape[0], next_pow2(E))
        for i, (ea, eb, _) in enumerate(self._batch):
            self._u[i] = ea[0]
            self._v[i] = eb[0]
        u = jnp.asarray(self._u[:B])
        v = jnp.asarray(self._v[:B])
        staged = 2 * B * self.cap * self.dim * 4
        self._stat("h2d_transfers", 2)
        self._stat("h2d_bytes", staged)
        self._charge_link(staged)
        d2, mask = kops.verify_pairs_batch(u, v, self.eps,
                                           use_pallas=self.use_pallas)
        d2 = np.asarray(d2)
        masks = np.asarray(mask)
        self._stat("d2h_bytes", d2.nbytes + masks.nbytes)
        self._charge_link(d2.nbytes + masks.nbytes)
        attr = self.attribute_mask
        for i, (ea, eb, intra) in enumerate(self._batch):
            na, nb = ea[2], eb[2]
            m = masks[i][:na, :nb]
            if intra:
                m = np.triu(m, k=1)
            self._count_dc(na, nb, intra)
            if attr is not None:
                # slice to the live rows: prefetch-mode id slabs are
                # capacity-padded with -1 past each bucket's rows
                m = m & attr[ea[1][:na]][:, None] & attr[eb[1][:nb]][None, :]
            rows, cols = np.nonzero(m)
            if rows.size:
                d = np.sqrt(d2[i][rows, cols])
                self.pairs_out.append(
                    np.stack([ea[1][rows], eb[1][cols]],
                             axis=1).astype(np.int64))
                self.dists_out.append(d.astype(np.float32))
        for ea, eb, _ in self._batch:  # drop the batch's slab pins
            self.cache.release(ea)
            self.cache.release(eb)
        self._batch.clear()
        self.compute_s += time.perf_counter() - t0

    def finish(self) -> None:
        self.flush()

    def abort(self) -> None:
        # an exception mid-run leaves checkout pins in the pending batch;
        # on a shared (session) pool they would leak for the session's
        # lifetime and starve the next join's liveness floor
        for ea, eb, _ in self._batch:
            self.cache.release(ea)
            self.cache.release(eb)
        self._batch.clear()


class DeviceVerifyEngine(_EngineBase):
    """Device-resident operands + double-buffered compacted dispatch."""

    def __init__(self, cache, **kw):
        pair_cap = kw.pop("pair_cap", None)
        super().__init__(cache, **kw)
        # slab transfers accrue link debt paid in one sleep per flush:
        # hundreds of sub-millisecond sleeps would each round up to the
        # OS timer slack and dwarf the modeled cost
        self._link_debt = 0
        self.pool = DeviceSlabPool(self.pstats,
                                   on_transfer=self._defer_link_charge,
                                   tracer=self.tracer)
        self._batch: list[tuple] = []
        self._inflight: tuple | None = None
        # start the compaction capacity at ~8 pairs per slab row: dense
        # enough that overflow re-compaction (and its recompile) is rare,
        # small enough that the compacted D2H stays ≪ the full mask
        cap2 = self.cap * self.cap
        self.pair_cap = min(
            next_pow2(pair_cap or max(PAIR_CAP_INIT, 8 * self.cap)), cap2)

    @property
    def pending(self) -> bool:
        # only a staged (undispatched) batch counts: in-flight batches
        # hold no host pins, so a stall-flush has nothing to release
        return bool(self._batch)

    def evict(self, b: int) -> None:
        self.pool.evict(b)

    def enqueue(self, bu: int, bv: int, intra: bool) -> None:
        ea = self.cache.checkout(bu)
        eb = self.cache.checkout(bv)
        try:
            da = self.pool.operand(bu, ea[0])
            db = self.pool.operand(bv, eb[0])
            # id sidecars live in recyclable pool slots: copy the live
            # rows so the pins can drop now (the pool operand is already
            # an independent copy)
            meta = (np.array(ea[1][:ea[2]]), ea[2],
                    np.array(eb[1][:eb[2]]), eb[2], intra)
        finally:
            self.cache.release(ea)
            self.cache.release(eb)
        self._batch.append((da, db, bu, bv, meta))
        if len(self._batch) >= self.verify_batch:
            self.flush()

    def flush(self) -> None:
        """Collect the in-flight batch, then dispatch the staged one
        asynchronously. Between this dispatch and the next collect the
        engine issues NO eager device work — on single-stream backends
        any eager op would synchronize with the running kernel — so the
        whole enqueue/walk of the next batch overlaps this one's kernel
        (double buffering)."""
        if not self._batch:
            return
        if self._link_debt:
            # pay accrued transfer debt while the previous batch's kernel
            # is still in flight — on real hardware the DMA overlaps
            # compute, so the modeled link time overlaps it here too
            self._charge_link(self._link_debt)
            self._link_debt = 0
        self._collect()        # previous batch; drains the device queue
        self._dispatch()

    def _dispatch(self) -> None:
        span = self.tracer.span("verify.dispatch", edges=len(self._batch))
        span.__enter__()
        t0 = time.perf_counter()
        E = len(self._batch)
        # pow2 of the actual batch, never below it: the threshold may
        # have been retuned (planner region switch) below the pending E
        B = next_pow2(E)

        def fresh(b, captured):
            # operands were captured at enqueue, possibly before the
            # previous batch's harvest: re-query the pool so a bucket
            # harvested since then rides as a device array instead of
            # re-transferring its staged host copy
            cur = self.pool.current(b)
            return captured if cur is None else cur

        ops_u = [fresh(bu, da) for da, _, bu, _, _ in self._batch]
        ops_v = [fresh(bv, db) for _, db, _, bv, _ in self._batch]
        slabs = (ops_u + [ops_u[0]] * (B - E)
                 + ops_v + [ops_v[0]] * (B - E))
        # na = nb = 0 masks the pad lanes out inside the compaction
        na = np.zeros(B, np.int32)
        nb = np.zeros(B, np.int32)
        intra = np.zeros(B, bool)
        metas = []
        harvest: list[tuple[int, int, int]] = []  # (bucket, side, lane)
        staged: set[int] = set()
        for i, (_, _, bu, bv, (ids_a, n_a, ids_b, n_b, is_intra)) \
                in enumerate(self._batch):
            na[i], nb[i], intra[i] = n_a, n_b, is_intra
            metas.append((ids_a, ids_b))
            self._count_dc(n_a, n_b, is_intra)
            if bu not in staged and self.pool.needs_harvest(bu):
                harvest.append((bu, 0, i))
                staged.add(bu)
            if bv not in staged and self.pool.needs_harvest(bv):
                harvest.append((bv, 1, i))
                staged.add(bv)
        k_cap = self.pair_cap
        out = device_verify(na, nb, intra, *slabs, eps=self.eps,
                            k_cap=k_cap, use_pallas=self.use_pallas)
        self._batch.clear()
        self._stat("device_batches", 1)
        self._inflight = (out, slabs, na, nb, intra, metas, harvest,
                          k_cap, time.perf_counter())
        self.compute_s += time.perf_counter() - t0
        span.__exit__(None, None, None)

    def _defer_link_charge(self, nbytes: int) -> None:
        self._link_debt += nbytes

    def _collect(self) -> None:
        if self._inflight is None:
            return
        (out, slabs, na, nb, intra, metas, harvest, k_cap,
         t_dispatch) = self._inflight
        self._inflight = None
        span = self.tracer.span("verify.collect")
        span.__enter__()
        t0 = time.perf_counter()
        # host time since dispatch ran concurrently with the kernel
        self._stat("d2h_overlap_s", max(0.0, t0 - t_dispatch))
        counts = np.asarray(out[0])
        top = int(counts.max()) if counts.size else 0
        if top > k_cap:
            # capacity overflow: the kernel output was sized too small,
            # not wrong — re-dispatch at the next pow2, which sticks
            k_cap = min(next_pow2(top), self.cap * self.cap)
            self.pair_cap = max(self.pair_cap, k_cap)
            self._stat("device_compact_overflows", 1)
            self.tracer.instant("verify.overflow", top=top, k_cap=k_cap)
            out = device_verify(na, nb, intra, *slabs, eps=self.eps,
                                k_cap=k_cap, use_pallas=self.use_pallas)
            counts = np.asarray(out[0])
        # the queue is idle now: slice first-touch lanes out of the
        # stacked operands into the pool (device-resident for later
        # batches of this residency)
        for b, side, lane in harvest:
            self.pool.harvest(b, out[4 + side][lane])
        rows = np.asarray(out[1])
        cols = np.asarray(out[2])
        dists = np.asarray(out[3])
        fetched = counts.nbytes + rows.nbytes + cols.nbytes + dists.nbytes
        self._stat("d2h_bytes", fetched)
        self._charge_link(fetched)
        attr = self.attribute_mask
        for i, (ids_a, ids_b) in enumerate(metas):
            k = int(counts[i])
            if k == 0:
                continue
            pa = ids_a[rows[i, :k]]
            pb = ids_b[cols[i, :k]]
            d = dists[i, :k]
            if attr is not None:
                keep = attr[pa] & attr[pb]
                pa, pb, d = pa[keep], pb[keep], d[keep]
                if pa.size == 0:
                    continue
            self.pairs_out.append(np.stack([pa, pb], axis=1)
                                  .astype(np.int64))
            self.dists_out.append(d.astype(np.float32))
        self.compute_s += time.perf_counter() - t0
        span.__exit__(None, None, None)

    def finish(self) -> None:
        self.flush()
        self._collect()

    def abort(self) -> None:
        self._batch.clear()
        self._inflight = None
        self.pool.clear()


class RoutedVerifyEngine:
    """Mixed host/device routing under one engine surface.

    The planner's ``JoinPlan`` may route each verify unit to whichever
    path models cheaper; this wrapper owns one engine of each kind and
    forwards every enqueue to the route selected via ``set_route``
    (called by the executor from the plan cursor, immediately before the
    enqueue). Cache evictions reach both engines — the device slab pool
    must mirror the host cache schedule even for buckets whose edges all
    ran host-side — and results concatenate: duplicate pairs across the
    two engines carry byte-identical distances (both paths take d² from
    the same jitted program + IEEE f32 sqrt), so the executor's
    ``dedup_pairs`` is order-insensitive and planner-on results stay
    byte-identical to single-engine runs.
    """

    def __init__(self, host: HostVerifyEngine, device: DeviceVerifyEngine):
        self.host = host
        self.device = device
        self._target = host

    def set_route(self, route: str) -> None:
        self._target = self.device if route == "device" else self.host

    def set_verify_batch(self, n: int) -> None:
        self._target.set_verify_batch(n)

    def enqueue(self, bu: int, bv: int, intra: bool) -> None:
        self._target.enqueue(bu, bv, intra)

    def flush(self) -> None:
        self.host.flush()
        self.device.flush()

    def finish(self) -> None:
        self.host.finish()
        self.device.finish()

    def abort(self) -> None:
        self.host.abort()
        self.device.abort()

    def evict(self, b: int) -> None:
        self.host.evict(b)
        self.device.evict(b)

    @property
    def pending(self) -> bool:
        return self.host.pending or self.device.pending

    @property
    def dc(self) -> int:
        return self.host.dc + self.device.dc

    @property
    def compute_s(self) -> float:
        return self.host.compute_s + self.device.compute_s

    def results(self) -> tuple[list[np.ndarray], list[np.ndarray]]:
        hp, hd = self.host.results()
        dp, dd = self.device.results()
        return hp + dp, hd + dd


def make_verify_engine(config, cache, capacity_rows: int, dim: int,
                       attribute_mask=None, pstats=None, tracer=None,
                       plan=None):
    """Engine per ``JoinConfig.compute_mode`` ("host" | "device"), or per
    the ``JoinPlan``'s resolved routing when one is supplied: the plan's
    ``pair_cap`` seeds the device compaction capacity, and a "mixed"
    plan gets a ``RoutedVerifyEngine`` wrapping one engine of each kind.
    """
    kw = dict(epsilon=float(config.epsilon), capacity_rows=capacity_rows,
              dim=dim, verify_batch=int(config.verify_batch),
              use_pallas=bool(config.use_pallas),
              attribute_mask=attribute_mask, pstats=pstats,
              tracer=tracer, xfer_gb_s=float(config.emulate_xfer_gb_s))
    mode = plan.compute_mode if plan is not None else config.compute_mode
    pair_cap = plan.pair_cap if plan is not None else None
    if mode == "mixed":
        return RoutedVerifyEngine(
            HostVerifyEngine(cache, **kw),
            DeviceVerifyEngine(cache, pair_cap=pair_cap, **kw))
    if mode == "device":
        return DeviceVerifyEngine(cache, pair_cap=pair_cap, **kw)
    return HostVerifyEngine(cache, **kw)
