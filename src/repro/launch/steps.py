"""Lowerable step functions + their sharding trees.

``make_train_step``: loss → grads → AdamW update, one jit-able function.
``make_serve_step``: one decode step against the full KV/state cache.
``sharding trees``: params by path pattern, batch/caches by logical axes,
with the decode-time ``cache_seq`` override (sequence-sharded flash-decode,
DESIGN §5).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist import sharding as shd
from repro.models import encdec
from repro.models.model_api import ModelBundle
from repro.train.optimizer import AdamW, AdamWConfig


def make_train_step(bundle: ModelBundle, opt: AdamW):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            bundle.loss, has_aux=True)(params, batch)
        new_params, new_state, opt_metrics = opt.update(grads, opt_state,
                                                        params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_serve_step(bundle: ModelBundle):
    def serve_step(params, caches, tokens):
        logits, new_caches = bundle.decode(params, tokens, caches)
        return logits, new_caches

    return serve_step


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------
def batch_shardings(mesh, specs: dict) -> dict:
    out = {}
    for k, v in specs.items():
        if k in ("tokens", "labels"):
            axes = ["batch"] + [None] * (len(v.shape) - 1)
        elif k in ("patches", "frames"):
            axes = ["batch", None, None]
        else:
            axes = [None] * len(v.shape)
        out[k] = shd.logical_spec(mesh, v.shape, *axes)
    return out


def cache_shardings(mesh, cache_tree):
    """Logical axes per cache leaf, keyed on path names."""
    def one(path, leaf):
        pstr = "/".join(shd._key_str(k) for k in path)
        shape = leaf.shape
        nd = len(shape)
        if "cross_k" in pstr or "cross_v" in pstr:
            axes = ["batch", None, "kv_heads", None]      # (B, F, H, D)
        elif pstr.endswith("/k") or pstr.endswith("/v"):
            axes = ["batch", "cache_seq", "kv_heads", None]
        elif pstr.endswith("kpos") or pstr.endswith("pos"):
            axes = []
        elif pstr.endswith("state") and nd >= 4:
            axes = ["batch", "mlp", None, None]           # ssm (B,H,P,N)
        elif pstr.endswith("state"):
            axes = ["batch", "mlp"]                       # rglru (B,W)
        elif pstr.endswith("conv"):
            axes = ["batch", None, "mlp"]
        else:
            axes = []
        full = [None] * (nd - len(axes)) + axes           # stacked dims lead
        return shd.logical_spec(mesh, shape, *full)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def opt_state_shardings(mesh, opt_state, params_shardings):
    def like_params(tree):
        return jax.tree_util.tree_map(lambda s: s, params_shardings)

    out = {"mu": _retype(params_shardings),
           "nu": _retype(params_shardings),
           "step": NamedSharding(mesh, P())}
    if "error" in opt_state:
        out["error"] = _retype(params_shardings)
    return out


def _retype(tree):
    return jax.tree_util.tree_map(lambda s: s, tree)


# ---------------------------------------------------------------------------
# full lowering helper (used by dryrun + launcher)
# ---------------------------------------------------------------------------
def lower_cell(bundle: ModelBundle, shape: ShapeSpec, mesh,
               *, fsdp: bool = False, remat: bool = True,
               donate: bool = True, extra_rules: Optional[dict] = None):
    """Lower train_step or serve_step for (arch × shape) on ``mesh``.

    Returns (lowered, aux_info). Uses ShapeDtypeStructs throughout — no
    device allocation.
    """
    cfg = bundle.cfg
    rules = {}
    if shape.kind == "decode":
        rules["cache_seq"] = (("data", "model") if shape.global_batch == 1
                              else ("model",))
    if extra_rules:
        rules.update(extra_rules)
    with shd.axis_rules(**rules):
        shd.set_mesh(mesh)
        try:
            params_shapes = jax.eval_shape(
                bundle.init, jax.random.PRNGKey(0))
            p_shards = shd.param_shardings(params_shapes, mesh, fsdp=fsdp)
            specs = bundle.input_specs(shape)
            b_shards = batch_shardings(mesh, specs)

            if shape.kind == "train":
                opt = AdamW(AdamWConfig())
                opt_shapes = jax.eval_shape(opt.init, params_shapes)
                o_shards = opt_state_shardings(mesh, opt_shapes, p_shards)
                step = make_train_step(bundle, opt)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shards, o_shards, b_shards),
                    out_shardings=(p_shards, o_shards, None),
                    donate_argnums=(0, 1) if donate else ())
                lowered = jitted.lower(params_shapes, opt_shapes, specs)
                return lowered, {"kind": "train_step"}

            if shape.kind == "prefill":
                jitted = jax.jit(bundle.prefill,
                                 in_shardings=(p_shards, b_shards))
                lowered = jitted.lower(params_shapes, specs)
                return lowered, {"kind": "prefill_step"}

            # decode
            cache_shapes = _cache_shapes(bundle, shape)
            c_shards = cache_shardings(mesh, cache_shapes)
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            t_shard = shd.logical_spec(mesh, tok.shape, "batch", None)
            step = make_serve_step(bundle)
            jitted = jax.jit(
                step,
                in_shardings=(p_shards, c_shards, t_shard),
                out_shardings=(None, c_shards),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_shapes, cache_shapes, tok)
            return lowered, {"kind": "serve_step"}
        finally:
            shd.set_mesh(None)


def _cache_shapes(bundle: ModelBundle, shape: ShapeSpec):
    cfg = bundle.cfg
    b = shape.global_batch
    if cfg.enc_dec:
        return jax.eval_shape(functools.partial(
            _encdec_cache, bundle, b, shape.seq_len))
    return jax.eval_shape(functools.partial(
        bundle.init_cache, b, shape.seq_len))


def _encdec_cache(bundle: ModelBundle, batch: int, max_seq: int):
    from repro.models.model_api import _encdec_cache_eval
    return _encdec_cache_eval(bundle, batch, max_seq)
