"""HLO-text cost pass with loop-trip-count multiplication.

XLA's HloCostAnalysis (surfaced via ``compiled.cost_analysis()``) counts
each while-loop body ONCE — with scan-over-layers and chunked attention
that undercounts FLOPs by the full layer count. The optimized HLO, however,
carries ``backend_config={"known_trip_count":{"n":...}}`` on while ops and
names their body computations, so this module re-derives per-device totals
by walking the call graph:

  total(comp) = local(comp) + Σ_callsite total(callee) × trip_multiplier

Counted per computation:
  * dot/convolution FLOPs (2 × |result| × contraction size),
  * HBM-boundary bytes: operands + results of fusions, dots, copies,
    parameters/constants feeding the entry (an *estimate* of traffic at
    fusion boundaries — the roofline memory term's numerator),
  * collective bytes by kind (ring-model traffic, see hlo_analysis).

This is structural analysis of the compiled artifact — the "profile" the
perf loop iterates on (no real TPU in this container).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))\s+"
    r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALL_SINGLE_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_CALL_LIST_RE = re.compile(
    r"(?:calls|branch_computations)=\{([^}]*)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _parse_shapes(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype,
                    [int(d) for d in dims.split(",") if d] if dims else []))
    return out


def _shape_bytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * (math.prod(dims) if dims else 1)
               for dt, dims in _parse_shapes(type_str))


def _shape_elems(type_str: str) -> int:
    return sum(math.prod(dims) if dims else 1
               for _, dims in _parse_shapes(type_str))


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    result_type: str
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes_lo: float = 0.0
    bytes_hi: float = 0.0
    region_bytes_lo: float = 0.0   # ops inside jax.named_scope regions
    region_flops: float = 0.0      # tagged "flash_attn_region"
    collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    calls: list = dataclasses.field(default_factory=list)
    # calls: (callee_name, multiplier)


def _split_computations(text: str) -> dict[str, list[str]]:
    """Computation headers start at column 0: ``[ENTRY] %name (...) -> ... {``
    (parameter lists may contain nested parens — match structurally)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if (line and not line[0].isspace() and "->" in line
                and line.rstrip().endswith("{")):
            tok = line.split()[0]
            if tok == "ENTRY":
                tok = line.split()[1]
            cur = tok.lstrip("%")
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _split_args(argstr: str) -> list[str]:
    """Split an HLO operand list on top-level commas only (shapes carry
    commas inside ``[...]``/``{...}``)."""
    parts: list[str] = []
    depth = 0
    cur: list[str] = []
    for ch in argstr:
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
            continue
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _operand_type(tok: str, shapes: dict[str, str]) -> str:
    """Type string of one operand token. Newer HLO prints the type inline
    (``f32[128,64]{1,0} %name``); older prints just ``%name`` — fall back
    to the shape table built from earlier op results."""
    m = _SHAPE_RE.search(tok)
    if m and m.group(1) in _DTYPE_BYTES:
        return tok
    name = tok.split()[-1].lstrip("%") if tok else ""
    return shapes.get(name, "")


_CALL_HEAD_RE = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+[\w\-]+\(")


def _op_args(line: str) -> list[str]:
    """Operand tokens of an op line, with balanced-paren extraction so
    tuple-typed inline operands survive (a ``[^)]*`` cut would not)."""
    m = _CALL_HEAD_RE.search(line)
    if not m:
        return []
    start = m.end()
    depth = 1
    i = start
    while i < len(line) and depth:
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
        i += 1
    if depth:
        return []
    return _split_args(line[start:i - 1])


def _dot_flops(line: str, result_type: str,
               shapes: dict[str, str]) -> float:
    args = _op_args(line)
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if m and args:
        parsed = _parse_shapes(_operand_type(args[0], shapes))
        if parsed:
            dims = parsed[0][1]
            for di in m.group(1).split(","):
                if di and int(di) < len(dims):
                    contract *= dims[int(di)]
    return 2.0 * _shape_elems(result_type) * contract


def _conv_flops(line: str, result_type: str, shapes: dict[str, str]) -> float:
    args = _op_args(line)
    kernel_elems = 1
    if len(args) >= 2:
        parsed = _parse_shapes(_operand_type(args[1], shapes))
        if parsed:
            kernel_elems = math.prod(parsed[0][1] or [1])
    return 2.0 * _shape_elems(result_type) * max(1, kernel_elems // 1)


def _collective_traffic(kind: str, nbytes: int, line: str,
                        default_group: int) -> float:
    n = default_group
    m = _GROUPS_ARR_RE.search(line)
    if m:
        n = int(m.group(2))
    else:
        m = _GROUPS_RE.search(line)
        if m:
            first = m.group(1).split("},{")[0]
            n = max(1, first.count(",") + 1)
    frac = (n - 1) / n if n > 1 else 0.0
    if kind == "all-reduce":
        return 2 * nbytes * frac
    if kind == "collective-permute":
        return float(nbytes)
    return nbytes * frac


# bytes_lo: traffic that survives even perfect fusion — matmul operand
# streaming, data-movement ops, collectives. bytes_hi adds every elementwise
# /layout op at CPU-HLO fusion granularity (an upper bound: the TPU compiler
# fuses most of these chains). The roofline memory term is reported as the
# [lo, hi] bracket; see EXPERIMENTS §Roofline.
_BYTES_LO_OPS = {"dot", "convolution", "copy", "gather", "scatter",
                 "dynamic-update-slice", "dynamic-slice", "all-gather",
                 "all-reduce", "reduce-scatter", "all-to-all",
                 "collective-permute", "sort"}
_BYTES_HI_EXTRA = {"fusion", "reduce", "transpose", "broadcast",
                   "concatenate", "slice", "pad", "select-and-scatter",
                   "reduce-window", "iota", "reverse", "exponential",
                   "add", "multiply", "subtract", "divide", "select",
                   "compare", "convert", "maximum", "minimum", "tanh",
                   "rsqrt", "sqrt", "log", "negate", "power", "and", "or"}


def analyze_hlo(text: str, default_group: int = 256) -> dict:
    comps = _split_computations(text)
    costs: dict[str, CompCost] = {}
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].lstrip("%")

    for cname, lines in comps.items():
        cost = CompCost()
        shapes: dict[str, str] = {}
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            opname, rtype, kind = m.groups()
            shapes[opname] = rtype
            in_region = "flash_attn_region" in line
            if kind == "dot":
                f = _dot_flops(line, rtype, shapes)
                cost.flops += f
                if in_region:
                    cost.region_flops += f
            elif kind == "convolution":
                cost.flops += _conv_flops(line, rtype, shapes)
            for c in _COLLECTIVES:
                if kind == c or kind.startswith(c + "-start"):
                    nb = _shape_bytes(rtype)
                    cost.collective[c] += _collective_traffic(
                        c, nb, line, default_group)
                    cost.collective[c + "__count"] += 1
            in_lo = kind in _BYTES_LO_OPS
            in_hi = in_lo or kind in _BYTES_HI_EXTRA
            if in_hi:
                nb = _shape_bytes(rtype)
                ob = 0
                args = _op_args(line)
                for a in args:
                    ob += _shape_bytes(_operand_type(a, shapes))
                if kind == "dynamic-update-slice":
                    # in-place DUS: traffic = update read + update write,
                    # not the whole buffer (XLA aliases the operand)
                    upd = 0
                    if len(args) >= 2:
                        upd = _shape_bytes(_operand_type(args[1], shapes))
                    total = 2 * upd if upd else nb
                elif kind == "dynamic-slice":
                    total = 2 * nb
                else:
                    total = nb + ob
                if in_lo:
                    cost.bytes_lo += total
                    if in_region:
                        cost.region_bytes_lo += total
                cost.bytes_hi += total
            # call edges
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            callees = set(_CALL_SINGLE_RE.findall(line))
            for cm in _CALL_LIST_RE.finditer(line):
                for c_ in cm.group(1).split(","):
                    callees.add(c_.strip().lstrip("%"))
            for callee in callees:
                if callee in comps:
                    mult = trip if kind == "while" else 1
                    cost.calls.append((callee, mult))
        costs[cname] = cost

    memo: dict[str, tuple] = {}

    def total(cname: str, depth=0):
        if cname in memo:
            return memo[cname]
        if depth > 64:
            return 0.0, 0.0, 0.0, 0.0, 0.0, {}
        c = costs.get(cname)
        if c is None:
            return 0.0, 0.0, 0.0, 0.0, 0.0, {}
        f, blo, bhi = c.flops, c.bytes_lo, c.bytes_hi
        rb, rf = c.region_bytes_lo, c.region_flops
        coll = dict(c.collective)
        for callee, mult in c.calls:
            cf, clo, chi, crb, crf, cc = total(callee, depth + 1)
            f += cf * mult
            blo += clo * mult
            bhi += chi * mult
            rb += crb * mult
            rf += crf * mult
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + v * mult
        memo[cname] = (f, blo, bhi, rb, rf, coll)
        return memo[cname]

    if entry is None:
        return {"flops": 0, "bytes_lo": 0, "bytes_hi": 0, "collectives": {}}
    f, blo, bhi, rb, rf, coll = total(entry)
    per_kind = {k: v for k, v in coll.items() if not k.endswith("__count")}
    counts = {k[:-7]: int(v) for k, v in coll.items()
              if k.endswith("__count")}
    return {
        "flops": f,
        "bytes_lo": blo,
        "bytes_hi": bhi,
        "bytes": blo,  # back-compat alias: the defensible floor
        "flash_region_bytes_lo": rb,
        "flash_region_flops": rf,
        "collective_traffic_bytes": float(sum(per_kind.values())),
        "collectives": per_kind,
        "collective_counts": counts,
    }
