import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (see dryrun.py).

"""Dry-run of the DiskJoin verify superstep on the production mesh.

The paper's own workload, scaled to pod size: a billion-vector join
(1M buckets, capacity 1024, d=128) processed as supersteps of E edges with
the window slab resident in HBM and edges sharded over ``data`` —
`core/distributed.py`'s execution pattern. Proves the join engine itself
is deployable on the 256/512-chip meshes and gives its roofline terms.

    python -m repro.launch.dryrun_join [--edges 4096] [--cap 1024]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.distributed import verify_edges
from repro.launch.dryrun import RESULTS, _mem_dict, append_result
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh


def run(edges: int, cap: int, dim: int, window: int,
        multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": "diskjoin-verify", "shape": f"E{edges}_cap{cap}_d{dim}",
        "mesh": "2x16x16" if multi_pod else "16x16", "tag": "baseline",
        "step": "join_superstep",
    }
    t0 = time.time()
    try:
        slab = jax.ShapeDtypeStruct((window, cap, dim), jnp.float32)
        eidx = jax.ShapeDtypeStruct((edges, 2), jnp.int32)
        from jax.sharding import NamedSharding, PartitionSpec as P
        s_slab = NamedSharding(mesh, P())              # window resident
        # edge tasks shard over EVERY mesh axis — independent tasks, no
        # cross-task state (perf iteration J5: data-only sharding left the
        # model axis recomputing every edge 16×)
        axes = tuple(a for a in mesh.shape)
        s_edges = NamedSharding(mesh, P(axes))
        with mesh:
            jitted = jax.jit(verify_edges,
                             in_shardings=(s_slab, s_edges),
                             out_shardings=(s_edges, s_edges, s_edges),
                             static_argnums=(2,))
            lowered = jitted.lower(slab, eidx, 1.0)
            compiled = lowered.compile()
        hlo = analyze_hlo(compiled.as_text())
        rec.update(
            status="ok",
            memory=_mem_dict(compiled.memory_analysis()),
            hlo_cost=hlo,
            params=window * cap * dim,   # resident floats
            active_params=window * cap * dim,
            tokens=edges,
            chips=int(mesh.size),
        )
        print(f"[dryrun-join] E={edges} cap={cap} d={dim} "
              f"{rec['mesh']}: mem/dev="
              f"{rec['memory'].get('bytes_per_device', 0):,} "
              f"flops/dev={hlo['flops']:.3e} "
              f"coll/dev={hlo['collective_traffic_bytes']:.3e}B")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}")
        print(f"[dryrun-join] FAILED: {e}")
    rec["elapsed_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--edges", type=int, default=4096)
    ap.add_argument("--cap", type=int, default=1024)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--window", type=int, default=512)
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()
    for mp in ([False, True] if args.both_meshes else [False]):
        rec = run(args.edges, args.cap, args.dim, args.window, mp)
        append_result(rec)


if __name__ == "__main__":
    main()
