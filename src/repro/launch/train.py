"""Production launcher: --arch/--shape selection, mesh setup, training or
serving with checkpointing (the `repro.launch` CLI).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke
    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --steps 100 --ckpt /tmp/ckpt

Full-size archs on this CPU container are only *lowered* (see dryrun.py);
--smoke trains the reduced config end-to-end.
"""
from __future__ import annotations

import argparse

from repro.configs import get_config, list_archs, smoke_config
from repro.launch.mesh import make_local_mesh
from repro.train import AdamWConfig, TrainConfig, train
from repro.train.grad_compress import make_int8_compressor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU end-to-end)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 gradient compression w/ error feedback")
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = None
    if args.model_axis > 1:
        mesh = make_local_mesh(model_axis=args.model_axis)

    out = train(
        cfg,
        TrainConfig(
            steps=args.steps, log_every=max(1, args.steps // 20),
            checkpoint_every=max(2, args.steps // 4),
            checkpoint_dir=args.ckpt,
            global_batch=args.global_batch, seq_len=args.seq_len,
            optimizer=AdamWConfig(learning_rate=args.lr,
                                  warmup_steps=max(1, args.steps // 10),
                                  total_steps=args.steps)),
        mesh=mesh,
        grad_transform=(make_int8_compressor() if args.compress_grads
                        else None))
    print(f"done: final_loss={out['final_loss']:.4f} "
          f"mean_step={out['mean_step_ms']:.0f}ms")


if __name__ == "__main__":
    main()
