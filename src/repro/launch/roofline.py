"""Roofline analysis (deliverable g) over the dry-run records.

Per (arch × shape × mesh) cell, from the compiled artifact's per-device
trip-count-corrected HLO census:

  compute term    = HLO_FLOPs/dev   / peak_FLOP/s          [197 TF bf16]
  memory term     = HLO_bytes/dev   / HBM_bw               [819 GB/s]
  collective term = coll_bytes/dev  / ICI link bw          [50 GB/s/link]

Step-time lower bound = max(terms) (perfect overlap); the roofline
fraction reported in EXPERIMENTS §Perf is

  useful_fraction = (MODEL_FLOPS/dev / peak) / max(terms)

with MODEL_FLOPS = 6·N·D (train), 2·N·D (prefill), 2·N·B (decode), N =
active params. It is 1.0 when the model's mathematically-necessary FLOPs
fully occupy the binding resource — waste (remat recompute, padding,
un-overlapped collectives) shows up as a smaller fraction.
"""
from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.json")
OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "results", "roofline.json")


def model_flops_per_device(rec: dict) -> float:
    n = rec["active_params"]
    tokens = rec["tokens"]
    chips = rec["chips"]
    kind = rec.get("step", "train_step")
    if kind == "train_step":
        total = 6.0 * n * tokens
    else:  # prefill_step / serve_step: forward only
        total = 2.0 * n * tokens
    return total / chips


def flash_kernel_traffic(rec: dict) -> float | None:
    """Per-device HBM traffic of the Pallas flash kernel replacing the
    census-attributed `flash_attn_region` (kernels/flash_attention.py):

        fwd/layer = Q + O + ⌈S/bq⌉·(K+V)      (score tiles stay in VMEM)
        train ≈ 3× fwd (dq/dkv backward re-streams)

    The kernel is implemented + interpret-validated; it cannot *compile* on
    this CPU container, so its effect on the memory term is modeled — the
    region subtraction uses measured census bytes, this adds the kernel's
    exact streaming cost. Tagged runs only ("…-flash")."""
    from repro.configs import SHAPES, get_config
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    if cfg.enc_dec or cfg.family == "ssm":
        return None
    mesh_axes = rec["mesh"].split("x")
    model = int(mesh_axes[-1])
    dp = rec["chips"] // model
    s = shape.seq_len
    t_dev = shape.global_batch * s / dp
    hq = max(1, cfg.n_heads // model) if cfg.n_heads % model == 0 \
        else cfg.n_heads
    hkv = max(1, cfg.n_kv_heads // model) if cfg.n_kv_heads % model == 0 \
        else cfg.n_kv_heads
    bq = 1024
    nqb = -(-s // bq)
    q_bytes = t_dev * hq * cfg.head_dim * 4
    kv_bytes = t_dev * hkv * cfg.head_dim * 4
    fwd = 2 * q_bytes + nqb * 2 * kv_bytes
    n_attn = sum(1 for k in (list(cfg.block_pattern)
                             * (cfg.n_layers // len(cfg.block_pattern) + 1)
                             )[:cfg.n_layers] if k in ("global", "local"))
    mult = 3.0 if shape.kind == "train" else 1.0
    return n_attn * fwd * mult


def roofline_terms(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "hlo_cost" not in rec:
        return None
    h = rec["hlo_cost"]
    mem = rec.get("memory", {})
    # per-device HBM traffic floor: fusion-surviving op traffic + one pass
    # over the live arguments/outputs (params, caches, batch)
    arg_out = (mem.get("argument_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0))
    bytes_lo = h.get("bytes_lo", h.get("bytes", 0.0)) + max(arg_out, 0)
    bytes_hi = h.get("bytes_hi", bytes_lo) + max(arg_out, 0)
    flash_note = ""
    if "flash" in rec.get("tag", ""):
        kern = flash_kernel_traffic(rec)
        region = h.get("flash_region_bytes_lo", 0.0)
        if kern is not None and region > 0:
            bytes_lo = bytes_lo - region + kern
            bytes_hi = bytes_hi - region + kern
            flash_note = (f"flash-kernel modeled: −{region:.2e}B region "
                          f"+{kern:.2e}B streaming")
    t_c = h["flops"] / PEAK_FLOPS
    t_m_lo = bytes_lo / HBM_BW
    t_m_hi = bytes_hi / HBM_BW
    t_x = h.get("collective_traffic_bytes", 0.0) / ICI_BW
    dominant = max((t_c, "compute"), (t_m_lo, "memory"),
                   (t_x, "collective"))
    mf = model_flops_per_device(rec)
    t_model = mf / PEAK_FLOPS
    denom = max(t_c, t_m_lo, t_x, 1e-30)
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", "baseline"), "step": rec.get("step"),
        "compute_s": t_c, "memory_s": t_m_lo, "memory_s_hi": t_m_hi,
        "collective_s": t_x,
        "dominant": dominant[1],
        "step_time_lb_s": denom,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": h["flops"],
        "useful_flops_ratio": mf / max(h["flops"], 1e-30),
        "roofline_fraction": t_model / denom,
        "mem_bytes_per_dev": mem.get("bytes_per_device"),
        "fits_hbm_16g": mem.get("bytes_per_device", 0) <= 16e9,
    }
    if rec.get("step") == "serve_step":
        # decode is bandwidth-bound by physics; the meaningful score is
        # how close traffic is to the stream-the-live-state-once floor
        # (params shard + cache + tokens = the argument set)
        floor = mem.get("argument_size_in_bytes", 0) / HBM_BW
        out["bw_floor_s"] = floor
        out["bw_fraction"] = floor / denom if denom > 0 else 0.0
    out["note"] = _suggestion(out)
    if flash_note:
        out["flash_note"] = flash_note
    return out


def _suggestion(t: dict) -> str:
    if t["dominant"] == "compute":
        if t["useful_flops_ratio"] < 0.5:
            return ("compute-bound with low useful-FLOP ratio — cut remat "
                    "recompute / padding waste to move the term down")
        return ("compute-bound near useful FLOPs — gains need lower-"
                "precision matmuls or fewer model FLOPs")
    if t["dominant"] == "memory":
        return ("memory-bound — fuse/retile to raise arithmetic intensity; "
                "check cache/scan buffers for gratuitous HBM round-trips")
    return ("collective-bound — reshard to shrink cross-device traffic or "
            "overlap collectives behind compute (async/latency-hiding)")


def analyze(path: str = RESULTS) -> list[dict]:
    with open(path) as f:
        rows = json.load(f)
    out = []
    for rec in rows:
        t = roofline_terms(rec)
        if t is not None:
            out.append(t)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "status": "skipped",
                        "reason": rec.get("reason", "")})
    return out


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | step | compute(s) | memory(s) | "
           "collective(s) | dominant | MODEL/HLO | roofline frac | "
           "fits 16G |\n|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped — {r['reason'][:60]} |" + " |" * 7)
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {'y' if r['fits_hbm_16g'] else 'N'} |")
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = analyze(args.results)
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(rows, f, indent=1)
    if args.markdown:
        print(to_markdown(rows))
    else:
        for r in rows:
            if "dominant" in r:
                print(f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} "
                      f"{r['dominant']:10s} frac={r['roofline_fraction']:.3f}"
                      f" useful={r['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    main()
