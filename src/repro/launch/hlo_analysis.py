"""Post-SPMD HLO analysis: collective byte census + cost summaries.

``collective_bytes`` parses ``compiled.as_text()`` and sums, per collective
kind, the bytes each op moves per device. Traffic model (documented — the
roofline's collective term divides by per-link bandwidth):

  all-gather        : output bytes × (n−1)/n     (ring; ≈ output bytes)
  reduce-scatter    : input  bytes × (n−1)/n
  all-reduce        : 2 × bytes × (n−1)/n        (reduce-scatter + all-gather)
  all-to-all        : bytes × (n−1)/n
  collective-permute: bytes                      (point-to-point)

Shapes are parsed from the HLO result type; replica-group count n is parsed
per op when present (fallback: the full partition count).
"""
from __future__ import annotations

import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0]
        return max(1, first.count(",") + 1)
    return default


def collective_bytes(hlo_text: str, default_group: int = 256) -> dict:
    """→ {kind: {'count', 'bytes', 'traffic_bytes'}, 'total_traffic_bytes'}."""
    out: dict = {k: {"count": 0, "bytes": 0, "traffic_bytes": 0}
                 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", ls)
        if not m:
            continue
        opname = m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-start") or \
                    opname.startswith(c + "."):
                kind = c
                break
        if kind is None:
            continue
        nbytes = _shape_bytes(m.group(1))
        n = _group_size(ls, default_group)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-reduce":
            traffic = 2 * nbytes * frac
        elif kind == "collective-permute":
            traffic = nbytes
        else:
            traffic = nbytes * frac
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
        out[kind]["traffic_bytes"] += int(traffic)
    out["total_traffic_bytes"] = int(
        sum(v["traffic_bytes"] for k, v in out.items()
            if isinstance(v, dict)))
    return out


def summarize_cost(cost) -> dict:
    """Normalize compiled.cost_analysis() across jax versions."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals",
              "optimal_seconds"):
        if k in cost:
            out[k.replace(" ", "_")] = float(cost[k])
    # per-memory-space byte entries
    for k, v in cost.items():
        if isinstance(k, str) and k.startswith("bytes accessed"):
            out[k.replace(" ", "_")] = float(v)
    return out
