import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, lower + compile the train or
serve step on the production meshes:

    16×16 ("data","model")           — single pod, 256 chips
    2×16×16 ("pod","data","model")   — 2 pods, 512 chips

and record memory_analysis(), cost_analysis(), and the collective-op byte
census parsed from the post-SPMD HLO. Results append incrementally to a
JSON file so a crashed/timed-out cell never loses prior work.

Usage:
    python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.hlo_analysis import summarize_cost
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.models import build_model

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.json")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp: bool = False, tag: str = "",
             decode_unroll: bool = False,
             capacity_data: bool = False,
             dp_over_model: bool = False,
             moe_replicated_dispatch: bool = False,
             moe_a2a: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag or ("fsdp" if fsdp else "baseline"),
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        bundle = build_model(cfg, decode_unroll=decode_unroll)
        extra = {}
        if capacity_data:
            extra["capacity"] = (("data", "model") if dp_over_model
                                 else "data")
        if dp_over_model:
            extra["batch"] = ("pod", "data", "model")
        if moe_replicated_dispatch:
            extra["moe_tokens"] = ()   # replicate the dispatch payload
        if moe_a2a:
            extra["moe_a2a"] = "model"
        extra = extra or None
        with mesh:
            lowered, info = lower_cell(bundle, shape, mesh, fsdp=fsdp,
                                       extra_rules=extra)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo_text = compiled.as_text()
            hlo = analyze_hlo(hlo_text)
            _save_hlo(rec, hlo_text)
            tokens = (shape.global_batch
                      if shape.kind == "decode"
                      else shape.global_batch * shape.seq_len)
            rec.update(
                status="ok",
                step=info["kind"],
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                memory=_mem_dict(mem),
                xla_cost=summarize_cost(cost),
                hlo_cost=hlo,              # per-device, trip-count corrected
                params=cfg.param_count(),
                active_params=cfg.active_param_count(),
                tokens=tokens,
                chips=int(mesh.size),
            )
            print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: "
                  f"mem/dev={rec['memory'].get('bytes_per_device', 0):,} "
                  f"flops/dev={hlo['flops']:.3e} "
                  f"coll/dev={hlo['collective_traffic_bytes']:.3e}B")
    except Exception as e:  # record the failure — these are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} {shape_name} FAILED: {e}")
    rec["elapsed_s"] = round(time.time() - t0, 1)
    return rec


def _save_hlo(rec: dict, text: str) -> None:
    """Persist the post-SPMD HLO so metric refinements replay without
    recompiling (results/hlo/<arch>__<shape>__<mesh>__<tag>.txt.gz)."""
    import gzip
    d = os.path.join(os.path.dirname(RESULTS), "hlo")
    os.makedirs(d, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['tag']}"
    with gzip.open(os.path.join(d, name + ".txt.gz"), "wt") as f:
        f.write(text)


def _mem_dict(mem) -> dict:
    out = {}
    for field in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        try:
            out[field] = int(getattr(mem, field))
        except Exception:
            pass
    if out:
        live = (out.get("argument_size_in_bytes", 0)
                + out.get("temp_size_in_bytes", 0)
                + out.get("output_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0))
        # memory_analysis reports whole-program sizes; arguments/outputs are
        # sharded across devices, temps are per-device already on CPU AOT
        out["bytes_per_device"] = live
    return out


def load_results() -> list[dict]:
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            return json.load(f)
    return []


def append_result(rec: dict) -> None:
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    rows = load_results()
    rows = [r for r in rows
            if not (r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
                    and r["mesh"] == rec["mesh"]
                    and r.get("tag") == rec.get("tag"))]
    rows.append(rec)
    tmp = RESULTS + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rows, f, indent=1)
    os.replace(tmp, RESULTS)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--decode-unroll", action="store_true")
    ap.add_argument("--capacity-data", action="store_true",
                    help="shard MoE dispatch capacity over the data axis")
    ap.add_argument("--dp-over-model", action="store_true",
                    help="batch also sharded over the model axis "
                         "(pure-DP + ZeRO-3 when combined with --fsdp)")
    ap.add_argument("--moe-replicated-dispatch", action="store_true",
                    help="all-gather token payload before expert scatter")
    ap.add_argument("--moe-a2a", action="store_true",
                    help="shard_map all-to-all expert-parallel dispatch")
    ap.add_argument("--tag", default="")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already recorded ok/skipped")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    done = set()
    if args.resume:
        for r in load_results():
            if r.get("status") in ("ok", "skipped"):
                done.add((r["arch"], r["shape"], r["mesh"],
                          r.get("tag", "baseline")))
    for arch, shape in cells:
        for mp in meshes:
            key = (arch, shape, "2x16x16" if mp else "16x16",
                   args.tag or ("fsdp" if args.fsdp else "baseline"))
            if key in done:
                continue
            rec = run_cell(arch, shape, mp, fsdp=args.fsdp, tag=args.tag,
                           decode_unroll=args.decode_unroll,
                           capacity_data=args.capacity_data,
                           dp_over_model=args.dp_over_model,
                           moe_replicated_dispatch=(
                               args.moe_replicated_dispatch),
                           moe_a2a=args.moe_a2a)
            append_result(rec)


if __name__ == "__main__":
    main()
