"""Production meshes (DESIGN §5).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips,
("data", "model"). Multi-pod: (2, 16, 16) = 512 chips with the outer
"pod" axis as pure data parallelism (gradient all-reduce crosses DCN —
outermost placement lets XLA do reduce-scatter intra-pod first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_devices: int | None = None,
                    model_axis: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def validate_mesh(mesh: jax.sharding.Mesh) -> dict:
    return {
        "axes": dict(mesh.shape),
        "devices": int(mesh.size),
        "platform": mesh.devices.flat[0].platform,
    }
