"""Fault tolerance: crash-safe joins, resumable builds, warm restarts.

See ``ft/README.md`` for the checkpoint format, the crash matrix, and
the goodput definition used by ``benchmarks/fig25_resilience.py``.
"""
from repro.ft.atomic import (AsyncCommitter, atomic_commit_dir,
                             atomic_write_json, fingerprint, reap_tmp)
from repro.ft.fault import FaultInjector, FlakyStore, InjectedKill
from repro.ft.join_ckpt import JoinCheckpointer, ResumeState
from repro.ft.phases import PhaseLog

__all__ = [
    "AsyncCommitter", "atomic_commit_dir", "atomic_write_json",
    "fingerprint", "reap_tmp",
    "FaultInjector", "FlakyStore", "InjectedKill",
    "JoinCheckpointer", "ResumeState",
    "PhaseLog",
]
