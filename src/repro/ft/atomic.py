"""Durability primitives shared by train and join checkpointing.

One implementation of the commit protocol both ``repro.checkpoint``
(training state) and ``repro.ft.JoinCheckpointer`` (join progress) rely
on:

  * **atomic directory commit** — writers fill ``<name>.tmp/`` and make
    it visible with a single ``os.replace`` to ``<name>/``. A crash at
    any point leaves either the committed previous state or a torn
    ``.tmp`` that readers ignore and ``reap_tmp`` removes on next open.
  * **async writer thread** — ``AsyncCommitter`` runs commit closures on
    a daemon thread behind a depth-1 queue: a slow disk can delay at
    most one snapshot and never corrupts one. ``try_submit`` never
    blocks (the join checkpointer defers to the next superstep boundary
    instead of stalling the double-buffered verify); ``submit`` blocks
    (the training loop's original backpressure semantics).
  * **config fingerprints** — ``fingerprint`` hashes a canonical-JSON
    rendering of a config/shape so restore can refuse state written by a
    different session setup instead of silently resuming into garbage.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading

TMP_SUFFIX = ".tmp"


def reap_tmp(directory: str) -> list[str]:
    """Remove torn ``*.tmp`` entries (uncommitted writes from a crashed
    writer). Returns the names reaped. Missing directory is a no-op."""
    reaped = []
    if not os.path.isdir(directory):
        return reaped
    for name in os.listdir(directory):
        if name.endswith(TMP_SUFFIX):
            path = os.path.join(directory, name)
            if os.path.isdir(path):
                shutil.rmtree(path, ignore_errors=True)
            else:
                try:
                    os.remove(path)
                except OSError:
                    pass
            reaped.append(name)
    return reaped


def atomic_commit_dir(directory: str, name: str, writer) -> str:
    """Commit ``writer``'s output as ``<directory>/<name>`` atomically.

    ``writer(tmp_path)`` fills a fresh ``<name>.tmp`` directory; the
    commit is the ``os.replace`` rename at the end — readers either see
    the complete directory or nothing. An existing committed ``name`` is
    replaced. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, name + TMP_SUFFIX)
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    writer(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def atomic_write_json(path: str, obj) -> None:
    """Single-file analogue of ``atomic_commit_dir`` for small metadata
    (e.g. the serving residency snapshot): write ``path.tmp``, fsync,
    ``os.replace``."""
    tmp = path + TMP_SUFFIX
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def fingerprint(obj) -> str:
    """Stable 16-hex digest of a canonical-JSON rendering of ``obj``.

    Non-JSON leaves (numpy scalars, arrays) are stringified via
    ``default=str`` — good enough for config dataclass dicts and shape
    tuples, which is all restore compatibility needs."""
    blob = json.dumps(obj, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


class AsyncCommitter:
    """Daemon writer thread behind a depth-1 queue.

    Work items are zero-arg closures (typically ``atomic_commit_dir``
    calls). Failures are recorded and re-raised on the *next* submit or
    on ``close()`` — the pattern ``repro.checkpoint.CheckpointManager``
    established; both checkpointers now share this one implementation.
    """

    def __init__(self, name: str = "ft-commit"):
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._errors: list[Exception] = []
        self._worker = threading.Thread(target=self._drain, name=name,
                                        daemon=True)
        self._worker.start()

    def _drain(self) -> None:
        while True:
            fn = self._q.get()
            if fn is None:
                return
            try:
                fn()
            except Exception as e:  # surfaced on next submit()/close()
                self._errors.append(e)

    def _raise_pending(self) -> None:
        if self._errors:
            e = self._errors.pop(0)
            raise RuntimeError(f"async checkpoint failed: {e}") from e

    def submit(self, fn) -> None:
        """Enqueue, blocking while one write is in flight (backpressure)."""
        self._raise_pending()
        self._q.put(fn)

    def try_submit(self, fn) -> bool:
        """Enqueue only if the writer is idle — never blocks. Returns
        False when a write is in flight (caller keeps its pending state
        and retries at the next boundary)."""
        self._raise_pending()
        try:
            self._q.put_nowait(fn)
            return True
        except queue.Full:
            return False

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every enqueued write has landed (the queue is
        depth-1, so joining the queue suffices)."""
        # depth-1 queue: wait by submitting a no-op barrier
        done = threading.Event()
        self._q.put(done.set)
        if not done.wait(timeout):
            raise TimeoutError("async committer did not drain")
        self._raise_pending()

    def close(self, timeout: float = 60.0) -> None:
        self._q.put(None)
        self._worker.join(timeout=timeout)
        self._raise_pending()
