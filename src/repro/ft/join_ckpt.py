"""Async atomic checkpointing of ``DistributedJoin`` progress.

Checkpoint format (one committed dir per covered superstep):

    <dir>/ckpt_000042/
        pairs.npy       — (R, 2) int64 raw pairs emitted since the
                          previous checkpoint (the *delta*, not a full
                          dump — spills stay O(new work))
        dists.npy       — (R,) float32 distances, row-aligned with pairs
        state.json      — {"superstep": 42, "prev": 37,
                           "watermark_rows": <raw rows ≤ this ckpt>,
                           "fingerprint": "<session config digest>"}
    <dir>/ckpt_000057.tmp/   — torn write from a crash; ignored by
                               restore, reaped on open

Restore walks the committed chain in superstep order, refuses a chain
whose fingerprint mismatches the session (resuming a different config /
dataset into this run would emit garbage), and returns the raw emission
stream up to the watermark. ``DistributedJoin.run(resume_from=…)`` then
re-executes only supersteps past the cursor; because the raw stream is
replayed byte-for-byte and dedup runs over the concatenation exactly as
an uninterrupted run would, the final pairs+distances are byte-identical
and no pair is emitted twice across the watermark.

Saves ride ``AsyncCommitter``'s daemon thread; ``step_done`` uses the
non-blocking ``try_submit`` so a slow disk defers a checkpoint to the
next superstep boundary instead of stalling the double-buffered device
verify.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import time

import numpy as np

from repro.ft.atomic import AsyncCommitter, atomic_commit_dir, reap_tmp
from repro.obs import get_tracer

_CKPT_RE = re.compile(r"ckpt_(\d+)")


@dataclasses.dataclass
class ResumeState:
    """Committed progress handed to ``DistributedJoin.run(resume_from=…)``."""
    superstep: int            # last superstep covered; resume at +1
    pairs: list               # raw per-checkpoint (R,2) int64 deltas, in order
    dists: list               # matching (R,) float32 deltas
    watermark_rows: int       # total raw rows restored
    restore_s: float = 0.0


def _list_committed(directory: str) -> list[tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = _CKPT_RE.fullmatch(d)
        if m and os.path.exists(os.path.join(directory, d, "state.json")):
            out.append((int(m.group(1)), os.path.join(directory, d)))
    return sorted(out)


class JoinCheckpointer:
    """Checkpoints join progress every ``every`` supersteps.

    Usage (what ``DistributedJoin.run`` does internally)::

        ckpt = JoinCheckpointer(dir, every=4)
        ckpt.begin(fp)                      # reaps .tmp, clears stale chains
        for si, step in enumerate(steps):
            ...verify...
            ckpt.step_done(si, pairs, dists)   # never blocks
        ckpt.finish()                       # final blocking save + drain
        ckpt.close()
    """

    def __init__(self, directory: str, *, every: int = 1,
                 async_save: bool = True):
        self.directory = directory
        self.every = max(1, int(every))
        os.makedirs(directory, exist_ok=True)
        reap_tmp(directory)
        self._committer = AsyncCommitter(name="join-ckpt") if async_save \
            else None
        self._fingerprint: str | None = None
        # pending: rows emitted since the last *submitted* checkpoint
        self._pend_pairs: list[np.ndarray] = []
        self._pend_dists: list[np.ndarray] = []
        self._pend_rows = 0
        self._last_committed = -1   # superstep of last submitted ckpt
        self._last_step = -1        # highest superstep seen by step_done
        self._rows_total = 0        # watermark incl. pending
        self.stats = {"saves": 0, "save_s": 0.0, "saved_rows": 0,
                      "deferred": 0}

    # -- write side --------------------------------------------------------

    def begin(self, fingerprint: str, start_superstep: int = 0) -> None:
        """Arm for a run. A fresh run (``start_superstep == 0``) wipes any
        committed chain — stale state from an older config must not be
        concatenated into this run. A resumed run keeps the chain and
        continues appending past the cursor."""
        self._fingerprint = fingerprint
        if start_superstep == 0:
            for _, path in _list_committed(self.directory):
                shutil.rmtree(path, ignore_errors=True)
            self._last_committed = -1
            self._rows_total = 0
        else:
            self._last_committed = start_superstep - 1
            committed = _list_committed(self.directory)
            if committed:
                with open(os.path.join(committed[-1][1], "state.json")) as f:
                    self._rows_total = json.load(f)["watermark_rows"]
        self._last_step = self._last_committed

    def step_done(self, superstep: int, pairs, dists) -> None:
        """Record one superstep's raw emissions (possibly empty — the
        cursor must advance through pair-free steps too) and checkpoint
        at ``every``-step boundaries without blocking."""
        for p, d in zip(pairs, dists):
            if len(p):
                self._pend_pairs.append(np.asarray(p, np.int64))
                self._pend_dists.append(np.asarray(d, np.float32))
                self._pend_rows += len(p)
                self._rows_total += len(p)
        self._last_step = max(self._last_step, int(superstep))
        if (superstep - self._last_committed) >= self.every:
            self._commit(superstep, block=False)

    def finish(self) -> None:
        """Flush everything: blocking final save + drain the writer."""
        if self._last_step > self._last_committed or self._pend_rows:
            self._commit(max(self._last_step, self._last_committed + 1),
                         block=True)
        if self._committer is not None:
            self._committer.drain()

    def close(self) -> None:
        if self._committer is not None:
            self._committer.close()

    def _commit(self, superstep: int, *, block: bool) -> None:
        if self._fingerprint is None:
            raise RuntimeError("JoinCheckpointer.begin() not called")
        if superstep <= self._last_committed:
            return
        pairs = (np.concatenate(self._pend_pairs)
                 if self._pend_pairs else np.zeros((0, 2), np.int64))
        dists = (np.concatenate(self._pend_dists)
                 if self._pend_dists else np.zeros((0,), np.float32))
        state = {"superstep": int(superstep),
                 "prev": int(self._last_committed),
                 "watermark_rows": int(self._rows_total),
                 "fingerprint": self._fingerprint}

        def _write() -> None:
            t0 = time.perf_counter()
            with get_tracer().span("ft.save", superstep=int(superstep),
                                   rows=int(pairs.shape[0])):
                def fill(tmp: str) -> None:
                    np.save(os.path.join(tmp, "pairs.npy"), pairs)
                    np.save(os.path.join(tmp, "dists.npy"), dists)
                    with open(os.path.join(tmp, "state.json"), "w") as f:
                        json.dump(state, f)
                atomic_commit_dir(self.directory,
                                  f"ckpt_{superstep:06d}", fill)
            self.stats["saves"] += 1
            self.stats["save_s"] += time.perf_counter() - t0
            self.stats["saved_rows"] += int(pairs.shape[0])

        if self._committer is None:
            _write()
        elif block:
            self._committer.submit(_write)
        elif not self._committer.try_submit(_write):
            # writer busy: keep pending, retry at the next boundary —
            # the verify pipeline never waits on disk
            self.stats["deferred"] += 1
            return
        self._pend_pairs, self._pend_dists = [], []
        self._pend_rows = 0
        self._last_committed = int(superstep)

    # -- read side ---------------------------------------------------------

    @staticmethod
    def restore(directory: str, *, fingerprint: str) -> ResumeState | None:
        """Load the committed chain → ``ResumeState``, or None when no
        checkpoint exists. Torn ``.tmp`` dirs are reaped; a fingerprint
        mismatch raises — resuming foreign state is never silent."""
        t0 = time.perf_counter()
        with get_tracer().span("ft.restore"):
            reap_tmp(directory)
            committed = _list_committed(directory)
            if not committed:
                return None
            pairs, dists = [], []
            prev = -1
            cursor = -1
            watermark = 0
            for step, path in committed:
                with open(os.path.join(path, "state.json")) as f:
                    state = json.load(f)
                if state.get("fingerprint") != fingerprint:
                    raise ValueError(
                        f"checkpoint {path} was written for config "
                        f"fingerprint {state.get('fingerprint')!r} but this "
                        f"session is {fingerprint!r} — refusing to resume; "
                        "delete the checkpoint directory to start fresh")
                if state["prev"] != prev:
                    # hole in the chain (manual deletion): use the valid
                    # prefix rather than resuming past missing rows
                    break
                p = np.load(os.path.join(path, "pairs.npy"))
                d = np.load(os.path.join(path, "dists.npy"))
                if len(p):
                    pairs.append(np.asarray(p, np.int64))
                    dists.append(np.asarray(d, np.float32))
                prev = step
                cursor = step
                watermark = state["watermark_rows"]
            if cursor < 0:
                return None
        return ResumeState(superstep=cursor, pairs=pairs, dists=dists,
                           watermark_rows=watermark,
                           restore_s=time.perf_counter() - t0)
