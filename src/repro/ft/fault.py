"""Fault injection for resilience tests, ``fig25_resilience`` and
``fig27_replication``.

Failure families map to the crash matrix in ``ft/README.md``:

  * ``FaultInjector(kill_at_superstep=k)`` — process death mid-join: the
    injector raises ``InjectedKill`` at the top of superstep ``k`` and
    then disarms, so the resumed run sails past the same point.
  * ``FlakyStore(store, read_error_every=n)`` — transient SSD read
    errors: every n-th read call raises ``IOError`` (capped by
    ``max_errors``), exercising the retry/backoff path in the executors
    and prefetcher.
  * ``FaultInjector.tear_checkpoint(dir)`` — a torn ``.tmp`` checkpoint
    directory as a crashed writer would leave it; restore must ignore it
    and open must reap it.

Shard-level verbs (the replicated-serving failure modes of
``serve.replica``) wrap a replica session's store in a ``FlakyStore``
and flip its mode:

  * ``FaultInjector.kill_replica(replica)`` — permanent death: every
    read raises ``InjectedKill`` until the supervisor reopens a fresh
    session (or ``revive_replica`` is called in tests).
  * ``FaultInjector.brownout(replica, latency_x)`` — a slow-but-alive
    disk: reads succeed after ``latency_x`` times the store's emulated
    read latency.
  * ``FaultInjector.flaky_replica(replica, every=n)`` — the transient
    mode, addressed by replica.

``replica`` is anything with an ``.index`` attribute (a
``serve.replica.Replica``) or a ``DiskJoinIndex`` itself.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np


class InjectedKill(RuntimeError):
    """Raised by the injector in place of a real SIGKILL — the test
    harness catches it where a supervisor would restart the process."""


class FaultInjector:
    """Deterministic fault schedule for one join attempt, plus the
    shard-level verbs used by the replicated-serving benchmarks."""

    def __init__(self, kill_at_superstep: int | None = None):
        self.kill_at_superstep = kill_at_superstep
        self._fired = False
        self.kills = 0

    def superstep(self, si: int) -> None:
        """Hook called by ``DistributedJoin.run`` at the top of each
        superstep. Fires at most once, then disarms."""
        if (self.kill_at_superstep is not None and not self._fired
                and si >= self.kill_at_superstep):
            self._fired = True
            self.kills += 1
            raise InjectedKill(f"injected kill at superstep {si}")

    @staticmethod
    def tear_checkpoint(directory: str, superstep: int = 999999) -> str:
        """Fabricate a torn (uncommitted) checkpoint write: a ``.tmp``
        dir with a partial payload and no committed rename."""
        path = os.path.join(directory, f"ckpt_{superstep:06d}.tmp")
        os.makedirs(path, exist_ok=True)
        np.save(os.path.join(path, "pairs.npy"),
                np.zeros((3, 2), np.int64))   # garbage a resume must ignore
        with open(os.path.join(path, "state.json"), "w") as f:
            f.write('{"superstep": ')  # truncated mid-write
        return path

    # -- shard-level verbs ----------------------------------------------------
    @staticmethod
    def _flaky_store_of(target) -> "FlakyStore":
        """The target session's store, wrapped in a ``FlakyStore`` proxy
        (idempotent — an already-wrapped store is reused)."""
        index = getattr(target, "index", target)
        store = index.store
        if not isinstance(store, FlakyStore):
            store = FlakyStore(store, read_error_every=0)
            index.store = store
        return store

    def kill_replica(self, target) -> "FlakyStore":
        """Permanent replica death: every subsequent read on the
        session's store raises ``InjectedKill``, and the session's warm
        slabs are dropped — a dead process loses its cache, so requests
        cannot keep limping along on residual warm hits. The replica
        stays dead until a supervisor swaps in a fresh session (its
        reopen binds the real store again) or ``revive_replica`` is
        called."""
        store = self._flaky_store_of(target)
        store.kill()
        index = getattr(target, "index", target)
        try:
            index.drop_warm_cache()
        except Exception:
            pass           # a wedged session still counts as killed
        self.kills += 1
        return store

    def revive_replica(self, target) -> None:
        """Undo ``kill_replica`` in place (tests that do not run a
        supervisor)."""
        self._flaky_store_of(target).revive()

    def brownout(self, target, latency_x: float = 4.0, *,
                 extra_latency_s: float | None = None) -> "FlakyStore":
        """Slow-but-alive replica: reads succeed after ``latency_x``
        times the store's emulated read latency (or an explicit
        ``extra_latency_s``). A browned-out replica trips the hedging
        knob and drifts to DEGRADED via deadline drops — it is never
        ejected outright, which is the point: brownouts must be handled
        by routing AROUND the replica, not by declaring it dead."""
        store = self._flaky_store_of(target)
        if extra_latency_s is None:
            base = float(getattr(store, "read_latency_s", 0.0) or 0.0)
            extra_latency_s = base * (float(latency_x) - 1.0)
        store.extra_latency_s = float(max(0.0, extra_latency_s))
        return store

    def flaky_replica(self, target, every: int = 5,
                      max_errors: int | None = None) -> "FlakyStore":
        """Transient read errors on one replica (every n-th read), the
        retry-in-place regime — addressed form of ``FlakyStore``."""
        store = self._flaky_store_of(target)
        store.read_error_every = int(every)
        store.max_errors = max_errors
        return store


class FlakyStore:
    """Proxy store injecting faults on reads.

    Wraps any vector store; non-read attribute access (including
    ``read_latency_s`` assignment, which ``DiskJoinIndex`` sets) passes
    through to the inner store. Three modes, combinable:

      * transient: every ``read_error_every``-th read raises ``IOError``
        (capped by ``max_errors``; 0 disables);
      * killed (``kill()``/``revive()``): every read raises
        ``InjectedKill`` — a dead replica;
      * brownout (``extra_latency_s``): reads sleep first — a slow disk.

    Counters are shared across ``read_bucket`` / ``read_bucket_into`` /
    ``read_run_into`` and thread-safe (the prefetcher reads from worker
    threads).
    """

    _LOCAL = ("store", "read_error_every", "max_errors", "_lock",
              "_calls", "errors_injected", "killed", "kills_injected",
              "extra_latency_s")

    def __init__(self, store, *, read_error_every: int = 5,
                 max_errors: int | None = None):
        object.__setattr__(self, "store", store)
        object.__setattr__(self, "read_error_every", int(read_error_every))
        object.__setattr__(self, "max_errors", max_errors)
        object.__setattr__(self, "_lock", threading.Lock())
        object.__setattr__(self, "_calls", 0)
        object.__setattr__(self, "errors_injected", 0)
        object.__setattr__(self, "killed", False)
        object.__setattr__(self, "kills_injected", 0)
        object.__setattr__(self, "extra_latency_s", 0.0)

    def kill(self) -> None:
        object.__setattr__(self, "killed", True)

    def revive(self) -> None:
        object.__setattr__(self, "killed", False)

    def _maybe_fail(self) -> None:
        with self._lock:
            if self.killed:
                object.__setattr__(self, "kills_injected",
                                   self.kills_injected + 1)
                raise InjectedKill("replica store is dead (injected)")
            self._calls += 1
            calls, injected = self._calls, self.errors_injected
            if (self.read_error_every > 0
                    and calls % self.read_error_every == 0
                    and (self.max_errors is None
                         or injected < self.max_errors)):
                object.__setattr__(self, "errors_injected", injected + 1)
                raise IOError("injected transient read error")
        if self.extra_latency_s > 0:
            time.sleep(self.extra_latency_s)

    def read_bucket(self, *a, **kw):
        self._maybe_fail()
        return self.store.read_bucket(*a, **kw)

    def read_bucket_into(self, *a, **kw):
        self._maybe_fail()
        return self.store.read_bucket_into(*a, **kw)

    def read_run_into(self, *a, **kw):
        self._maybe_fail()
        return self.store.read_run_into(*a, **kw)

    def __getattr__(self, name):
        return getattr(self.store, name)

    def __setattr__(self, name, value):
        if name in self._LOCAL:
            object.__setattr__(self, name, value)
        else:
            setattr(self.store, name, value)
