"""Fault injection for resilience tests and ``fig25_resilience``.

Three failure families map to the crash matrix in ``ft/README.md``:

  * ``FaultInjector(kill_at_superstep=k)`` — process death mid-join: the
    injector raises ``InjectedKill`` at the top of superstep ``k`` and
    then disarms, so the resumed run sails past the same point.
  * ``FlakyStore(store, read_error_every=n)`` — transient SSD read
    errors: every n-th read call raises ``IOError`` (capped by
    ``max_errors``), exercising the retry/backoff path in the executors
    and prefetcher.
  * ``FaultInjector.tear_checkpoint(dir)`` — a torn ``.tmp`` checkpoint
    directory as a crashed writer would leave it; restore must ignore it
    and open must reap it.
"""
from __future__ import annotations

import os
import threading

import numpy as np


class InjectedKill(RuntimeError):
    """Raised by the injector in place of a real SIGKILL — the test
    harness catches it where a supervisor would restart the process."""


class FaultInjector:
    """Deterministic fault schedule for one join attempt."""

    def __init__(self, kill_at_superstep: int | None = None):
        self.kill_at_superstep = kill_at_superstep
        self._fired = False
        self.kills = 0

    def superstep(self, si: int) -> None:
        """Hook called by ``DistributedJoin.run`` at the top of each
        superstep. Fires at most once, then disarms."""
        if (self.kill_at_superstep is not None and not self._fired
                and si >= self.kill_at_superstep):
            self._fired = True
            self.kills += 1
            raise InjectedKill(f"injected kill at superstep {si}")

    @staticmethod
    def tear_checkpoint(directory: str, superstep: int = 999999) -> str:
        """Fabricate a torn (uncommitted) checkpoint write: a ``.tmp``
        dir with a partial payload and no committed rename."""
        path = os.path.join(directory, f"ckpt_{superstep:06d}.tmp")
        os.makedirs(path, exist_ok=True)
        np.save(os.path.join(path, "pairs.npy"),
                np.zeros((3, 2), np.int64))   # garbage a resume must ignore
        with open(os.path.join(path, "state.json"), "w") as f:
            f.write('{"superstep": ')  # truncated mid-write
        return path


class FlakyStore:
    """Proxy store injecting transient ``IOError`` on every n-th read.

    Wraps any vector store; non-read attribute access (including
    ``read_latency_s`` assignment, which ``DiskJoinIndex`` sets) passes
    through to the inner store. The error counter is shared across
    ``read_bucket`` / ``read_bucket_into`` / ``read_run_into`` and
    thread-safe (the prefetcher reads from worker threads).
    """

    _LOCAL = ("store", "read_error_every", "max_errors", "_lock",
              "_calls", "errors_injected")

    def __init__(self, store, *, read_error_every: int = 5,
                 max_errors: int | None = None):
        object.__setattr__(self, "store", store)
        object.__setattr__(self, "read_error_every", int(read_error_every))
        object.__setattr__(self, "max_errors", max_errors)
        object.__setattr__(self, "_lock", threading.Lock())
        object.__setattr__(self, "_calls", 0)
        object.__setattr__(self, "errors_injected", 0)

    def _maybe_fail(self) -> None:
        with self._lock:
            self._calls += 1
            calls, injected = self._calls, self.errors_injected
            if (calls % self.read_error_every == 0
                    and (self.max_errors is None
                         or injected < self.max_errors)):
                object.__setattr__(self, "errors_injected", injected + 1)
                raise IOError("injected transient read error")

    def read_bucket(self, *a, **kw):
        self._maybe_fail()
        return self.store.read_bucket(*a, **kw)

    def read_bucket_into(self, *a, **kw):
        self._maybe_fail()
        return self.store.read_bucket_into(*a, **kw)

    def read_run_into(self, *a, **kw):
        self._maybe_fail()
        return self.store.read_run_into(*a, **kw)

    def __getattr__(self, name):
        return getattr(self.store, name)

    def __setattr__(self, name, value):
        if name in self._LOCAL:
            object.__setattr__(self, name, value)
        else:
            setattr(self.store, name, value)
