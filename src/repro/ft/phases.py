"""Per-phase commit markers for resumable ``DiskJoinIndex.build``.

An index build is a pipeline of full-store scans and derivations —
sample centers → assign blocks → [sketch] → [layout order] → write
buckets. Each phase commits its outputs atomically under
``<workdir>/build_phases/<phase>/`` with a ``marker.json`` carrying the
build-config fingerprint. A build killed between phases restarts at the
first phase without a committed marker instead of rescanning the flat
store from the top; a build whose config changed (different fingerprint)
silently discards the stale phases and rebuilds from scratch — stale
markers must never leak a different config's centers into this build.

Layout per phase::

    <dir>/sample/
        marker.json         — {"fingerprint": …, "extra": {…}}
        arr_centers.npy     — named arrays committed with the marker
    <dir>/assign.tmp/       — torn write from a kill; reaped on open
"""
from __future__ import annotations

import json
import os
import shutil

import numpy as np

from repro.ft.atomic import atomic_commit_dir, fingerprint as _fp, reap_tmp

MARKER = "marker.json"


class PhaseLog:
    def __init__(self, directory: str, config_fingerprint: str):
        self.directory = directory
        self.fingerprint = config_fingerprint
        os.makedirs(directory, exist_ok=True)
        reap_tmp(directory)
        # drop committed phases from a different build config
        for name in os.listdir(directory):
            path = os.path.join(directory, name)
            marker = os.path.join(path, MARKER)
            if not os.path.isfile(marker):
                continue
            try:
                with open(marker) as f:
                    fp = json.load(f).get("fingerprint")
            except (OSError, ValueError):
                fp = None
            if fp != config_fingerprint:
                shutil.rmtree(path, ignore_errors=True)

    def path(self, phase: str) -> str:
        return os.path.join(self.directory, phase)

    def has(self, phase: str) -> bool:
        return os.path.isfile(os.path.join(self.path(phase), MARKER))

    def commit(self, phase: str, writer=None, extra: dict | None = None
               ) -> str:
        """Commit a finished phase: ``writer(tmp)`` (optional) fills the
        payload, the marker rides in the same atomic rename."""
        def fill(tmp: str) -> None:
            if writer is not None:
                writer(tmp)
            with open(os.path.join(tmp, MARKER), "w") as f:
                json.dump({"fingerprint": self.fingerprint,
                           "extra": extra or {}}, f)
        return atomic_commit_dir(self.directory, phase, fill)

    def commit_arrays(self, phase: str, extra: dict | None = None,
                      **arrays) -> str:
        return self.commit(
            phase,
            lambda tmp: [np.save(os.path.join(tmp, f"arr_{k}.npy"), v)
                         for k, v in arrays.items()],
            extra=extra)

    def load_arrays(self, phase: str) -> dict[str, np.ndarray]:
        out = {}
        for name in os.listdir(self.path(phase)):
            if name.startswith("arr_") and name.endswith(".npy"):
                out[name[4:-4]] = np.load(
                    os.path.join(self.path(phase), name))
        return out

    def load_meta(self, phase: str) -> dict:
        with open(os.path.join(self.path(phase), MARKER)) as f:
            return json.load(f).get("extra", {})

    def clear(self) -> None:
        """Build finished (manifest committed): the log has served its
        purpose; remove it so the workdir holds only live state."""
        shutil.rmtree(self.directory, ignore_errors=True)


def build_fingerprint(build_cfg_dict: dict, store_shape, layout) -> str:
    """Digest identifying one build: config + source extent + layout
    request. Any difference invalidates committed phases."""
    return _fp({"cfg": build_cfg_dict, "shape": list(store_shape),
                "layout": layout})
