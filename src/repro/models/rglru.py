"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = σ(W_a x_t)                     (recurrence gate)
    i_t = σ(W_x_gate x_t)                (input gate)
    a_t = exp(−c · softplus(Λ) · r_t)    (per-channel decay ∈ (0,1))
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses an associative scan over the sequence (log-depth); decode is
the O(1) per-step update. A depthwise causal conv (width 4) precedes the
recurrence, as in the paper's recurrent block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RGLRUConfig
from repro.dist.sharding import shard
from repro.models.layers import dense_init


def _width(cfg: ArchConfig) -> int:
    r: RGLRUConfig = cfg.rglru
    return r.lru_width or cfg.d_model


def init_rglru(key, cfg: ArchConfig) -> dict:
    r = cfg.rglru
    w = _width(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 5)
    return {"rglru": {
        "w_x": dense_init(keys[0], cfg.d_model, w, dtype),
        "w_gate_in": dense_init(keys[1], cfg.d_model, w, dtype),
        "conv": (jax.random.normal(keys[2], (r.conv_width, w), jnp.float32)
                 * 0.1).astype(dtype),
        "a_param": jnp.full((w,), 0.7, jnp.float32),   # Λ
        "in_gate_w": jnp.zeros((w,), jnp.float32),
        "rec_gate_w": jnp.zeros((w,), jnp.float32),
        "out": dense_init(keys[3], w, cfg.d_model, dtype),
    }}


def _gates(p, x_branch: jax.Array, c_const: float):
    rec_gate = jax.nn.sigmoid(
        x_branch.astype(jnp.float32) * p["rec_gate_w"][None, None]
        + 0.0)
    in_gate = jax.nn.sigmoid(
        x_branch.astype(jnp.float32) * p["in_gate_w"][None, None])
    log_a = -c_const * jax.nn.softplus(p["a_param"])[None, None] * rec_gate
    a = jnp.exp(log_a)
    return a, in_gate


def rglru_block(params: dict, cfg: ArchConfig, u: jax.Array,
                cache: dict | None = None):
    """u: (B, S, d_model) → (y, new_cache)."""
    p = params["rglru"]
    r = cfg.rglru
    b, s, _ = u.shape
    gate = jax.nn.gelu(u @ p["w_gate_in"])
    x = u @ p["w_x"]
    x = shard(x, "batch", "seq", "mlp")

    # depthwise causal conv
    wsize = p["conv"].shape[0]
    conv_state = cache["conv"] if cache is not None else \
        jnp.zeros((b, wsize - 1, x.shape[-1]), x.dtype)
    full = jnp.concatenate([conv_state, x], axis=1)
    x = sum(full[:, i:i + s] * p["conv"][i][None, None]
            for i in range(wsize))
    new_conv = full[:, -(wsize - 1):]

    a, in_gate = _gates(p, x, r.c_constant)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    v = beta * in_gate * x.astype(jnp.float32)                # (B,S,W)

    if cache is not None:
        h0 = cache["state"]                                   # (B, W)

        def step(h, t):
            h = a[:, t] * h + v[:, t]
            return h, h

        h_last, hs = jax.lax.scan(step, h0, jnp.arange(s))
        h = jnp.moveaxis(hs, 0, 1)
        new_cache = {"state": h_last, "conv": new_conv}
    else:
        # associative scan: (a2, v2) ∘ (a1, v1) = (a2·a1, a2·v1 + v2)
        def combine(c1, c2):
            a1, v1 = c1
            a2, v2 = c2
            return a1 * a2, a2 * v1 + v2

        _, h = jax.lax.associative_scan(combine, (a, v), axis=1)
        new_cache = None

    y = (h.astype(u.dtype) * gate) @ p["out"]
    return shard(y, "batch", "seq", "embed"), new_cache


def init_rglru_cache(cfg: ArchConfig, batch: int) -> dict:
    w = _width(cfg)
    return {
        "state": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w),
                          jnp.dtype(cfg.param_dtype)),
    }
