"""Model zoo: composable decoder LM (dense/MoE/SSM/hybrid/VLM) + enc-dec."""
from repro.models.model_api import ModelBundle, build_model, cache_specs

__all__ = ["ModelBundle", "build_model", "cache_specs"]
