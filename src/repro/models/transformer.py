"""Composable decoder-only LM covering dense / MoE / SSM / hybrid / VLM.

Layer structure is a repeated *block pattern* (e.g. gemma3's 5 local + 1
global, recurrentgemma's rglru,rglru,local). Layers are scanned in pattern
groups: parameters for each pattern position are stacked over the repeat
dimension and the whole group runs under one ``lax.scan`` (keeps HLO size
O(pattern) instead of O(layers) — critical for 48-layer dry-run compiles).
A remainder group covers ``n_layers % len(pattern)`` trailing layers.

Decode caches mirror the same grouping, scanned alongside the params.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod

ATTN_KINDS = ("global", "local")


# ---------------------------------------------------------------------------
# per-layer blocks
# ---------------------------------------------------------------------------
def init_block(key, cfg: ArchConfig, kind: str, layer_idx: int) -> dict:
    dtype = L.dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {
        "norm_in": L.init_rmsnorm(cfg.d_model, dtype),
        "norm_mid": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if kind in ATTN_KINDS:
        p["attn"] = L.init_attention(k1, cfg)
        p.update(_init_ffn(k2, cfg, layer_idx))
    elif kind == "ssm":
        p.update(ssm_mod.init_ssm(k1, cfg))
        del p["norm_mid"]  # mamba blocks are single-branch
    elif kind == "rglru":
        p.update(rglru_mod.init_rglru(k1, cfg))
        p.update(_init_ffn(k2, cfg, layer_idx))
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def _init_ffn(key, cfg: ArchConfig, layer_idx: int) -> dict:
    dtype = L.dtype_of(cfg)
    if cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense:
        return moe_mod.init_moe(key, cfg)
    d_ff = cfg.d_ff
    if cfg.moe is not None:
        d_ff = cfg.moe.d_ff_dense or cfg.d_ff
    return {"mlp": L.init_mlp(key, cfg.d_model, d_ff, dtype)}


def apply_block(params: dict, cfg: ArchConfig, kind: str, x: jax.Array,
                positions: jax.Array, cache: Optional[dict]):
    """→ (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(params["norm_in"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        attn_out, new_attn_cache = L.attention(
            params["attn"], cfg, h, positions, kind=kind,
            cache=None if cache is None else cache)
        x = x + attn_out
        h2 = L.rmsnorm(params["norm_mid"], x, cfg.norm_eps)
        if "moe" in params:
            ffn_out, aux = moe_mod.moe_ffn(params, cfg, h2)
        else:
            ffn_out = L.mlp(params["mlp"], h2)
        x = x + ffn_out
        return x, new_attn_cache, aux
    if kind == "ssm":
        out, new_cache = ssm_mod.ssm_block(params, cfg, h, cache)
        return x + out, new_cache, aux
    if kind == "rglru":
        out, new_cache = rglru_mod.rglru_block(params, cfg, h, cache)
        x = x + out
        h2 = L.rmsnorm(params["norm_mid"], x, cfg.norm_eps)
        if "moe" in params:
            ffn_out, aux = moe_mod.moe_ffn(params, cfg, h2)
        else:
            ffn_out = L.mlp(params["mlp"], h2)
        return x + ffn_out, new_cache, aux
    raise ValueError(kind)


def init_block_cache(cfg: ArchConfig, kind: str, batch: int,
                     max_seq: int) -> dict:
    if kind in ATTN_KINDS:
        return L.init_attn_cache(cfg, batch, max_seq, kind)
    if kind == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch)
    if kind == "rglru":
        return rglru_mod.init_rglru_cache(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# pattern groups
# ---------------------------------------------------------------------------
def _groups(cfg: ArchConfig) -> list[tuple[int, tuple[str, ...], int]]:
    """(repeats, pattern, start_layer_idx) groups covering n_layers.

    Within a group, every layer at the same pattern position must share a
    param structure — MoE archs with leading dense layers (deepseek's
    first_k_dense) get those layers as a separate group.
    """
    groups: list[tuple[int, tuple[str, ...], int]] = []
    pattern = tuple(cfg.block_pattern)
    start = 0
    n = cfg.n_layers
    dense_k = cfg.moe.first_k_dense if cfg.moe is not None else 0
    if dense_k:
        full, part = divmod(dense_k, len(pattern))
        if full:
            groups.append((full, pattern, 0))
        if part:
            groups.append((1, _rot(pattern, full * len(pattern))[:part],
                           full * len(pattern)))
        start = dense_k
    remaining = n - start
    reps, rem = divmod(remaining, len(pattern))
    if reps:
        groups.append((reps, _rot(pattern, start), start))
    if rem:
        groups.append((1, _rot(pattern, start + reps * len(pattern))[:rem],
                       start + reps * len(pattern)))
    return groups


def _rot(pattern: tuple[str, ...], abs_idx: int) -> tuple[str, ...]:
    """Pattern as seen starting from absolute layer ``abs_idx``."""
    k = abs_idx % len(pattern)
    return pattern[k:] + pattern[:k]


def _stack(trees: list) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(rng, cfg: ArchConfig) -> dict:
    dtype = L.dtype_of(cfg)
    keys = jax.random.split(rng, cfg.n_layers + 3)
    params: dict[str, Any] = {
        "embed": {"table": L.embed_init(keys[-1], cfg.vocab, cfg.d_model,
                                        dtype)},
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"kernel": L.dense_init(
            keys[-2], cfg.d_model, cfg.vocab, dtype)}
    if cfg.family == "vlm":
        enc = cfg.encoder
        fdim = enc.frontend_dim or cfg.d_model
        params["frontend"] = {"proj": L.dense_init(keys[-3], fdim,
                                                   cfg.d_model, dtype)}
    groups = []
    for reps, pattern, start in _groups(cfg):
        stacked = []
        for pi, kind in enumerate(pattern):
            per_rep = []
            for r in range(reps):
                idx = start + r * len(pattern) + pi
                per_rep.append(init_block(keys[idx], cfg, kind, idx))
            stacked.append(_stack(per_rep))
        groups.append(stacked)
    params["groups"] = groups
    return params


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> list:
    caches = []
    for reps, pattern, _ in _groups(cfg):
        stacked = []
        for kind in pattern:
            per_rep = [init_block_cache(cfg, kind, batch, max_seq)
                       for _ in range(reps)]
            stacked.append(_stack(per_rep))
        caches.append(stacked)
    return caches


def _run_groups(params: dict, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array, caches: Optional[list],
                remat: bool = False, unroll: bool = False):
    total_aux = jnp.zeros((), jnp.float32)
    new_caches: Optional[list] = [] if caches is not None else None
    for gi, (reps, pattern, _) in enumerate(_groups(cfg)):
        gparams = params["groups"][gi]
        gcaches = caches[gi] if caches is not None else None

        if unroll and gcaches is not None:
            # decode-optimized path: Python-unrolled layers — caches update
            # in place (donated args alias outputs) instead of riding a
            # lax.scan carry that XLA double-buffers (EXPERIMENTS §Perf)
            new_layer_caches = [[] for _ in pattern]
            for r in range(reps):
                for pi, kind in enumerate(pattern):
                    lp = jax.tree_util.tree_map(lambda p: p[r], gparams[pi])
                    c = jax.tree_util.tree_map(lambda v: v[r], gcaches[pi])
                    x, nc, a = apply_block(lp, cfg, kind, x, positions, c)
                    total_aux = total_aux + a
                    new_layer_caches[pi].append(nc)
            new_caches.append([
                jax.tree_util.tree_map(lambda *vs: jnp.stack(vs), *ncs)
                for ncs in new_layer_caches])
            continue

        def body(carry, xs):
            h, aux = carry
            layer_params = xs[0]
            layer_caches = xs[1] if gcaches is not None else None
            outs = []
            for pi, kind in enumerate(pattern):
                c = layer_caches[pi] if layer_caches is not None else None
                h, nc, a = apply_block(layer_params[pi], cfg, kind, h,
                                       positions, c)
                aux = aux + a
                outs.append(nc)
            return (h, aux), (outs if gcaches is not None else 0)

        body_fn = jax.checkpoint(body) if remat else body
        xs = (gparams, gcaches) if gcaches is not None else (gparams,)
        (x, total_aux), ys = jax.lax.scan(body_fn, (x, total_aux), xs)
        if gcaches is not None:
            new_caches.append(ys)
    return x, new_caches, total_aux


# ---------------------------------------------------------------------------
# public forward passes
# ---------------------------------------------------------------------------
def forward(params: dict, cfg: ArchConfig, tokens: jax.Array,
            patch_embeds: Optional[jax.Array] = None, remat: bool = True):
    """Training/prefill forward → (hidden (B,S,d), aux_loss).

    VLM: ``patch_embeds`` (B, P, frontend_dim) are projected and prepended;
    the returned hidden covers the full (P+S) sequence.
    """
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if patch_embeds is not None:
        px = patch_embeds.astype(x.dtype) @ params["frontend"]["proj"]
        x = jnp.concatenate([px, x], axis=1)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])[None, :]
    x, _, aux = _run_groups(params, cfg, x, positions, None, remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def decode_step(params: dict, cfg: ArchConfig, tokens: jax.Array,
                caches: list, unroll: bool = False):
    """One decode step. tokens: (B, 1) → (logits (B, vocab), new caches)."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard(x, "batch", "seq", "embed")
    pos0 = _cache_pos(cfg, caches)
    positions = (pos0 + jnp.arange(tokens.shape[1]))[None, :]
    x, new_caches, _ = _run_groups(params, cfg, x, positions, caches,
                                   unroll=unroll)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, cfg, x[:, -1:])[:, 0]
    return logits, new_caches


def _cache_pos(cfg: ArchConfig, caches: list) -> jax.Array:
    """Current absolute position — stored in every attn cache; ssm/rglru
    archs keep a dedicated counter in the first cache dict."""
    for group in caches:
        for stacked in group:
            if isinstance(stacked, dict) and "pos" in stacked:
                return stacked["pos"][0]  # all layers advance in lockstep
    return jnp.zeros((), jnp.int32)


def lm_logits(params: dict, cfg: ArchConfig, hidden: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        table = params["embed"]["table"]
        return jnp.einsum("bsd,vd->bsv", hidden, table)
    return hidden @ params["lm_head"]["kernel"]
