"""Model API: uniform init / loss / decode across all assigned archs.

``build_model(cfg)`` returns a ``ModelBundle`` whose members are pure
functions suitable for jit/pjit lowering:

  init(rng)                          → params
  loss(params, batch)                → (scalar loss, metrics dict)
  init_cache(batch, max_seq)         → decode caches
  decode(params, tokens, caches)     → (logits (B, V), new caches)
  input_specs(shape)                 → ShapeDtypeStruct batch stand-ins
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist.sharding import shard
from repro.models import encdec, transformer


# ---------------------------------------------------------------------------
# loss: chunked cross-entropy (vocab logits never fully materialized)
# ---------------------------------------------------------------------------
def chunked_xent(hidden: jax.Array, table: jax.Array, labels: jax.Array,
                 chunk: int = 2048) -> jax.Array:
    """hidden (B,S,d) × table (V,d) × labels (B,S) → mean NLL.

    Scans over sequence chunks so the (tokens, vocab) logits tensor exists
    only one chunk at a time — with 262k vocabs the full tensor would be
    ~10× the activation footprint of the whole backbone.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    if s % chunk:
        pad = chunk - s % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        s = s + pad
    n_chunks = s // chunk
    hidden = hidden.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    labels = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        tot, cnt = carry
        h, y = xs
        logits = jnp.einsum("bcd,vd->bcv", h.astype(jnp.float32),
                            table.astype(jnp.float32))
        logits = shard(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (tot + nll.sum(), cnt + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hidden, labels))
    return tot / jnp.maximum(cnt, 1.0)


@dataclasses.dataclass
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    init_cache: Callable
    decode: Callable
    input_specs: Callable
    prefill: Callable = None  # forward-only: batch → last-token logits


def build_model(cfg: ArchConfig, *, decode_unroll: bool = False
                ) -> ModelBundle:
    if cfg.enc_dec:
        return _build_encdec(cfg)
    return _build_lm(cfg, decode_unroll=decode_unroll)


# ---------------------------------------------------------------------------
# decoder-only families (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------
def _build_lm(cfg: ArchConfig, decode_unroll: bool = False) -> ModelBundle:
    is_vlm = cfg.family == "vlm"

    def init(rng):
        return transformer.init_lm(rng, cfg)

    def loss(params, batch):
        patches = batch.get("patches") if is_vlm else None
        hidden, aux = transformer.forward(params, cfg, batch["tokens"],
                                          patch_embeds=patches)
        if is_vlm:  # loss only over the token suffix
            hidden = hidden[:, patches.shape[1]:]
        nll = chunked_xent(hidden[:, :-1], params["embed"]["table"],
                           batch["labels"][:, 1:])
        return nll + aux, {"nll": nll, "aux": aux}

    def init_cache(batch, max_seq):
        return transformer.init_cache(cfg, batch, max_seq)

    def decode(params, tokens, caches):
        return transformer.decode_step(params, cfg, tokens, caches,
                                       unroll=decode_unroll)

    def prefill(params, batch):
        """Inference prefill: forward over the prompt → last-token logits.
        (KV-cache emission is pure data movement fused into the attention
        projections; its footprint is measured by the decode cells.)"""
        patches = batch.get("patches") if is_vlm else None
        hidden, _ = transformer.forward(params, cfg, batch["tokens"],
                                        patch_embeds=patches, remat=False)
        return transformer.lm_logits(params, cfg, hidden[:, -1:])[:, 0]

    def input_specs(shape: ShapeSpec) -> dict:
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        else:
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        if is_vlm and shape.kind != "decode":
            enc = cfg.encoder
            fdim = enc.frontend_dim or cfg.d_model
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, enc.n_patches, fdim), jnp.bfloat16)
        return specs

    return ModelBundle(cfg, init, loss, init_cache, decode, input_specs,
                       prefill)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------
def _build_encdec(cfg: ArchConfig) -> ModelBundle:
    def init(rng):
        return encdec.init_encdec(rng, cfg)

    def loss(params, batch):
        enc_out = encdec.encode(params, cfg, batch["frames"])
        hidden = encdec.decode_train(params, cfg, batch["tokens"], enc_out)
        nll = chunked_xent(hidden[:, :-1], params["embed"]["table"],
                           batch["labels"][:, 1:])
        return nll, {"nll": nll}

    def init_cache(batch, max_seq, params=None, enc_out=None):
        if params is None:
            raise ValueError("enc-dec cache needs params (cross-attn K/V)")
        return encdec.init_decode_cache(params, cfg, batch, max_seq, enc_out)

    def decode(params, tokens, caches):
        return encdec.decode_step(params, cfg, tokens, caches)

    def prefill(params, batch):
        """Audio prefill: encode frames + first decoder-step logits."""
        enc_out = encdec.encode(params, cfg, batch["frames"])
        hidden = encdec.decode_train(params, cfg, batch["tokens"], enc_out)
        logits = jnp.einsum("bsd,vd->bsv", hidden[:, -1:],
                            params["embed"]["table"])
        return logits[:, 0]

    def input_specs(shape: ShapeSpec) -> dict:
        b, s = shape.global_batch, shape.seq_len
        enc = cfg.encoder
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        specs = {
            "frames": jax.ShapeDtypeStruct((b, enc.n_frames, cfg.d_model),
                                           jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, min(s, cfg.max_position)),
                                           jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct(
                (b, min(s, cfg.max_position)), jnp.int32)
        return specs

    return ModelBundle(cfg, init, loss, init_cache, decode, input_specs,
                       prefill)


# ---------------------------------------------------------------------------
# cache stand-ins for dry-run decode lowering (no allocation)
# ---------------------------------------------------------------------------
def cache_specs(bundle: ModelBundle, batch: int, max_seq: int):
    """ShapeDtypeStruct pytree matching init_cache without allocating."""
    cfg = bundle.cfg
    if cfg.enc_dec:
        closure = functools.partial(_encdec_cache_eval, bundle, batch,
                                    max_seq)
    else:
        closure = functools.partial(bundle.init_cache, batch, max_seq)
    return jax.eval_shape(closure)


def _encdec_cache_eval(bundle: ModelBundle, batch: int, max_seq: int):
    cfg = bundle.cfg
    params = transformer_params_shapes = None
    # build cache specs directly without params: replicate structure
    from repro.models import layers as L
    caches = {"self": [], "cross_k": [], "cross_v": [],
              "pos": jnp.zeros((), jnp.int32)}
    f = cfg.encoder.n_frames
    for _ in range(cfg.n_layers):
        caches["self"].append(L.init_attn_cache(cfg, batch, max_seq))
        caches["cross_k"].append(
            jnp.zeros((batch, f, cfg.n_kv_heads, cfg.head_dim),
                      jnp.dtype(cfg.param_dtype)))
        caches["cross_v"].append(
            jnp.zeros((batch, f, cfg.n_kv_heads, cfg.head_dim),
                      jnp.dtype(cfg.param_dtype)))
    del params, transformer_params_shapes
    return caches
