"""Mixture-of-Experts FFN (deepseek-moe fine-grained, olmoe).

Token-choice top-k routing with capacity bounding, GShard-style:
  1. router softmax → top-k experts per token,
  2. position-in-expert via cumulative sum over tokens,
  3. scatter tokens into per-expert slabs (E, C, d) — sharded over the
     ``experts``/EP axis so XLA emits the dispatch all-to-all,
  4. per-expert SwiGLU via stacked einsum,
  5. weighted combine (gather back + sum over k).

Shared experts (deepseek) run densely on every token. Aux load-balance loss
(switch-style) is returned for the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.dist.sharding import shard
from repro.models.layers import dense_init, init_mlp, mlp


def init_moe(key, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 5)
    p = {
        "router": dense_init(keys[0], d, m.num_experts, jnp.float32),
        "experts": {
            "w_gate": _stack_init(keys[1], m.num_experts, d, m.d_ff_expert,
                                  dtype),
            "w_up": _stack_init(keys[2], m.num_experts, d, m.d_ff_expert,
                                dtype),
            "w_down": _stack_init(keys[3], m.num_experts, m.d_ff_expert, d,
                                  dtype),
        },
    }
    if m.num_shared:
        p["shared"] = init_mlp(keys[4], d, m.num_shared * m.d_ff_shared,
                               dtype)
    return {"moe": p}


def _stack_init(key, e: int, din: int, dout: int, dtype):
    scale = 1.0 / jnp.sqrt(din)
    return (jax.random.normal(key, (e, din, dout), jnp.float32)
            * scale).astype(dtype)


def moe_ffn(params: dict, cfg: ArchConfig, x: jax.Array
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss)."""
    from repro.dist.sharding import current_mesh, has_rule
    if has_rule("moe_a2a") and current_mesh() is not None:
        # explicit expert-parallel dataflow (EXPERIMENTS §Perf cell 2
        # endpoint): shard_map all-to-all dispatch instead of SPMD scatter
        from repro.models.moe_a2a import moe_ffn_a2a
        return moe_ffn_a2a(params, cfg, x)
    m: MoEConfig = cfg.moe
    p = params["moe"]
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)    # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = int(m.capacity_factor * t * m.top_k / m.num_experts)
    capacity = max(8, min(capacity, t))

    # position-in-expert via sort (perf iteration 2, EXPERIMENTS §Perf):
    # the textbook cumsum-of-one-hot materializes a (T·k, E) int32 matrix
    # that XLA all-gathers across data shards (2.1 GB/layer at olmoe's
    # train_4k cell); rank-by-sort uses only 1-D length-T·k arrays.
    eid = expert_idx.reshape(-1)                               # (T*k,)
    order = jnp.argsort(eid)
    eid_sorted = jnp.take(eid, order)
    starts = jnp.searchsorted(eid_sorted, jnp.arange(m.num_experts))
    ranks_sorted = jnp.arange(t * m.top_k) - jnp.take(starts, eid_sorted)
    pos_in_expert = jnp.zeros_like(eid).at[order].set(ranks_sorted)
    keep = pos_in_expert < capacity

    # dispatch: scatter tokens into (E, C, d), bf16 payload end-to-end
    src = jnp.repeat(xf, m.top_k, axis=0)                     # (T*k, d)
    safe_pos = jnp.where(keep, pos_in_expert, capacity - 1)
    from repro.dist.sharding import has_rule
    if has_rule("moe_tokens"):
        # replicated-token dispatch (perf iteration, EXPERIMENTS §Perf):
        # one all-gather of the token payload lets every expert shard
        # scatter locally — replacing the (E, C, d) slab all-reduce that
        # SPMD emits for cross-shard scatter-adds
        src = shard(src, "moe_tokens", "embed")
    zeros = jnp.zeros((m.num_experts, capacity, d), x.dtype)
    slab = zeros.at[eid, safe_pos].add(
        jnp.where(keep[:, None], src, jnp.zeros((), x.dtype)))
    slab = shard(slab, "experts", "capacity", "embed")

    # per-expert SwiGLU
    e = p["experts"]
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", slab, e["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", slab, e["w_up"]))
    h = shard(h, "experts", "capacity", "expert_mlp")
    out_slab = jnp.einsum("ecf,efd->ecd", h, e["w_down"])
    out_slab = shard(out_slab, "experts", "capacity", "embed")

    # combine: gather each token's k expert outputs, weight, sum
    gathered = out_slab[eid, safe_pos]                        # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_vals.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.sum((gathered * w).reshape(t, m.top_k, d), axis=1)

    if m.num_shared:
        y = y + mlp(params["moe"]["shared"], xf[None])[0]

    # switch aux loss: fraction-of-tokens × mean-prob per expert
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], m.num_experts, dtype=jnp.float32),
        axis=0)
    aux = m.num_experts * jnp.sum(me * ce) * m.router_aux_loss
    return y.reshape(b, s, d), aux
