"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, d_model). Encoder: bidirectional
self-attention with learned positions. Decoder: causal self-attention +
cross-attention to the encoder output; decode caches the self-attn KV and
the (static) cross-attn KV computed once at prefill.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import shard
from repro.models import layers as L


def init_encdec(rng, cfg: ArchConfig) -> dict:
    enc = cfg.encoder
    dtype = L.dtype_of(cfg)
    n_enc = enc.n_layers
    keys = jax.random.split(rng, 3 * (n_enc + cfg.n_layers) + 6)
    ki = iter(range(len(keys)))

    def nk():
        return keys[next(ki)]

    params = {
        "embed": {"table": L.embed_init(nk(), cfg.vocab, cfg.d_model, dtype)},
        "pos_embed": {"table": L.embed_init(nk(), cfg.max_position,
                                            cfg.d_model, dtype) * 0.02},
        "enc_pos": {"table": L.embed_init(nk(), enc.n_frames, cfg.d_model,
                                          dtype) * 0.02},
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "enc_final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "encoder": [], "decoder": [],
    }
    for _ in range(n_enc):
        params["encoder"].append({
            "norm_in": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": L.init_attention(nk(), cfg),
            "norm_mid": L.init_rmsnorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(nk(), cfg.d_model, cfg.d_ff, dtype),
        })
    for _ in range(cfg.n_layers):
        params["decoder"].append({
            "norm_in": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": L.init_attention(nk(), cfg),
            "norm_x": L.init_rmsnorm(cfg.d_model, dtype),
            "cross_attn": L.init_attention(nk(), cfg, cross=True),
            "norm_mid": L.init_rmsnorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(nk(), cfg.d_model, cfg.d_ff, dtype),
        })
    return params


def encode(params: dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d_model) stub embeddings → encoder states."""
    x = frames.astype(L.dtype_of(cfg))
    x = x + params["enc_pos"]["table"][None, :x.shape[1]]
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])[None, :]
    for lp in params["encoder"]:
        h = L.rmsnorm(lp["norm_in"], x, cfg.norm_eps)
        # bidirectional: cross-attend to itself (no causal mask, no rope)
        attn_out, _ = L.attention(lp["attn"], cfg, h, positions, kv_x=h,
                                  rope=False)
        x = x + attn_out
        h = L.rmsnorm(lp["norm_mid"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h)
    return L.rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


def decode_train(params: dict, cfg: ArchConfig, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    """Teacher-forced decoder forward → hidden (B, S, d)."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x + params["pos_embed"]["table"][None, :tokens.shape[1]]
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(tokens.shape[1])[None, :]
    for lp in params["decoder"]:
        h = L.rmsnorm(lp["norm_in"], x, cfg.norm_eps)
        attn_out, _ = L.attention(lp["attn"], cfg, h, positions, rope=False)
        x = x + attn_out
        h = L.rmsnorm(lp["norm_x"], x, cfg.norm_eps)
        cross_out, _ = L.attention(lp["cross_attn"], cfg, h, positions,
                                   kv_x=enc_out, rope=False)
        x = x + cross_out
        h = L.rmsnorm(lp["norm_mid"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def init_decode_cache(params: dict, cfg: ArchConfig, batch: int,
                      max_seq: int, enc_out: Optional[jax.Array] = None
                      ) -> dict:
    """Self-attn caches + precomputed cross-attn K/V per layer."""
    caches = {"self": [], "cross_k": [], "cross_v": [],
              "pos": jnp.zeros((), jnp.int32)}
    for lp in params["decoder"]:
        caches["self"].append(L.init_attn_cache(cfg, batch, max_seq))
        if enc_out is not None:
            k = (enc_out @ lp["cross_attn"]["wk"]).reshape(
                batch, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
            v = (enc_out @ lp["cross_attn"]["wv"]).reshape(
                batch, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        else:
            f = cfg.encoder.n_frames
            k = jnp.zeros((batch, f, cfg.n_kv_heads, cfg.head_dim),
                          L.dtype_of(cfg))
            v = jnp.zeros_like(k)
        caches["cross_k"].append(k)
        caches["cross_v"].append(v)
    return caches


def decode_step(params: dict, cfg: ArchConfig, tokens: jax.Array,
                caches: dict):
    """One decoder step with cached cross-attn → (logits (B,V), caches)."""
    b, s = tokens.shape
    pos0 = caches["pos"]
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    pos_emb = jnp.take(params["pos_embed"]["table"],
                       pos0 + jnp.arange(s), axis=0)
    x = x + pos_emb[None]
    positions = (pos0 + jnp.arange(s))[None, :]
    new_self = []
    for li, lp in enumerate(params["decoder"]):
        h = L.rmsnorm(lp["norm_in"], x, cfg.norm_eps)
        attn_out, nc = L.attention(lp["attn"], cfg, h, positions,
                                   cache=caches["self"][li], rope=False)
        new_self.append(nc)
        x = x + attn_out
        h = L.rmsnorm(lp["norm_x"], x, cfg.norm_eps)
        # cross-attn against cached encoder K/V
        q = (h @ lp["cross_attn"]["wq"]).reshape(b, s, cfg.n_heads,
                                                 cfg.head_dim)
        out = L.gqa_scores_chunked(q, caches["cross_k"][li],
                                   caches["cross_v"][li], causal=False)
        x = x + out.reshape(b, s, -1) @ lp["cross_attn"]["wo"]
        h = L.rmsnorm(lp["norm_mid"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:],
                        params["embed"]["table"])[:, 0]
    new_caches = {"self": new_self, "cross_k": caches["cross_k"],
                  "cross_v": caches["cross_v"], "pos": pos0 + s}
    return logits, new_caches
