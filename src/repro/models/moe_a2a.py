"""Expert-parallel MoE dispatch via shard_map all-to-all (§Perf endpoint).

The SPMD scatter formulation makes XLA all-reduce the full (E, C, d) slab
(EXPERIMENTS §Perf cell 2 — three refuted resharding attempts). This
module expresses the dataflow explicitly: tokens are grouped by
destination expert shard and exchanged with ``jax.lax.all_to_all`` over
the ``model`` axis — per-device traffic is the routed token payload
(t_loc·k·d), the paper-counted minimum for token-choice routing.

Layout inside shard_map (per (data i, model j) device):
  x_loc (t_loc, d)  → route: send (ep, cap_pair, d) → all_to_all →
  recv (ep, cap_pair, d) holding tokens whose experts live here →
  local slab (e_loc, cap_loc, d) → SwiGLU → reverse path → combine.

Capacity bounds are per source→destination pair (static shapes); dropped
tokens mirror the GShard capacity semantics. Numerical equivalence to
``moe_ffn`` (up to capacity-drop tie-breaking) is tested on 8 devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from repro.dist.sharding import current_mesh
from repro.models.layers import mlp


def _ranks_by_sort(dest: jax.Array, n_dest: int) -> jax.Array:
    """Position of each element within its destination group (1-D)."""
    order = jnp.argsort(dest)
    sorted_dest = jnp.take(dest, order)
    starts = jnp.searchsorted(sorted_dest, jnp.arange(n_dest))
    ranks_sorted = jnp.arange(dest.shape[0]) - jnp.take(starts, sorted_dest)
    return jnp.zeros_like(dest).at[order].set(ranks_sorted)


def moe_ffn_a2a(params: dict, cfg: ArchConfig, x: jax.Array,
                axis_name: str = "model"):
    """x: (B, S, d) → (y, aux). Requires an active mesh with ``model``."""
    mesh = current_mesh()
    if mesh is None or axis_name not in mesh.shape:
        raise ValueError("moe_ffn_a2a needs an active mesh with a "
                         f"'{axis_name}' axis")
    m: MoEConfig = cfg.moe
    ep = mesh.shape[axis_name]
    assert m.num_experts % ep == 0, "experts must divide the EP axis"
    e_loc = m.num_experts // ep
    b, s, d = x.shape
    p = params["moe"]

    data_axes = tuple(a for a in mesh.shape if a != axis_name)

    def body(xb, router, wg, wu, wd):
        # xb: (b_loc, s, d) tokens local to this (data, model) shard
        t_loc = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(t_loc, d)
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, m.top_k)          # (t_loc, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        flat_e = eidx.reshape(-1)                           # (t_loc*k,)
        dest = flat_e // e_loc                              # target shard
        cap_pair = max(8, int(m.capacity_factor * t_loc * m.top_k / ep))
        rank = _ranks_by_sort(dest, ep)
        keep = rank < cap_pair
        slot = jnp.where(keep, rank, cap_pair - 1)

        src = jnp.repeat(xf, m.top_k, axis=0)
        payload = jnp.where(keep[:, None], src, jnp.zeros((), src.dtype))
        send = jnp.zeros((ep, cap_pair, d), x.dtype
                         ).at[dest, slot].add(payload)
        # local expert index (+1; 0 = empty slot) rides a side channel
        send_eid = jnp.zeros((ep, cap_pair), jnp.int32
                             ).at[dest, slot].add(
            jnp.where(keep, flat_e % e_loc + 1, 0))

        recv = jax.lax.all_to_all(send, axis_name, 0, 0, tiled=True)
        recv_eid = jax.lax.all_to_all(send_eid, axis_name, 0, 0,
                                      tiled=True)
        # recv: (ep*cap_pair, d) tokens destined for this shard's experts
        recv = recv.reshape(ep * cap_pair, d)
        eid_loc = recv_eid.reshape(ep * cap_pair)

        # local expert compute — scatter into (e_loc, cap_loc, d), no comm.
        # cap_loc = the fair share per local expert (perf iterations: the
        # ep×cap_pair worst case cost 3.9× compute, 2× fair share cost 2×;
        # fair share matches the SPMD baseline's expert compute exactly —
        # cap_pair's capacity_factor already provides the slack, and
        # overflow drops follow standard capacity semantics)
        cap_loc = max(8, (ep * cap_pair) // e_loc)
        lrank = _ranks_by_sort(jnp.where(eid_loc > 0, eid_loc - 1, 0),
                               e_loc)
        occupied = (eid_loc > 0) & (lrank < cap_loc)
        lslot = jnp.where(occupied, jnp.minimum(lrank, cap_loc - 1),
                          cap_loc - 1)
        lexp = jnp.where(occupied, eid_loc - 1, 0)
        slab = jnp.zeros((e_loc, cap_loc, d), x.dtype
                         ).at[lexp, lslot].add(
            jnp.where(occupied[:, None], recv, jnp.zeros((), recv.dtype)))
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", slab, wg))
             * jnp.einsum("ecd,edf->ecf", slab, wu))
        out = jnp.einsum("ecf,efd->ecd", h, wd)
        back = out[lexp, lslot]
        back = jnp.where(occupied[:, None], back, jnp.zeros((), out.dtype))

        # reverse route + combine
        back = back.reshape(ep, cap_pair, d)
        ret = jax.lax.all_to_all(back, axis_name, 0, 0, tiled=True)
        gathered = ret.reshape(ep, cap_pair, d)[dest, slot]
        gathered = jnp.where(keep[:, None], gathered, 0)
        w = gate.reshape(-1)[:, None].astype(gathered.dtype)
        y = jnp.sum((gathered * w).reshape(t_loc, m.top_k, d), axis=1)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], m.num_experts,
                                     dtype=jnp.float32), axis=0)
        aux = (m.num_experts * jnp.sum(me * ce) * m.router_aux_loss)
        aux = jax.lax.pmean(aux, axis_name)
        for ax in data_axes:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(xb.shape), aux

    e = p["experts"]
    batch_spec = P(data_axes if data_axes else None)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(batch_spec, P(), P(axis_name), P(axis_name),
                  P(axis_name)),
        out_specs=(batch_spec, P()),
        check_rep=False,
    )(x, p["router"], e["w_gate"], e["w_up"], e["w_down"])

    if m.num_shared:
        y = y + mlp(p["shared"], x.reshape(b * s, d)[None])[0].reshape(
            x.shape)
    return y, aux
