"""Shared transformer layers: norms, RoPE, SwiGLU MLP, GQA attention.

All layers are pure functions over explicit param dicts (init_* returns the
params; apply is the function). Dtypes: params in ``cfg.param_dtype``,
activations kept in the same dtype with f32 softmax/norm internals.
Activations carry logical-axis annotations via ``repro.dist.shard``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.dist.sharding import shard


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    # std d^-0.5: unit-variance inputs after the sqrt(d) embedding scale,
    # and O(1) logits through the tied head
    return (jax.random.normal(key, (vocab, dim), jnp.float32)
            / np.sqrt(dim)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard / half-dim "2d" GLM style)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, rope_dim: int) -> jax.Array:
    exponent = jnp.arange(0, rope_dim, 2, dtype=jnp.float32) / rope_dim
    return 1.0 / (theta ** exponent)                       # (rope_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mode: str = "full") -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    if mode == "none":
        return x
    d = x.shape[-1]
    rope_dim = d if mode == "full" else d // 2
    freqs = rope_freqs(d, theta, rope_dim)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, rd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    xr = x[..., :rope_dim].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape).astype(x.dtype)
    if rope_dim == d:
        return rotated
    return jnp.concatenate([rotated, x[..., rope_dim:]], axis=-1)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard(h, "batch", "seq", "mlp")
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# GQA attention (full / sliding-window), chunked-flash for long sequences
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AttnDims:
    n_heads: int
    n_kv: int
    head_dim: int


def init_attention(key, cfg: ArchConfig, cross: bool = False) -> dict:
    d = cfg.d_model
    hd = cfg.head_dim
    dtype = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def gqa_scores_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                       *, causal: bool, window: int = 0,
                       q_offset=0, kv_positions: Optional[jax.Array] = None,
                       q_chunk: int = 1024) -> jax.Array:
    """Memory-bounded attention: scan over query chunks.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D). GQA via grouped einsum — kv
    heads are never materialized repeated. ``window > 0`` restricts each
    query to the trailing ``window`` keys (sliding-window local attention).
    ``q_offset`` is the absolute position of q[0] (decode / chunked
    prefill); ``kv_positions`` gives absolute key positions (rolling decode
    caches; −1 marks empty slots).
    """
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    scale = d ** -0.5
    qg = q.reshape(b, sq, hkv, g, d)
    q_chunk = min(q_chunk, sq)
    n_chunks = sq // q_chunk if sq % q_chunk == 0 else -(-sq // q_chunk)

    kv_pos = jnp.arange(skv) if kv_positions is None else kv_positions

    def one_chunk(ci):
        # named scope: the HLO census attributes this region's traffic so
        # the roofline can model its replacement by the Pallas flash kernel
        # (kernels/flash_attention.py — VMEM-resident score tiles)
        with jax.named_scope("flash_attn_region"):
            start = ci * q_chunk
            qc = jax.lax.dynamic_slice_in_dim(qg, start, q_chunk, axis=1)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qc.astype(jnp.float32),
                           k.astype(jnp.float32)) * scale
            q_pos = q_offset + start + jnp.arange(q_chunk)
            mask = kv_pos[None, :] >= 0
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bkgqs,bskd->bqkgd", p,
                              v.astype(jnp.float32)).astype(q.dtype)

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        outs = jax.lax.map(one_chunk, jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, n_chunks * q_chunk,
                                               hkv, g, d)[:, :sq]
    return out.reshape(b, sq, h, d)


def attention(params: dict, cfg: ArchConfig, x: jax.Array,
              positions: jax.Array, *, kind: str = "global",
              kv_x: Optional[jax.Array] = None,
              cache: Optional[dict] = None,
              rope: bool = True) -> tuple[jax.Array, Optional[dict]]:
    """Self/cross attention with optional KV cache (decode).

    cache: {"k": (B, S_max, Hkv, D), "v": ..., "pos": scalar int32} —
    functional update returned alongside the output.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = _split_heads(x @ params["wq"], cfg.n_heads)
    src = x if kv_x is None else kv_x
    k = _split_heads(src @ params["wk"], cfg.n_kv_heads)
    v = _split_heads(src @ params["wv"], cfg.n_kv_heads)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)

    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)

    causal = kv_x is None
    window = cfg.window if kind == "local" else 0
    new_cache = None
    if cache is not None and kv_x is None:
        pos0 = cache["pos"]
        if rope and cfg.rope_mode != "none":
            q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_mode)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_mode)
        steps = cache["k"].shape[1]
        idx = (pos0 + jnp.arange(s)) % steps   # rolling for local windows
        ck = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
        kpos = cache["kpos"].at[idx].set(pos0 + jnp.arange(s))
        ck = shard(ck, "batch", "cache_seq", "kv_heads", None)
        cv = shard(cv, "batch", "cache_seq", "kv_heads", None)
        new_cache = {"k": ck, "v": cv, "kpos": kpos, "pos": pos0 + s}
        out = gqa_scores_chunked(q, ck, cv, causal=True, window=window,
                                 q_offset=pos0, kv_positions=kpos)
    else:
        if rope and kv_x is None and cfg.rope_mode != "none":
            q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_mode)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_mode)
        out = gqa_scores_chunked(q, k, v, causal=causal, window=window)

    out = shard(out, "batch", "seq", "heads", None)
    y = out.reshape(b, s, cfg.n_heads * hd) @ params["wo"]
    return shard(y, "batch", "seq", "embed"), new_cache


def init_attn_cache(cfg: ArchConfig, batch: int, max_seq: int,
                    kind: str = "global", dtype=None) -> dict:
    """Decode cache. Local layers only keep a rolling window — the 500k
    decode's memory win for sliding-window archs (DESIGN §4)."""
    dtype = dtype or dtype_of(cfg)
    steps = min(max_seq, cfg.window) if kind == "local" else max_seq
    return {
        "k": jnp.zeros((batch, steps, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, steps, cfg.n_kv_heads, cfg.head_dim), dtype),
        "kpos": jnp.full((steps,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }
