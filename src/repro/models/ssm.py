"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: the sequence is split into chunks of length Q; the
quadratic "attention-like" intra-chunk term and the linear inter-chunk state
recurrence are combined:

  intra:  Y_intra = (L ∘ (C Bᵀ)) · X           (L = causal decay matrix)
  states: S_c     = Σ_t a(t..end) B_t X_tᵀ      (per-chunk final state)
  carry:  H_c     = decay(c) H_{c−1} + S_c      (scan over chunks)
  inter:  Y_inter = C · H_{c−1} (decayed)

Decode is the O(1) recurrence h = a·h + B x; y = C·h + D x — the state is
the whole cache (no KV growth ⇒ long_500k applicability, DESIGN §4).

Scalar-per-head decay a_t = exp(−Δ_t·softplus(A_log)) (Mamba-2's SSD
restriction), depthwise causal conv on the input projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.dist.sharding import shard
from repro.models.layers import dense_init


def _dims(cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return s, d_inner, n_heads


def init_ssm(key, cfg: ArchConfig) -> dict:
    s, d_inner, n_heads = _dims(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    # in_proj emits [x, z, B, C, dt]
    proj_out = 2 * d_inner + 2 * n_heads * s.state_dim + n_heads
    return {"ssm": {
        "in_proj": dense_init(keys[0], cfg.d_model, proj_out, dtype),
        "conv": (jax.random.normal(keys[1],
                                   (s.conv_width,
                                    d_inner + 2 * n_heads * s.state_dim),
                                   jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "out_proj": dense_init(keys[2], d_inner, cfg.d_model, dtype),
    }}


def _split_proj(cfg: ArchConfig, proj: jax.Array):
    s, d_inner, n_heads = _dims(cfg)
    x, z, bc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner,
               2 * d_inner + 2 * n_heads * s.state_dim], axis=-1)
    return x, z, bc, dt


def _causal_conv(seq: jax.Array, w: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along axis 1. seq: (B, S, C); w: (W, C)."""
    wsize = w.shape[0]
    if state is None:
        pad = jnp.zeros((seq.shape[0], wsize - 1, seq.shape[2]), seq.dtype)
    else:
        pad = state
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i][None, None]
              for i in range(wsize))
    new_state = full[:, -(wsize - 1):] if wsize > 1 else pad
    return jax.nn.silu(out), new_state


def ssm_block(params: dict, cfg: ArchConfig, u: jax.Array,
              cache: dict | None = None):
    """u: (B, S, d_model) → (y, new_cache)."""
    p = params["ssm"]
    s, d_inner, n_heads = _dims(cfg)
    b, seqlen, _ = u.shape

    proj = u @ p["in_proj"]
    x, z, bc, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([x, bc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    x, bc = conv_out[..., :d_inner], conv_out[..., d_inner:]
    B, C = jnp.split(bc, 2, axis=-1)
    B = B.reshape(b, seqlen, n_heads, s.state_dim)
    C = C.reshape(b, seqlen, n_heads, s.state_dim)
    xh = x.reshape(b, seqlen, n_heads, s.head_dim)
    xh = shard(xh, "batch", "seq", "mlp", None)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])          # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"])[None, None])        # decay ∈ (0,1)

    if cache is not None:
        # O(1) recurrence (decode); supports S≥1 via mini-scan
        h0 = cache["state"]                                   # (B,H,P,N)

        def step(h, t):
            xt, Bt, Ct, at = (xh[:, t], B[:, t], C[:, t], a[:, t])
            h = (h * at[..., None, None]
                 + jnp.einsum("bhp,bhn->bhpn", xt.astype(jnp.float32),
                              Bt.astype(jnp.float32)))
            yt = jnp.einsum("bhpn,bhn->bhp", h, Ct.astype(jnp.float32))
            return h, yt

        h, ys = jax.lax.scan(step, h0, jnp.arange(seqlen))
        y = jnp.moveaxis(ys, 0, 1)                            # (B,S,H,P)
        new_cache = {"state": h, "conv": new_conv}
    else:
        y = _ssd_chunked(xh, a, B, C, s.chunk)
        new_cache = None

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, seqlen, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return shard(out, "batch", "seq", "embed"), new_cache


def _ssd_chunked(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                 chunk: int) -> jax.Array:
    """Chunked SSD scan. x: (B,S,H,P); a: (B,S,H); B/C: (B,S,H,N)."""
    b, seq, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, seq)
    orig_seq = seq
    if seq % chunk:  # pad tail: x/B/C zeros (inert), decay 1 (state-neutral)
        pad = chunk - seq % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        seq = seq + pad
    c = seq // chunk

    def r(t):  # (B, c, Q, ...) views
        return t.reshape(b, c, chunk, *t.shape[2:])

    xc, ac, Bc, Cc = r(x.astype(jnp.float32)), r(a), r(B.astype(jnp.float32)), \
        r(C.astype(jnp.float32))
    la = jnp.log(jnp.maximum(ac, 1e-20))                      # (B,c,Q,H)
    cum = jnp.cumsum(la, axis=2)                              # inclusive

    # intra-chunk: L[q,t] = exp(cum[q] − cum[t]) for q ≥ t  (decay t→q)
    Lmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,c,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(Lmat), 0.0)
    scores = jnp.einsum("bcqhn,bcthn->bcqth", Cc, Bc)
    y_intra = jnp.einsum("bcqth,bcqth,bcthp->bcqhp",
                         scores, Lmat, xc)

    # chunk states: S_c = Σ_t exp(cum[Q−1] − cum[t]) B_t x_tᵀ
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                   # (B,c,Q,H)
    states = jnp.einsum("bcthn,bcth,bcthp->bchpn", Bc, tail, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (B,c,H)

    # inter-chunk recurrence over c
    def carry_fn(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, hprevs = jax.lax.scan(
        carry_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                       # (B,c,H,P,N)

    inner = jnp.exp(cum)                                      # decay 0..t
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Cc, inner, hprevs)
    out = (y_intra + y_inter).reshape(b, seq, h, p)
    return out[:, :orig_seq]


def init_ssm_cache(cfg: ArchConfig, batch: int) -> dict:
    s, d_inner, n_heads = _dims(cfg)
    return {
        "state": jnp.zeros((batch, n_heads, s.head_dim, s.state_dim),
                           jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1,
                           d_inner + 2 * n_heads * s.state_dim),
                          jnp.dtype(cfg.param_dtype)),
    }
