"""mistral-nemo-12b — dense GQA, 128k ctx. [hf:mistralai/Mistral-Nemo-
Base-2407; hf]: 40L, d_model 5120, 32H, kv=8, head_dim 128, d_ff 14336,
vocab 131072. Pure full attention → long_500k skipped (DESIGN §4)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    block_pattern=("global",),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
