"""whisper-small — encoder-decoder audio backbone, conv frontend STUB.
[arXiv:2212.04356; unverified]: 12+12L, d_model 768, 12H (MHA), head_dim 64,
d_ff 3072, vocab 51865, 1500 mel frames. ``input_specs()`` provides
precomputed frame embeddings. Learned positions are extended to 32768 to
mechanically support the decode_32k cell (noted in DESIGN §4); long_500k is
inapplicable (enc-dec short decoder)."""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    block_pattern=("global",),
    rope_mode="none",
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
    enc_dec=True,
    max_position=32768,
)
