"""Config registry: ``get_config(arch)``, ``SHAPES``, smoke reductions."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ArchConfig, EncoderConfig, MoEConfig,
                                RGLRUConfig, SHAPES, ShapeSpec, SSMConfig,
                                shape_applicable)

from repro.configs import (chatglm3_6b, deepseek_moe_16b, gemma3_4b,
                           internvl2_26b, mamba2_1_3b, mistral_nemo_12b,
                           olmoe_1b_7b, qwen3_0_6b, recurrentgemma_2b,
                           whisper_small)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (gemma3_4b, mistral_nemo_12b, qwen3_0_6b, chatglm3_6b,
              deepseek_moe_16b, olmoe_1b_7b, mamba2_1_3b,
              recurrentgemma_2b, internvl2_26b, whisper_small)
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small widths, few
    layers/experts, tiny vocab; structure (pattern, GQA ratio, MoE topology,
    qk_norm, rope mode) preserved."""
    pat = tuple(cfg.block_pattern)
    n_layers = len(pat) + min(2, len(pat))  # ≥1 full pattern + remainder
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, (cfg.n_heads // max(1, cfg.n_kv_heads)) * kv)
    changes: dict = dict(
        n_layers=n_layers,
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=32,
        d_ff=256,
        vocab=512,
        window=32,
        max_position=4096,
        param_dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_shared=64 if cfg.moe.num_shared else 0,
            d_ff_dense=256 if cfg.moe.first_k_dense else 0)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk=8)
    if cfg.rglru is not None:
        changes["rglru"] = dataclasses.replace(cfg.rglru, lru_width=128)
    if cfg.encoder is not None:
        changes["encoder"] = dataclasses.replace(
            cfg.encoder,
            n_layers=2 if cfg.encoder.n_layers else 0,
            n_frames=24, n_patches=16,
            frontend_dim=48 if cfg.encoder.frontend_dim else 0)
    return dataclasses.replace(cfg, **changes)


__all__ = ["ARCHS", "ArchConfig", "EncoderConfig", "MoEConfig",
           "RGLRUConfig", "SHAPES", "SSMConfig", "ShapeSpec", "get_config",
           "list_archs", "shape_applicable", "smoke_config"]
