"""mamba2-1.3b — attention-free SSM with state-space duality.
[arXiv:2405.21060; unverified]: 48L, d_model 2048, ssm_state 128,
head_dim 64, expand 2, vocab 50280. O(1) decode state → long_500k runs."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,           # attention-free; SSD heads live in SSMConfig
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    block_pattern=("ssm",),
    rope_mode="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    sub_quadratic=True,
)
