"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig`` in its own module under
``repro.configs``; the four input-shape cells are ``ShapeSpec``s. The
registry resolves ``--arch`` / ``--shape`` strings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0
    first_k_dense: int = 0          # leading dense layers (deepseek-moe)
    d_ff_dense: int = 0             # their FFN width
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0              # 0 → d_model
    conv_width: int = 4
    c_constant: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) / frontend stubs (vlm)."""
    n_layers: int = 0
    n_frames: int = 1500            # whisper: mel frames after conv stub
    n_patches: int = 1024           # vlm: vision patches after ViT stub
    frontend_dim: int = 0           # stub embedding dim (0 → d_model)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # attention structure
    block_pattern: Sequence[str] = ("global",)   # per-layer kinds, repeated
    window: int = 1024                            # sliding-window size
    rope_theta: float = 10000.0
    rope_mode: str = "full"         # full | half (chatglm 2d) | none
    qk_norm: bool = False
    logits_softcap: float = 0.0
    # substructure configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    # numerics
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # capability flags (shape applicability, DESIGN §4)
    sub_quadratic: bool = False     # can run long_500k
    enc_dec: bool = False
    max_position: int = 1 << 20

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def pattern_layers(self) -> tuple[int, int, Sequence[str]]:
        """(full_repeats, remainder, pattern) covering n_layers."""
        p = len(self.block_pattern)
        return self.n_layers // p, self.n_layers % p, self.block_pattern

    def param_count(self) -> int:
        """Total parameters N (embedding included once if tied)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        attn_o = self.n_heads * self.head_dim * d
        per_layer = 0
        counts = {"attn": 0, "ffn": 0, "ssm": 0, "rglru": 0}
        reps, rem, pattern = self.pattern_layers()
        kinds = list(pattern) * reps + list(pattern[:rem])
        total = 0
        for li, kind in enumerate(kinds):
            total += 2 * d  # norms
            if kind in ("global", "local"):
                total += qkv + attn_o
                total += self._ffn_params(li)
            elif kind == "rglru":
                w = (self.rglru.lru_width or d) if self.rglru else d
                total += 2 * d * w + 2 * w + w * (self.rglru.conv_width
                                                  if self.rglru else 4)
                total += w * d
                total += self._ffn_params(li)
            elif kind == "ssm":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                total += d * (2 * d_in + 2 * nheads * s.state_dim + nheads)
                total += d_in * s.conv_width + d_in * d + 2 * nheads
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        del per_layer, counts
        return total

    def _ffn_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.moe is None:
            return 3 * d * self.d_ff  # SwiGLU
        m = self.moe
        if layer_idx < m.first_k_dense:
            return 3 * d * m.d_ff_dense
        total = m.num_experts * 3 * d * m.d_ff_expert
        total += m.num_shared * 3 * d * m.d_ff_shared
        total += d * m.num_experts  # router
        return total

    def active_param_count(self) -> int:
        """N_active for MoE (6·N_active·D MODEL_FLOPS convention)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_total = self.param_count()
        routed_all = (self.n_layers - m.first_k_dense) * \
            m.num_experts * 3 * self.d_model * m.d_ff_expert
        routed_active = (self.n_layers - m.first_k_dense) * \
            m.top_k * 3 * self.d_model * m.d_ff_expert
        return dense_total - routed_all + routed_active


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode
    needs_sub_quadratic: bool = False


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode",
                           needs_sub_quadratic=True),
}


def shape_applicable(arch: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """DESIGN §4 applicability matrix."""
    if shape.needs_sub_quadratic and not arch.sub_quadratic:
        return False, ("pure full-attention arch — 500k decode KV cache is "
                       "quadratic-history; skipped per DESIGN §4")
    if arch.enc_dec and shape.needs_sub_quadratic:
        return False, "enc-dec decoder is short-context by construction"
    return True, ""
