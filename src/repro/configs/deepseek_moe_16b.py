"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6,
first layer dense. [arXiv:2401.06066; hf]: 28L, d_model 2048, 16H (MHA),
head_dim 128, expert d_ff 1408, dense d_ff 10944, vocab 102400."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,              # dense-layer FFN width
    vocab=102400,
    block_pattern=("global",),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared=2,
        d_ff_shared=1408,
        first_k_dense=1,
        d_ff_dense=10944,
    ),
    tie_embeddings=False,
)
