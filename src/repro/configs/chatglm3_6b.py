"""chatglm3-6b — dense, aggressive GQA (kv=2), 2d/partial RoPE.
[arXiv:2406.12793; hf]: 28L, d_model 4096, 32H, kv=2, head_dim 128,
d_ff 13696, vocab 65024. RoPE applied to half the head dims (GLM style)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=65024,
    block_pattern=("global",),
    rope_mode="half",
    tie_embeddings=False,
)
