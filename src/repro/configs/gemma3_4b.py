"""gemma3-4b — dense, 5:1 local:global interleaved attention, 128k ctx.

[hf:google/gemma-3-*-pt; unverified]: 34L, d_model 2560, 8 q-heads,
GQA kv=4, head_dim 256, d_ff 10240, vocab 262144, sliding window 1024.
Sub-quadratic long-context: 5/6 of layers are windowed; global layers
decode against a data-axis-sharded KV cache (DESIGN §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1_000_000.0,
    sub_quadratic=True,
)
