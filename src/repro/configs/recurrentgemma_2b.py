"""recurrentgemma-2b — RG-LRU + local attention hybrid, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]: 26L, d_model 2560, 10H, MQA kv=1, head_dim 256,
d_ff 7680, vocab 256000, lru_width 2560, window 2048. Linear recurrence +
windowed attention → long_500k runs (DESIGN §4)."""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    sub_quadratic=True,
)
