"""qwen3-0.6b — dense GQA with qk_norm. [hf:Qwen/Qwen3-0.6B; hf]:
28L, d_model 1024, 16H, kv=8, head_dim 128, d_ff 3072, vocab 151936."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab=151936,
    block_pattern=("global",),
    qk_norm=True,
    rope_theta=1_000_000.0,
)
