"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2 backbone.
[arXiv:2404.16821; hf]: backbone 48L, d_model 6144, 48H, kv=8, head_dim 128,
d_ff 16384, vocab 92553. The vision tower is stubbed per the assignment:
``input_specs()`` provides precomputed patch embeddings (frontend_dim=3200,
InternViT-6B width) projected into the LM width."""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    block_pattern=("global",),
    encoder=EncoderConfig(n_patches=1024, frontend_dim=3200),
    tie_embeddings=False,
)
