"""Data substrate: synthetic datasets, resumable pipeline, semantic dedup."""
from repro.data.synthetic import (brute_force_pairs, clustered_vectors,
                                  epsilon_for_avg_neighbors, uniform_vectors)

__all__ = ["brute_force_pairs", "clustered_vectors",
           "epsilon_for_avg_neighbors", "uniform_vectors"]
