"""Deterministic, resumable, sharded data pipeline.

A ``TokenPipeline`` yields fixed-shape token batches from an (emulated)
corpus with three production properties:

  * **determinism** — batch t is a pure function of (seed, step), so every
    host computes its own shard with zero coordination;
  * **resumability** — the cursor is one integer (`step`), checkpointed in
    the manifest; restore → identical stream continuation;
  * **sharding** — each host materializes only its
    ``global_batch / num_hosts`` slice.

The dedup stage (``repro.data.dedup``) plugs in as a document filter built
from DiskJoin output — the paper's flagship application.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    drop_ids: Optional[np.ndarray] = None   # dedup-dropped document ids
    docs_per_batch_element: int = 1


class TokenPipeline:
    """Synthetic-corpus pipeline with deterministic per-step RNG.

    Documents are id-addressed; a document's tokens are a pure function of
    its id. ``drop_ids`` (from semantic dedup) are skipped by remapping to
    their survivor representative — mirroring how a real pipeline consumes
    the DiskJoin output.
    """

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide among hosts")
        self.local_batch = cfg.global_batch // cfg.num_hosts
        self._drop_lookup = (set(int(i) for i in cfg.drop_ids)
                             if cfg.drop_ids is not None else set())
        self.step = 0

    # -- determinism core ----------------------------------------------------
    def _doc_ids_for_step(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, step))
        ids = rng.integers(0, 2 ** 31 - 1,
                           size=(self.cfg.global_batch,))
        lo = self.cfg.host_id * self.local_batch
        return ids[lo:lo + self.local_batch]

    def _doc_tokens(self, doc_id: int) -> np.ndarray:
        if doc_id in self._drop_lookup:
            doc_id = doc_id // 2  # deterministic survivor remap
        rng = np.random.default_rng((doc_id, 7))
        return rng.integers(0, self.cfg.vocab,
                            size=(self.cfg.seq_len,), dtype=np.int32)

    # -- public API -----------------------------------------------------------
    def batch_at(self, step: int) -> dict:
        ids = self._doc_ids_for_step(step)
        tokens = np.stack([self._doc_tokens(int(i)) for i in ids])
        return {"tokens": tokens, "labels": tokens.copy()}

    def __iter__(self) -> Iterator[dict]:
        while True:
            b = self.batch_at(self.step)
            self.step += 1
            yield b

    # -- checkpoint integration ------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed,
                "host_id": self.cfg.host_id}

    def restore(self, state: dict) -> None:
        if state.get("seed") != self.cfg.seed:
            raise ValueError("pipeline seed mismatch on restore")
        self.step = int(state["step"])
