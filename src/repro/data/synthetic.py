"""Synthetic vector datasets with controllable neighbor structure.

Clustered Gaussian mixtures mimic embedding-space geometry (local density +
global spread), which is what makes bucketization effective. ``epsilon_for_
avg_neighbors`` calibrates ε so each vector has ~k similar neighbors —
the paper's protocol ("set ε such that each vector has 100 similar vectors
on average").
"""
from __future__ import annotations

import numpy as np


def clustered_vectors(n: int, dim: int, *, clusters: int | None = None,
                      spread: float = 1.0, cluster_std: float = 0.08,
                      cluster_std_range: tuple | None = None,
                      intrinsic_dim: int | None = None,
                      seed: int = 0) -> np.ndarray:
    """Gaussian-mixture embeddings with low intrinsic dimension.

    Real embedding spaces concentrate on low-dimensional manifolds — the
    regime where the paper's geometric pruning has power. We sample the
    mixture in an ``intrinsic_dim``-dimensional latent space (default
    min(dim, 12)) and project through a random orthonormal map, plus small
    ambient noise. Full-rank isotropic Gaussians (``intrinsic_dim=dim``)
    are the adversarial case: nearest-neighbor distances concentrate and
    no geometric filter separates anything.
    """
    rng = np.random.default_rng(seed)
    clusters = clusters or max(4, n // 256)
    idim = intrinsic_dim or min(dim, 12)
    centers = rng.normal(scale=spread, size=(clusters, idim))
    assign = rng.integers(0, clusters, size=n)
    if cluster_std_range is not None:
        # heterogeneous density — dense cores + diffuse regions, the
        # regime real embedding spaces exhibit and where the paper's
        # probabilistic pruning (radius-dependent) has bite
        lo, hi = cluster_std_range
        stds = np.exp(rng.uniform(np.log(lo), np.log(hi), size=clusters))
        per_point_std = stds[assign][:, None]
    else:
        per_point_std = cluster_std
    z = centers[assign] + rng.normal(size=(n, idim)) * per_point_std
    if idim == dim:
        x = z
    else:
        proj = np.linalg.qr(rng.normal(size=(dim, idim)))[0]  # orthonormal
        x = z @ proj.T + rng.normal(scale=cluster_std * 0.1, size=(n, dim))
    return x.astype(np.float32)


def uniform_vectors(n: int, dim: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(-1, 1, size=(n, dim)).astype(np.float32)


def brute_force_pairs(x: np.ndarray, epsilon: float,
                      block: int = 2048) -> np.ndarray:
    """Exact ground-truth ε-pairs (a < b), blocked to bound memory."""
    n = x.shape[0]
    eps2 = epsilon * epsilon
    out = []
    sq = np.sum(x.astype(np.float64) ** 2, axis=1)
    for i0 in range(0, n, block):
        i1 = min(n, i0 + block)
        for j0 in range(i0, n, block):
            j1 = min(n, j0 + block)
            d2 = (sq[i0:i1, None] - 2.0 * x[i0:i1] @ x[j0:j1].T
                  + sq[None, j0:j1])
            rows, cols = np.nonzero(d2 <= eps2)
            rows = rows + i0
            cols = cols + j0
            keep = rows < cols
            if keep.any():
                out.append(np.stack([rows[keep], cols[keep]], axis=1))
    if not out:
        return np.zeros((0, 2), np.int64)
    return np.concatenate(out).astype(np.int64)


def epsilon_for_avg_neighbors(x: np.ndarray, k: int,
                              sample: int = 512, seed: int = 0) -> float:
    """Calibrate ε so the average #ε-neighbors per vector ≈ k."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = rng.choice(n, size=min(sample, n), replace=False)
    q = x[idx].astype(np.float64)
    sq = np.sum(x.astype(np.float64) ** 2, axis=1)
    d2 = (np.sum(q * q, axis=1)[:, None] - 2.0 * q @ x.T + sq[None, :])
    d2 = np.maximum(d2, 0)
    kth = np.sort(d2, axis=1)[:, min(k, n - 1)]  # k-th neighbor (excl. self)
    return float(np.sqrt(np.median(kth)))
