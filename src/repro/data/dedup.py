"""Semantic deduplication — DiskJoin's flagship application (paper §1).

Runs the similarity self-join over document embeddings and collapses each
connected component of the ε-pair graph to one survivor (union-find), as in
SemDeDup-style pipelines. Returns the drop list the data pipeline consumes.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

import numpy as np

from repro.core import JoinConfig, similarity_self_join
from repro.store.vector_store import FlatVectorStore


class UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        while self.parent[x] != root:       # path compression
            self.parent[x], x = root, self.parent[x]
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)  # keep smallest id


@dataclasses.dataclass
class DedupReport:
    num_docs: int
    num_pairs: int
    num_dropped: int
    drop_ids: np.ndarray        # documents to drop (non-survivors)
    keep_ids: np.ndarray
    join_stats: dict

    @property
    def dedup_rate(self) -> float:
        return self.num_dropped / max(1, self.num_docs)


def semantic_dedup(embeddings: np.ndarray, epsilon: float, *,
                   recall_target: float = 0.9,
                   memory_fraction: float = 0.1,
                   workdir: str | None = None,
                   join_config: JoinConfig | None = None) -> DedupReport:
    """embeddings: (N, d) float32 document embeddings → DedupReport."""
    n = embeddings.shape[0]
    workdir = workdir or tempfile.mkdtemp(prefix="dedup_")
    os.makedirs(workdir, exist_ok=True)
    store = FlatVectorStore.from_array(
        os.path.join(workdir, "embeddings.bin"),
        embeddings.astype(np.float32))
    cfg = join_config or JoinConfig(
        epsilon=epsilon,
        recall_target=recall_target,
        memory_budget_bytes=max(1 << 20,
                                int(store.nbytes * memory_fraction)),
        pad_align=64,
    )
    result = similarity_self_join(store, cfg, workdir=workdir)

    uf = UnionFind(n)
    for a, b in result.pairs:
        uf.union(int(a), int(b))
    roots = np.asarray([uf.find(i) for i in range(n)])
    keep = roots == np.arange(n)
    return DedupReport(
        num_docs=n,
        num_pairs=int(result.pairs.shape[0]),
        num_dropped=int((~keep).sum()),
        drop_ids=np.flatnonzero(~keep),
        keep_ids=np.flatnonzero(keep),
        join_stats={
            "distance_computations": result.num_distance_computations,
            "cache_hit_rate": result.cache_hit_rate,
            "read_amplification":
                result.io_stats.get("read_amplification", 1.0),
            "timings": result.timings,
        },
    )
