"""Bucketed vector store — the framework's "SSD" tier.

Real file-backed storage with byte-level I/O accounting so the paper's
read-amplification and disk-traffic claims are measurable on any box.
"""
from repro.store.io_stats import IOStats
from repro.store.striped_store import StripedBucketedVectorStore
from repro.store.vector_store import BucketedVectorStore, FlatVectorStore

__all__ = ["IOStats", "BucketedVectorStore", "FlatVectorStore",
           "StripedBucketedVectorStore"]
