"""Multi-SSD striped bucketed store (ROADMAP: multi-SSD striping).

One NVMe queue should never be the ceiling when the schedule already knows
every future read: ``StripedBucketedVectorStore`` maps each bucket to one
of D backing files ("devices") so the prefetcher can keep every device's
submission queue full independently. Two placement policies:

  ``phase``  — round-robin over the *disk layout order* (Gorder/schedule
               order when the writer was given one): schedule-consecutive
               misses land on distinct devices, saturating all D queues.
  ``hash``   — bucket id mod D: order-oblivious, uniform by count.

Each device file is itself a ``BucketedVectorStore`` packing its buckets
in layout-rank order, so two rank-adjacent buckets on the same device are
always disk-adjacent — the property the prefetcher's coalescer exploits
(``contiguous_after`` / ``read_run_into``). All devices share one
``IOStats``, so amplification/traffic accounting is unchanged.

Files: ``<path>.meta`` (striping map) + per-device ``<path>.d<k>[.*]``
(standard bucketed-store files over that device's bucket subset) +
top-level ``<path>.centers.npy`` / ``<path>.radii.npy``.
"""
from __future__ import annotations

import json

import numpy as np

from repro.store.io_stats import IOStats
from repro.store.vector_store import BucketedVectorStore, check_layout_order


def _device_path(path: str, dev: int) -> str:
    return f"{path}.d{dev}"


# phase-striping chunk used when read coalescing is on: runs of this many
# layout-rank-consecutive buckets share a device (coalescible into one
# sequential read) while chunks still round-robin across devices. Half the
# prefetcher's MAX_BATCH, so a typical lookahead window (≥ chunk × D)
# keeps every device busy and still forms multi-bucket runs.
COALESCE_STRIPE_CHUNK = 4


class StripedBucketedVectorStore:
    """Bucketed store striped over D backing files; one read queue each.

    Same read surface as ``BucketedVectorStore`` (``read_bucket``,
    ``read_bucket_into``, ``read_run_into``, stats) plus the device
    surface (``num_devices``, ``device_of``) the per-device prefetcher
    routes on.
    """

    def __init__(self, path: str, stats: IOStats | None = None,
                 read_latency_s: float = 0.0):
        self.path = path
        self.stats = stats if stats is not None else IOStats()
        with open(path + ".meta") as f:
            meta = json.load(f)
        if not meta.get("striped"):
            raise ValueError(f"{path}.meta is not a striped-store meta")
        self.num_devices = int(meta["num_devices"])
        self.stripe_by = meta.get("stripe_by", "phase")
        self._device_of = np.asarray(meta["device_of"], dtype=np.int64)
        self._local_id = np.asarray(meta["local_id"], dtype=np.int64)
        self.devices = [
            BucketedVectorStore(_device_path(path, d), stats=self.stats)
            for d in range(self.num_devices)]
        self.dim = self.devices[0].dim
        self.dtype = self.devices[0].dtype
        self.row_bytes = self.devices[0].row_bytes
        self.bucket_sizes = np.asarray(meta["sizes"], dtype=np.int64)
        self.num_buckets = len(self.bucket_sizes)
        self.num_vectors = int(self.bucket_sizes.sum())
        self.centers = np.load(path + ".centers.npy")
        self.radii = np.load(path + ".radii.npy")
        self.read_latency_s = read_latency_s

    # emulated latency is charged by the device performing the read
    @property
    def read_latency_s(self) -> float:
        return self.devices[0].read_latency_s

    @read_latency_s.setter
    def read_latency_s(self, value: float) -> None:
        for dev in self.devices:
            dev.read_latency_s = value

    # -- construction -------------------------------------------------------
    @staticmethod
    def create(path: str, dim: int, dtype, bucket_sizes: np.ndarray,
               centers: np.ndarray, radii: np.ndarray,
               num_devices: int, stats: IOStats | None = None,
               layout_order: np.ndarray | None = None,
               stripe_by: str = "phase",
               stripe_chunk: int = 1) -> "_StripedWriter":
        """``stripe_chunk`` (phase striping only): consecutive layout
        ranks share a device in runs of this size before rotating —
        chunk 1 maximizes fan-out, larger chunks keep schedule-adjacent
        buckets coalescible on one device."""
        return _StripedWriter(path, dim, np.dtype(dtype),
                              np.asarray(bucket_sizes, dtype=np.int64),
                              centers, radii, int(num_devices),
                              stats if stats is not None else IOStats(),
                              layout_order, stripe_by, int(stripe_chunk))

    # -- device surface ------------------------------------------------------
    def device_of(self, b: int) -> int:
        return int(self._device_of[b])

    def contiguous_after(self, a: int, b: int) -> bool:
        """Disk-adjacent ⇔ same device and adjacent in its file."""
        if self._device_of[a] != self._device_of[b]:
            return False
        dev = self.devices[int(self._device_of[a])]
        return dev.contiguous_after(int(self._local_id[a]),
                                    int(self._local_id[b]))

    def layout_keys(self, buckets) -> np.ndarray:
        """Disk-placement sort key (see ``BucketedVectorStore.layout_keys``).

        Offset-major with the device as tie-break: sorting an unordered
        miss set by this key keeps each device's disk-contiguous buckets
        adjacent (coalescible) while still interleaving devices at extent
        granularity, so one device's backlog never serializes the rest."""
        buckets = np.asarray(buckets, dtype=np.int64)
        devs = self._device_of[buckets]
        keys = np.empty(len(buckets), dtype=np.int64)
        for d in range(self.num_devices):
            m = devs == d
            if m.any():
                local = self._local_id[buckets[m]]
                keys[m] = (self.devices[d].bucket_offsets[local]
                           * self.num_devices + d)
        return keys

    # -- reads ---------------------------------------------------------------
    def read_bucket(self, b: int) -> tuple[np.ndarray, np.ndarray]:
        return self.devices[self.device_of(b)].read_bucket(
            int(self._local_id[b]))

    def read_bucket_into(self, b: int, out_vecs: np.ndarray,
                         out_ids: np.ndarray,
                         pad_value: float = 0.0) -> int:
        return self.devices[self.device_of(b)].read_bucket_into(
            int(self._local_id[b]), out_vecs, out_ids, pad_value=pad_value)

    def read_run_into(self, buckets, out_vecs, out_ids,
                      pad_value: float = 0.0) -> list[int]:
        dev = self.device_of(buckets[0])
        if any(self.device_of(b) != dev for b in buckets[1:]):
            raise ValueError("coalesced run spans devices")
        local = [int(self._local_id[b]) for b in buckets]
        return self.devices[dev].read_run_into(local, out_vecs, out_ids,
                                               pad_value=pad_value)

    # -- sizing / lifecycle --------------------------------------------------
    def bucket_nbytes(self, b: int) -> int:
        return int(self.bucket_sizes[b]) * self.row_bytes

    @property
    def nbytes(self) -> int:
        return self.num_vectors * self.row_bytes

    def device_loads_balanced(self) -> np.ndarray:
        """Bytes resident per device (striping-balance diagnostic)."""
        out = np.zeros(self.num_devices, dtype=np.int64)
        np.add.at(out, self._device_of, self.bucket_sizes * self.row_bytes)
        return out

    def close(self) -> None:
        for dev in self.devices:
            dev.close()


class _StripedWriter:
    """Streaming writer fanned out over per-device ``_BucketedWriter``s.

    Placement is fixed up front from (layout_order, stripe_by); each
    device's writer packs its buckets in layout-rank order, which is what
    makes rank-adjacent same-device buckets disk-contiguous.
    """

    def __init__(self, path, dim, dtype, bucket_sizes, centers, radii,
                 num_devices, stats, layout_order, stripe_by,
                 stripe_chunk: int = 1):
        if num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {num_devices}")
        if stripe_by not in ("phase", "hash"):
            raise ValueError(f"stripe_by must be 'phase' or 'hash', "
                             f"got {stripe_by!r}")
        self.path = path
        self.stats = stats
        self.bucket_sizes = bucket_sizes
        num_buckets = len(bucket_sizes)
        # an empty device file would be an unmappable 0-row store
        num_devices = min(num_devices, max(1, num_buckets))
        stripe_chunk = max(1, int(stripe_chunk))
        order = (check_layout_order(layout_order, num_buckets)
                 if layout_order is not None
                 else np.arange(num_buckets, dtype=np.int64))
        rank = np.empty(num_buckets, dtype=np.int64)
        rank[order] = np.arange(num_buckets)
        if stripe_by == "phase":
            device_of = (rank // stripe_chunk) % num_devices
        else:
            device_of = np.arange(num_buckets, dtype=np.int64) % num_devices
        # chunking (or few buckets) can leave a device empty, and an empty
        # device file is unmappable — compact device ids onto those in use
        used = np.unique(device_of)
        if len(used) < num_devices:
            remap = np.full(num_devices, -1, dtype=np.int64)
            remap[used] = np.arange(len(used))
            device_of = remap[device_of]
            num_devices = len(used)
        self._device_of = device_of
        # local ids assigned in rank order per device → per-device layout
        # follows the global schedule order
        self._local_id = np.empty(num_buckets, dtype=np.int64)
        self._writers = []
        for d in range(num_devices):
            mine = order[device_of[order] == d]  # device d's buckets, by rank
            self._local_id[mine] = np.arange(len(mine))
            self._writers.append(BucketedVectorStore.create(
                _device_path(path, d), dim, dtype, bucket_sizes[mine],
                centers[mine], radii[mine], stats=stats))
        self._meta = {
            "striped": True, "num_devices": num_devices,
            "stripe_by": stripe_by, "stripe_chunk": stripe_chunk,
            "dim": dim, "dtype": np.dtype(dtype).name,
            "sizes": bucket_sizes.tolist(),
            "device_of": device_of.tolist(),
            "local_id": self._local_id.tolist(),
        }
        np.save(path + ".centers.npy", centers)
        np.save(path + ".radii.npy", radii)

    def append(self, bucket: int, vec: np.ndarray, vec_id: int) -> None:
        try:
            self._writers[int(self._device_of[bucket])].append(
                int(self._local_id[bucket]), vec, vec_id)
        except ValueError as e:
            raise ValueError(f"striped bucket {bucket}: {e}") from e

    def append_batch(self, bucket: int, vecs: np.ndarray,
                     ids: np.ndarray) -> None:
        try:
            self._writers[int(self._device_of[bucket])].append_batch(
                int(self._local_id[bucket]), vecs, ids)
        except ValueError as e:
            raise ValueError(f"striped bucket {bucket}: {e}") from e

    def finalize(self) -> StripedBucketedVectorStore:
        for w in self._writers:
            w.finalize()
        with open(self.path + ".meta", "w") as f:
            json.dump(self._meta, f)
        return StripedBucketedVectorStore(self.path, stats=self.stats)
