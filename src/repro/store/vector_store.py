"""File-backed vector stores.

``FlatVectorStore`` — row-major (N, d) array on disk, read per-vector or in
sequential blocks. This models the *input* dataset and the baseline access
pattern (per-vector reads suffer read amplification when row bytes < 4 KB).

``BucketedVectorStore`` — DiskJoin's reorganized layout: each bucket's
vectors are contiguous, fetched with one sequential read. Bucket loads are
page-aligned, so amplification ≈ bucket_bytes / page_round(bucket_bytes) → 1
for buckets ≫ 4 KB (paper Fig. 16: amp 1.003–1.004).

Both are np.memmap-backed; every access is accounted in an ``IOStats``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Iterator, Sequence

import numpy as np

from repro.store.io_stats import IOStats, read_timer, write_timer


def check_layout_order(order: np.ndarray, num_buckets: int) -> np.ndarray:
    """Validate a disk-layout permutation (O(B) numpy, no PyObject churn)."""
    order = np.asarray(order, dtype=np.int64)
    if (order.shape != (num_buckets,)
            or not np.array_equal(np.sort(order),
                                  np.arange(num_buckets, dtype=np.int64))):
        raise ValueError("layout_order must be a permutation of bucket ids")
    return order


class FlatVectorStore:
    """(N, d) float32/float16 matrix on disk with per-row and block reads."""

    def __init__(self, path: str, num_vectors: int, dim: int,
                 dtype=np.float32, stats: IOStats | None = None,
                 create: bool = False):
        self.path = path
        self.num_vectors = int(num_vectors)
        self.dim = int(dim)
        self.dtype = np.dtype(dtype)
        self.row_bytes = self.dim * self.dtype.itemsize
        self.stats = stats if stats is not None else IOStats()
        mode = "w+" if create else "r+"
        self._mm = np.memmap(path, dtype=self.dtype, mode=mode,
                             shape=(self.num_vectors, self.dim))

    # -- construction -------------------------------------------------------
    @classmethod
    def from_array(cls, path: str, data: np.ndarray,
                   stats: IOStats | None = None) -> "FlatVectorStore":
        store = cls(path, data.shape[0], data.shape[1], data.dtype,
                    stats=stats, create=True)
        store._mm[:] = data
        store._mm.flush()
        store.stats.record_write(data.nbytes)
        return store

    # -- reads --------------------------------------------------------------
    def read_vector(self, idx: int) -> np.ndarray:
        """Single-vector random read — page-granular (models SSD behaviour)."""
        with read_timer(self.stats):
            out = np.array(self._mm[idx])
        self.stats.record_read(self.row_bytes)  # page-rounded internally
        return out

    def read_rows(self, idxs: Sequence[int]) -> np.ndarray:
        """Gather of rows; each row is an independent page-granular read."""
        with read_timer(self.stats):
            out = np.array(self._mm[np.asarray(idxs, dtype=np.int64)])
        self.stats.record_reads(len(idxs), self.row_bytes)
        return out

    def read_block(self, start: int, count: int) -> np.ndarray:
        """Sequential block read — amplification amortizes to ~1."""
        with read_timer(self.stats):
            out = np.array(self._mm[start:start + count])
        self.stats.record_read(count * self.row_bytes)
        return out

    def iter_blocks(self, block_rows: int) -> Iterator[tuple[int, np.ndarray]]:
        """Stream the dataset in sequential blocks (one full scan)."""
        for start in range(0, self.num_vectors, block_rows):
            count = min(block_rows, self.num_vectors - start)
            yield start, self.read_block(start, count)

    # -- writes -------------------------------------------------------------
    def write_block(self, start: int, data: np.ndarray) -> None:
        with write_timer(self.stats):
            self._mm[start:start + data.shape[0]] = data
        self.stats.record_write(data.nbytes)

    def flush(self) -> None:
        self._mm.flush()

    @property
    def nbytes(self) -> int:
        return self.num_vectors * self.row_bytes

    def close(self) -> None:
        del self._mm


class BucketedVectorStore:
    """DiskJoin's on-disk layout: buckets stored contiguously.

    Files:
      <path>         — the concatenated vector data
      <path>.meta    — JSON: dim, dtype, bucket offsets/sizes, centers file
      <path>.ids     — int64 original vector ids, same layout as data
      <path>.centers — (B, d) centers;  <path>.radii — (B,) radii
    """

    def __init__(self, path: str, stats: IOStats | None = None,
                 fragment_rows: int | None = None,
                 read_latency_s: float = 0.0):
        """``fragment_rows``: emulate file-system fragmentation (paper
        Fig. 14) — each bucket read is accounted as ⌈size/fragment⌉
        page-rounded extent reads instead of one sequential read.
        ``read_latency_s``: emulate SSD access latency — each bucket read
        sleeps this long (page-cache memmap reads are RAM-speed in this
        container; the latency knob restores the paper's I/O-bound regime
        for the pipeline benchmarks)."""
        self.path = path
        self.stats = stats if stats is not None else IOStats()
        self.fragment_rows = fragment_rows
        self.read_latency_s = read_latency_s
        with open(path + ".meta") as f:
            meta = json.load(f)
        self.dim = int(meta["dim"])
        self.dtype = np.dtype(meta["dtype"])
        self.row_bytes = self.dim * self.dtype.itemsize
        self.bucket_offsets = np.asarray(meta["offsets"], dtype=np.int64)
        self.bucket_sizes = np.asarray(meta["sizes"], dtype=np.int64)
        self.num_buckets = len(self.bucket_sizes)
        self.num_vectors = int(self.bucket_sizes.sum())
        self._mm = np.memmap(path, dtype=self.dtype, mode="r",
                             shape=(self.num_vectors, self.dim))
        self._ids = np.memmap(path + ".ids", dtype=np.int64, mode="r",
                              shape=(self.num_vectors,))
        self.centers = np.load(path + ".centers.npy")
        self.radii = np.load(path + ".radii.npy")

    # -- construction -------------------------------------------------------
    @staticmethod
    def create(path: str, dim: int, dtype, bucket_sizes: np.ndarray,
               centers: np.ndarray, radii: np.ndarray,
               stats: IOStats | None = None,
               layout_order: np.ndarray | None = None) -> "_BucketedWriter":
        """``layout_order``: permutation of bucket ids giving their on-disk
        extent order (Gorder/schedule order ⇒ schedule-adjacent buckets are
        disk-adjacent, enabling coalesced sequential reads). None = id
        order."""
        return _BucketedWriter(path, dim, np.dtype(dtype), bucket_sizes,
                               centers, radii,
                               stats if stats is not None else IOStats(),
                               layout_order=layout_order)

    # -- device surface (uniform with StripedBucketedVectorStore) -----------
    num_devices = 1

    def device_of(self, b: int) -> int:
        return 0

    def contiguous_after(self, a: int, b: int) -> bool:
        """True iff bucket ``b``'s extent starts where ``a``'s ends.

        Under emulated file-system fragmentation nothing is guaranteed
        contiguous, so coalescing is disabled — ``read_run_into`` would
        otherwise charge one sequential read for extents the fragmented
        file cannot physically serve that way.
        """
        if self.fragment_rows:
            return False
        return (int(self.bucket_offsets[b])
                == int(self.bucket_offsets[a]) + int(self.bucket_sizes[a]))

    def layout_keys(self, buckets) -> np.ndarray:
        """Disk-placement sort key per bucket: an *unordered* bucket set
        (e.g. a serving wave's unioned miss set) read in ascending key
        order visits the file in extent order, so disk-adjacent buckets
        become read-adjacent and the prefetcher's batching/coalescing
        applies to ad-hoc sets the same way it does to join schedules."""
        return self.bucket_offsets[np.asarray(buckets, dtype=np.int64)]

    # -- reads --------------------------------------------------------------
    def read_bucket(self, b: int) -> tuple[np.ndarray, np.ndarray]:
        """One sequential read of bucket b → (vectors, original ids)."""
        size = int(self.bucket_sizes[b])
        vecs = np.empty((size, self.dim), self.dtype)
        ids = np.empty(size, np.int64)
        self.read_bucket_into(b, vecs, ids)
        return vecs, ids

    def read_bucket_into(self, b: int, out_vecs: np.ndarray,
                         out_ids: np.ndarray,
                         pad_value: float = 0.0) -> int:
        """Read bucket ``b`` directly into preallocated slabs (no per-read
        allocation — the hot path of the prefetching I/O subsystem).

        ``out_vecs``: (capacity, dim) float32, ``out_ids``: (capacity,)
        int64 with capacity >= bucket size; rows past the bucket are filled
        with ``pad_value`` / -1. Returns the bucket's row count.
        ``read_bucket`` delegates here, so sync and prefetch reads share
        one accounting path.

        One page-aligned sequential read per bucket (vectors dominate; the
        id sidecar is read alongside and accounted at byte granularity) —
        under emulated fragmentation, one read per extent instead.
        """
        off = int(self.bucket_offsets[b])
        size = int(self.bucket_sizes[b])
        with read_timer(self.stats):
            if self.read_latency_s:
                time.sleep(self.read_latency_s)
            out_vecs[:size] = self._mm[off:off + size]
            out_ids[:size] = self._ids[off:off + size]
        out_vecs[size:] = pad_value
        out_ids[size:] = -1
        if self.fragment_rows and size:
            extents = -(-size // self.fragment_rows)
            full, last = extents - 1, size - (extents - 1) * self.fragment_rows
            self.stats.record_reads(full, self.fragment_rows * self.row_bytes)
            self.stats.record_read(last * self.row_bytes)
        else:
            self.stats.record_read(size * self.row_bytes)
        self.stats.record_read(size * 8, page_aligned=False)
        return size

    def read_run_into(self, buckets, out_vecs, out_ids,
                      pad_value: float = 0.0) -> list[int]:
        """Coalesced read: a disk-contiguous run of buckets fetched as ONE
        sequential read, split into per-bucket slabs on completion.

        ``buckets`` must satisfy ``contiguous_after`` pairwise (the
        prefetcher's coalescer guarantees this); the whole run is accounted
        as a single read op and charged one emulated-latency access.
        """
        for a, b in zip(buckets, buckets[1:]):
            if not self.contiguous_after(a, b):
                raise ValueError(f"buckets {a},{b} are not disk-contiguous")
        sizes = [int(self.bucket_sizes[b]) for b in buckets]
        with read_timer(self.stats):
            if self.read_latency_s:
                time.sleep(self.read_latency_s)
            for b, n, ov, oi in zip(buckets, sizes, out_vecs, out_ids):
                off = int(self.bucket_offsets[b])
                ov[:n] = self._mm[off:off + n]
                oi[:n] = self._ids[off:off + n]
                ov[n:] = pad_value
                oi[n:] = -1
        total = sum(sizes)
        self.stats.record_read(total * self.row_bytes)
        self.stats.record_read(total * 8, page_aligned=False)
        return sizes

    def bucket_nbytes(self, b: int) -> int:
        return int(self.bucket_sizes[b]) * self.row_bytes

    @property
    def nbytes(self) -> int:
        return self.num_vectors * self.row_bytes

    def close(self) -> None:
        del self._mm
        del self._ids


class _BucketedWriter:
    """Streaming writer with per-bucket buffers (paper §5.1).

    Vectors are appended to in-memory per-bucket buffers and flushed to their
    reserved disk extent when the buffer fills — avoiding sub-page writes
    (write amplification). Buffer memory is bounded by
    ``buffer_rows_per_bucket × num_buckets × row_bytes``.
    """

    def __init__(self, path, dim, dtype, bucket_sizes, centers, radii, stats,
                 buffer_rows_per_bucket: int = 64,
                 layout_order: np.ndarray | None = None):
        self.path = path
        self.dim = dim
        self.dtype = dtype
        self.stats = stats
        self.bucket_sizes = np.asarray(bucket_sizes, dtype=np.int64)
        if layout_order is None:
            self.bucket_offsets = np.concatenate(
                [[0], np.cumsum(self.bucket_sizes)[:-1]])
        else:
            order = check_layout_order(layout_order, len(self.bucket_sizes))
            ordered = self.bucket_sizes[order]
            csum = np.concatenate([[0], np.cumsum(ordered)[:-1]])
            self.bucket_offsets = np.empty_like(self.bucket_sizes)
            self.bucket_offsets[order] = csum
        self.num_vectors = int(self.bucket_sizes.sum())
        self._mm = np.memmap(path, dtype=dtype, mode="w+",
                             shape=(self.num_vectors, dim))
        self._ids = np.memmap(path + ".ids", dtype=np.int64, mode="w+",
                              shape=(self.num_vectors,))
        self._fill = np.zeros(len(bucket_sizes), dtype=np.int64)
        self._buf_cap = buffer_rows_per_bucket
        self._buf_vecs: dict[int, list[np.ndarray]] = {}
        self._buf_ids: dict[int, list[int]] = {}
        np.save(path + ".centers.npy", centers)
        np.save(path + ".radii.npy", radii)
        self._meta = {
            "dim": dim, "dtype": np.dtype(dtype).name,
            "offsets": self.bucket_offsets.tolist(),
            "sizes": self.bucket_sizes.tolist(),
        }

    def append(self, bucket: int, vec: np.ndarray, vec_id: int) -> None:
        planned = int(self.bucket_sizes[bucket])
        appended = int(self._fill[bucket]) + len(self._buf_vecs.get(bucket, ()))
        if appended >= planned:
            # without this check the flush would silently write past the
            # bucket's reserved extent into its neighbor's rows
            raise ValueError(
                f"bucket {bucket} overflow: layout reserved {planned} rows, "
                f"append #{appended + 1} (vec id {vec_id}) exceeds the extent")
        self._buf_vecs.setdefault(bucket, []).append(np.asarray(vec, self.dtype))
        self._buf_ids.setdefault(bucket, []).append(int(vec_id))
        if len(self._buf_vecs[bucket]) >= self._buf_cap:
            self._flush_bucket(bucket)

    def append_batch(self, bucket: int, vecs: np.ndarray,
                     ids: np.ndarray) -> None:
        for v, i in zip(vecs, ids):
            self.append(bucket, v, i)

    def _flush_bucket(self, b: int) -> None:
        vecs = self._buf_vecs.pop(b, [])
        ids = self._buf_ids.pop(b, [])
        if not vecs:
            return
        arr = np.stack(vecs)
        if int(self._fill[b]) + len(vecs) > int(self.bucket_sizes[b]):
            raise ValueError(
                f"bucket {b} overflow: flushing {len(vecs)} rows at fill "
                f"{int(self._fill[b])} would overrun the reserved extent of "
                f"{int(self.bucket_sizes[b])} rows")
        start = int(self.bucket_offsets[b] + self._fill[b])
        with write_timer(self.stats):
            self._mm[start:start + len(vecs)] = arr
            self._ids[start:start + len(vecs)] = np.asarray(ids)
        self.stats.record_write(arr.nbytes)
        self._fill[b] += len(vecs)

    def finalize(self) -> BucketedVectorStore:
        for b in list(self._buf_vecs.keys()):
            self._flush_bucket(b)
        if not np.array_equal(self._fill, self.bucket_sizes):
            bad = int(np.flatnonzero(self._fill != self.bucket_sizes)[0])
            raise ValueError(
                f"bucket fill mismatch: bucket {bad} appended "
                f"{int(self._fill[bad])} rows vs {int(self.bucket_sizes[bad])}"
                f" planned (totals {int(self._fill.sum())} vs "
                f"{int(self.bucket_sizes.sum())})")
        self._mm.flush()
        self._ids.flush()
        with open(self.path + ".meta", "w") as f:
            json.dump(self._meta, f)
        del self._mm, self._ids
        return BucketedVectorStore(self.path, stats=self.stats)


def dataset_path(root: str, name: str) -> str:
    os.makedirs(root, exist_ok=True)
    return os.path.join(root, name)
