"""I/O accounting: every byte that crosses the storage boundary is recorded.

The paper's Fig. 16 reports *total* vs *useful* disk traffic; the ratio is
read amplification. We track both so the same table can be produced from any
store implementation (bucketed or per-vector).

Thread safety: the prefetching I/O subsystem (``repro.io``) issues bucket
reads from a worker pool while the executor thread accounts verify-side
traffic, so all mutation goes through one lock. The lock is uncontended in
sync mode (single thread) and cheap relative to a page-sized read.
"""
from __future__ import annotations

import dataclasses
import threading
import time


PAGE_SIZE = 4096  # bytes — minimum granularity of a disk read (paper §1)


@dataclasses.dataclass
class IOStats:
    """Mutable I/O counters shared by a store and its readers."""

    read_ops: int = 0
    write_ops: int = 0
    bytes_read_total: int = 0      # page-granular traffic (what the disk does)
    bytes_read_useful: int = 0     # bytes the caller actually consumes
    bytes_written_total: int = 0
    bytes_written_useful: int = 0
    read_seconds: float = 0.0
    write_seconds: float = 0.0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def record_read(self, useful: int, *, page_aligned: bool = True) -> None:
        total = _page_round(useful) if page_aligned else useful
        with self._lock:
            self.read_ops += 1
            self.bytes_read_total += total
            self.bytes_read_useful += useful

    def record_reads(self, count: int, bytes_each: int, *,
                     page_aligned: bool = True) -> None:
        """Account ``count`` same-sized reads in one locked update (a row
        gather is one call instead of O(n) ``record_read`` calls)."""
        if count <= 0:
            return
        each = _page_round(bytes_each) if page_aligned else bytes_each
        with self._lock:
            self.read_ops += count
            self.bytes_read_total += count * each
            self.bytes_read_useful += count * bytes_each

    def record_write(self, useful: int, *, page_aligned: bool = True) -> None:
        total = _page_round(useful) if page_aligned else useful
        with self._lock:
            self.write_ops += 1
            self.bytes_written_total += total
            self.bytes_written_useful += useful

    def add_seconds(self, field: str, dt: float) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + dt)

    @property
    def read_amplification(self) -> float:
        return _amplification(self.bytes_read_total, self.bytes_read_useful)

    @property
    def write_amplification(self) -> float:
        return _amplification(self.bytes_written_total,
                              self.bytes_written_useful)

    def merge(self, other: "IOStats") -> "IOStats":
        out = IOStats()
        for f in dataclasses.fields(IOStats):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        return out

    def snapshot(self) -> dict:
        with self._lock:
            d = {f.name: getattr(self, f.name)
                 for f in dataclasses.fields(IOStats)}
        d["read_amplification"] = _amplification(d["bytes_read_total"],
                                                 d["bytes_read_useful"])
        d["write_amplification"] = _amplification(d["bytes_written_total"],
                                                  d["bytes_written_useful"])
        return d

    def reset(self) -> None:
        with self._lock:
            for f in dataclasses.fields(IOStats):
                setattr(self, f.name, type(getattr(self, f.name))())


class _Timer:
    """Context manager accumulating wall time into an IOStats field."""

    def __init__(self, stats: IOStats, field: str):
        self._stats = stats
        self._field = field

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        self._stats.add_seconds(self._field, dt)
        return False


def read_timer(stats: IOStats) -> _Timer:
    return _Timer(stats, "read_seconds")


def write_timer(stats: IOStats) -> _Timer:
    return _Timer(stats, "write_seconds")


def _amplification(total: int, useful: int) -> float:
    return total / useful if useful else 1.0


def _page_round(nbytes: int) -> int:
    if nbytes <= 0:
        return 0
    return ((nbytes + PAGE_SIZE - 1) // PAGE_SIZE) * PAGE_SIZE
