"""I/O accounting: every byte that crosses the storage boundary is recorded.

The paper's Fig. 16 reports *total* vs *useful* disk traffic; the ratio is
read amplification. We track both so the same table can be produced from any
store implementation (bucketed or per-vector).
"""
from __future__ import annotations

import dataclasses
import time


PAGE_SIZE = 4096  # bytes — minimum granularity of a disk read (paper §1)


@dataclasses.dataclass
class IOStats:
    """Mutable I/O counters shared by a store and its readers."""

    read_ops: int = 0
    write_ops: int = 0
    bytes_read_total: int = 0      # page-granular traffic (what the disk does)
    bytes_read_useful: int = 0     # bytes the caller actually consumes
    bytes_written_total: int = 0
    bytes_written_useful: int = 0
    read_seconds: float = 0.0
    write_seconds: float = 0.0

    def record_read(self, useful: int, *, page_aligned: bool = True) -> None:
        total = _page_round(useful) if page_aligned else useful
        self.read_ops += 1
        self.bytes_read_total += total
        self.bytes_read_useful += useful

    def record_write(self, useful: int, *, page_aligned: bool = True) -> None:
        total = _page_round(useful) if page_aligned else useful
        self.write_ops += 1
        self.bytes_written_total += total
        self.bytes_written_useful += useful

    @property
    def read_amplification(self) -> float:
        if self.bytes_read_useful == 0:
            return 1.0
        return self.bytes_read_total / self.bytes_read_useful

    @property
    def write_amplification(self) -> float:
        if self.bytes_written_useful == 0:
            return 1.0
        return self.bytes_written_total / self.bytes_written_useful

    def merge(self, other: "IOStats") -> "IOStats":
        out = IOStats()
        for f in dataclasses.fields(IOStats):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        return out

    def snapshot(self) -> dict:
        d = dataclasses.asdict(self)
        d["read_amplification"] = self.read_amplification
        d["write_amplification"] = self.write_amplification
        return d

    def reset(self) -> None:
        for f in dataclasses.fields(IOStats):
            setattr(self, f.name, type(getattr(self, f.name))())


class _Timer:
    """Context manager accumulating wall time into an IOStats field."""

    def __init__(self, stats: IOStats, field: str):
        self._stats = stats
        self._field = field

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        setattr(self._stats, self._field, getattr(self._stats, self._field) + dt)
        return False


def read_timer(stats: IOStats) -> _Timer:
    return _Timer(stats, "read_seconds")


def write_timer(stats: IOStats) -> _Timer:
    return _Timer(stats, "write_seconds")


def _page_round(nbytes: int) -> int:
    if nbytes <= 0:
        return 0
    return ((nbytes + PAGE_SIZE - 1) // PAGE_SIZE) * PAGE_SIZE
