"""Pallas TPU kernel — causal flash attention (online softmax).

Serving/prefill hot loop for the LM substrate. Grid (batch·heads, Sq/bq,
Skv/bkv); the innermost kv axis streams K/V tiles through VMEM while the
softmax statistics (running max m, normalizer l) and the output accumulator
stay resident in VMEM scratch for the whole row of kv steps. Causal blocks
above the diagonal are skipped via `pl.when` (no FLOPs, no HBM reads —
Pallas still prefetches the tile, so the win is compute, matching TPU's
compute-bound attention regime at these widths).

Block defaults (bq, bkv) = (128, 128); q/k/v tiles are (128, hd≤256) f32 →
≤ 384 KiB VMEM live, MXU-shaped matmuls throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BQ = 128
DEFAULT_BKV = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, nkv: int, bq: int, bkv: int):
    kv = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(kv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _step():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bkv, hd)
        v = v_ref[0].astype(jnp.float32)            # (bkv, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bkv)
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            cols = kv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        correction = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * correction + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * correction[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    if causal:
        # kv blocks strictly above the causal diagonal contribute nothing
        pl.when(kv * bkv < (iq + 1) * bq)(_step)
    else:
        _step()

    @pl.when(kv == nkv - 1)
    def _final():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "bq", "bkv",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    bq: int = DEFAULT_BQ, bkv: int = DEFAULT_BKV,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, hd), k/v: (BH, Skv, hd) → (BH, Sq, hd).

    GQA: callers repeat kv heads to match q heads before flattening BH.
    Sq % bq == 0 and Skv % bkv == 0 required (pad + mask upstream).
    """
    bh, sq, hd = q.shape
    _, skv, _ = k.shape
    if scale is None:
        scale = hd ** -0.5
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    if sq % bq or skv % bkv:
        raise ValueError(f"seq lens ({sq},{skv}) not divisible by ({bq},{bkv})")
    nkv = skv // bkv
    grid = (bh, sq // bq, nkv)
    kernel = functools.partial(_flash_kernel, scale=float(scale),
                               causal=causal, nkv=nkv, bq=bq, bkv=bkv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # normalizer l
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
