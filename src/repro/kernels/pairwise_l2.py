"""Pallas TPU kernel — blocked pairwise squared-L2 distance (+ threshold).

The verify step of DiskJoin computes d²(a, b) for every (a, b) across a
bucket pair. On TPU this is a matmul-shaped workload:

    D² = ‖a‖² − 2·A Bᵀ + ‖b‖²

Tiling: grid (M/bm, N/bn, d/bk). Each step loads an A tile (bm, bk) and a
B tile (bn, bk) into VMEM and accumulates −2·A Bᵀ into the (bm, bn) output
tile that lives in VMEM across the k loop (out block index ignores k). The
squared norms are folded in on the final k step, fused with the ε²
threshold mask — no second pass over HBM.

Block defaults (128, 128, 128) keep the MXU fully shaped: A+B tiles are
2·128·128·4 B = 128 KiB plus a 64 KiB f32 accumulator tile ≪ 16 MiB VMEM,
leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _pairwise_kernel(a_ref, b_ref, d2_ref, mask_ref, *, eps2: float,
                     nk: int):
    """One (m, n, k) grid step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        d2_ref[...] = jnp.zeros_like(d2_ref)

    a = a_ref[...].astype(jnp.float32)          # (bm, bk)
    b = b_ref[...].astype(jnp.float32)          # (bn, bk)
    # accumulate -2 A B^T plus the per-k-slice norm contributions; summing
    # |a_k|^2 and |b_k|^2 per slice is exact since norms decompose over k.
    acc = d2_ref[...]
    acc += -2.0 * jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    acc += jnp.sum(a * a, axis=1)[:, None]
    acc += jnp.sum(b * b, axis=1)[None, :]
    d2_ref[...] = acc

    @pl.when(k == nk - 1)
    def _finalize():
        d2 = jnp.maximum(d2_ref[...], 0.0)
        d2_ref[...] = d2
        mask_ref[...] = (d2 <= eps2).astype(jnp.int8)


def _pairwise_kernel_batched(a_ref, b_ref, d2_ref, mask_ref, *, eps2: float,
                             nk: int):
    """One (e, m, n, k) grid step — leading batch (edge) dimension."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        d2_ref[...] = jnp.zeros_like(d2_ref)

    a = a_ref[0].astype(jnp.float32)            # (bm, bk)
    b = b_ref[0].astype(jnp.float32)            # (bn, bk)
    acc = d2_ref[0]
    acc += -2.0 * jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    acc += jnp.sum(a * a, axis=1)[:, None]
    acc += jnp.sum(b * b, axis=1)[None, :]
    d2_ref[0] = acc

    @pl.when(k == nk - 1)
    def _finalize():
        d2 = jnp.maximum(d2_ref[...], 0.0)
        d2_ref[...] = d2
        mask_ref[...] = (d2 <= eps2).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("eps2", "bm", "bn", "bk",
                                             "interpret"))
def pairwise_l2_threshold_batched(a: jax.Array, b: jax.Array, eps2: float,
                                  bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                                  bk: int = DEFAULT_BK,
                                  interpret: bool = False):
    """(E, M, d) × (E, N, d) → (d2 (E, M, N) f32, mask (E, M, N) int8).

    One grid dispatch for a whole verify batch — the per-edge Python loop
    the executor used to run (E separate jit calls) collapses into a
    single kernel launch with a leading batch grid dimension.
    """
    e, m, d = a.shape
    _, n, _ = b.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, d)
    if m % bm or n % bn or d % bk:
        raise ValueError(f"shapes ({m},{n},{d}) not divisible by blocks "
                         f"({bm},{bn},{bk})")
    nk = d // bk
    grid = (e, m // bm, n // bn, nk)
    kernel = functools.partial(_pairwise_kernel_batched, eps2=float(eps2),
                               nk=nk)
    d2, mask = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bn, bk), lambda e, i, j, k: (e, j, k)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
            pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, m, n), jnp.float32),
            jax.ShapeDtypeStruct((e, m, n), jnp.int8),
        ],
        interpret=interpret,
    )(a, b)
    return d2, mask


@functools.partial(jax.jit, static_argnames=("eps2", "bm", "bn", "bk",
                                             "interpret"))
def pairwise_l2_threshold(a: jax.Array, b: jax.Array, eps2: float,
                          bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                          bk: int = DEFAULT_BK, interpret: bool = False):
    """(M, d) × (N, d) → (d2 (M, N) f32, mask (M, N) int8).

    M, N, d must be multiples of the block sizes — callers pad (the DiskJoin
    executor pads buckets to `bucket_capacity`, which is MXU-aligned).
    """
    m, d = a.shape
    n, _ = b.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, d)
    if m % bm or n % bn or d % bk:
        raise ValueError(f"shapes ({m},{n},{d}) not divisible by blocks "
                         f"({bm},{bn},{bk})")
    nk = d // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(_pairwise_kernel, eps2=float(eps2), nk=nk)
    d2, mask = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.int8),
        ],
        interpret=interpret,
    )(a, b)
    return d2, mask
