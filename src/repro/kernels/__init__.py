"""Pallas TPU kernels (+ jnp oracles) for the perf-critical hot spots.

- ``pairwise_l2``     — DiskJoin verify step (blocked distance + threshold)
- ``bucket_assign``   — bucketization scan-2 (fused nearest-center)
- ``flash_attention`` — LM prefill/serve attention (online softmax)

``ops`` is the only public entry point; ``ref`` holds the pure-jnp oracles
used by the per-kernel allclose test sweeps.
"""
from repro.kernels import ops, ref  # noqa: F401
