"""jit'd public wrappers around the Pallas kernels.

Every op pads its inputs to kernel block multiples, dispatches to the Pallas
kernel on TPU (interpret mode elsewhere — the kernel body runs in Python on
CPU for correctness), or to the pure-jnp reference when ``use_pallas`` is
off, and strips padding from the result. The DiskJoin executor and the model
stack call only this layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bucket_assign as _assign_kernel
from repro.kernels import flash_attention as _flash_kernel
from repro.kernels import pairwise_l2 as _pairwise_kernel
from repro.kernels import ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_rows(x, rows: int, value: float = 0.0):
    if x.shape[0] == rows:
        return x
    pad = [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=value)


# ---------------------------------------------------------------------------
# pairwise distance + threshold (DiskJoin verify step)
# ---------------------------------------------------------------------------
def pairwise_l2_threshold(a, b, eps: float, *, use_pallas: bool = False,
                          block: int = 128):
    """(M,d) × (N,d) → (d2 (M,N) f32, mask (M,N) bool). Unpadded shapes."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    eps2 = float(eps) ** 2
    if not use_pallas:
        return ref.pairwise_l2_threshold(a, b, eps2)
    m, d = a.shape
    n, _ = b.shape
    mp, np_, dp = _round_up(m, block), _round_up(n, block), _round_up(d, block)
    ap = jnp.pad(a, ((0, mp - m), (0, dp - d)))
    bp = jnp.pad(b, ((0, np_ - n), (0, dp - d)))
    d2, mask = _pairwise_kernel.pairwise_l2_threshold(
        ap, bp, eps2, interpret=not on_tpu())
    return d2[:m, :n], mask[:m, :n].astype(bool)


@functools.partial(jax.jit, static_argnames=("eps2",))
def _verify_pairs_ref(u, v, eps2: float):
    d2 = jax.vmap(ref.pairwise_l2)(u, v)
    return d2, d2 <= eps2


def verify_pairs_batch(u, v, eps: float, *, use_pallas: bool = False,
                       block: int = 128):
    """Batched verify: (E, cap, d) × (E, cap, d) → (d2, mask), (E, cap, cap).

    ONE dispatch for the whole edge batch — the Pallas path rides a
    leading batch grid dimension (``pairwise_l2_threshold_batched``)
    instead of E separate jit calls, and the reference path is the
    vmapped oracle. Both engines (``repro.compute``) consume this, so
    host and device compute modes see bitwise-identical d2.
    """
    eps2 = float(eps) ** 2
    if not use_pallas:
        return _verify_pairs_ref(u, v, eps2)
    e, m, d = u.shape
    # the kernel clamps blocks to the dims, so only dims above `block`
    # that aren't multiples of it need padding
    if d > block and d % block:
        dp = _round_up(d, block)
        u = jnp.pad(u, ((0, 0), (0, 0), (0, dp - d)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, dp - d)))
    mp = _round_up(m, block) if (m > block and m % block) else m
    if mp != m:
        # pad rows far away so they can never pass the ε² threshold
        u = jnp.pad(u, ((0, 0), (0, mp - m), (0, 0)), constant_values=1e15)
        v = jnp.pad(v, ((0, 0), (0, mp - m), (0, 0)), constant_values=1e15)
    d2, mask = _pairwise_kernel.pairwise_l2_threshold_batched(
        u, v, eps2, interpret=not on_tpu())
    if mp != m:
        d2, mask = d2[:, :m, :m], mask[:, :m, :m]
    return d2, mask.astype(bool)


# ---------------------------------------------------------------------------
# nearest-center assignment (bucketization scan 2)
# ---------------------------------------------------------------------------
def bucket_assign(x, centers, *, use_pallas: bool = True, block: int = 128):
    """(M,d) × (B,d) → (min_d2 (M,), argmin (M,) int32)."""
    x = jnp.asarray(x, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    if not use_pallas:
        return ref.bucket_assign(x, centers)
    m, d = x.shape
    b, _ = centers.shape
    mp, bp = _round_up(m, block), _round_up(b, block)
    xp = pad_rows(x, mp)
    # pad centers far away so padded rows never win the argmin
    cp = pad_rows(centers, bp, value=0.0)
    if bp != b:
        far = jnp.full((bp - b, d), 1e15, jnp.float32)
        cp = jnp.concatenate([centers, far], axis=0)
    mind2, idx = _assign_kernel.bucket_assign(xp, cp,
                                              interpret=not on_tpu())
    return mind2[:m], idx[:m]


# ---------------------------------------------------------------------------
# flash attention (LM substrate)
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: float | None = None, use_pallas: bool = False):
    """q: (B,H,S,D); k/v: (B,H,T,D) — GQA repeat done by caller."""
    if not use_pallas:
        return ref.attention(q, k, v, causal=causal, scale=scale)
    B, H, S, D = q.shape
    T = k.shape[2]
    if causal and S != T:
        # kernel causal convention: q position == row index (self-attn
        # prefill); offset-causal (decode against a longer cache) goes
        # through the cache-aware jnp path
        return ref.attention(q, k, v, causal=causal, scale=scale)
    bq = min(128, S)
    bkv = min(128, T)
    sp, tp = _round_up(S, bq), _round_up(T, bkv)
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, T, D)
    vf = v.reshape(B * H, T, D)
    if sp != S:
        qf = jnp.pad(qf, ((0, 0), (0, sp - S), (0, 0)))
    if tp != T:
        kf = jnp.pad(kf, ((0, 0), (0, tp - T), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, tp - T), (0, 0)))
        # padded kv columns masked out by causal rows < T; for non-causal,
        # fall back to ref to avoid attending to pad
        if not causal:
            return ref.attention(q, k, v, causal=causal, scale=scale)
    out = _flash_kernel.flash_attention(qf, kf, vf, causal=causal,
                                        scale=scale, bq=bq, bkv=bkv,
                                        interpret=not on_tpu())
    return out[:, :S, :].reshape(B, H, S, D)


# ---------------------------------------------------------------------------
# host-side helpers for the executor
# ---------------------------------------------------------------------------
def extract_pairs(d2: np.ndarray, mask: np.ndarray,
                  ids_a: np.ndarray, ids_b: np.ndarray,
                  *, upper_triangle: bool = False):
    """mask → (pairs (P,2) int64 original ids, dists (P,) f32)."""
    m = np.asarray(mask)
    if upper_triangle:
        m = np.triu(m, k=1)
    rows, cols = np.nonzero(m)
    if rows.size == 0:
        return np.zeros((0, 2), np.int64), np.zeros(0, np.float32)
    d = np.sqrt(np.asarray(d2)[rows, cols].astype(np.float32))
    pairs = np.stack([ids_a[rows], ids_b[cols]], axis=1).astype(np.int64)
    return pairs, d
