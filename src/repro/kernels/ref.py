"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_l2(a: jax.Array, b: jax.Array) -> jax.Array:
    """Squared L2 distance matrix: (M, d) × (N, d) → (M, N) float32."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)
    d2 = a2 - 2.0 * (a @ b.T) + b2.T
    return jnp.maximum(d2, 0.0)


def pairwise_l2_threshold(a: jax.Array, b: jax.Array, eps2: float):
    """(d2, mask) with mask = d2 ≤ eps²."""
    d2 = pairwise_l2(a, b)
    return d2, d2 <= eps2


def bucket_assign(x: jax.Array, centers: jax.Array):
    """Nearest center: (M, d) × (B, d) → (min_d2 (M,), argmin (M,) int32)."""
    d2 = pairwise_l2(x, centers)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind2 = jnp.take_along_axis(d2, idx[:, None], axis=1)[:, 0]
    return mind2, idx


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, scale: float | None = None) -> jax.Array:
    """Reference attention. q,k,v: (B, H, S, D) (k/v may have fewer heads —
    GQA handled by caller). Returns (B, H, S, D)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        s, t = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
