"""Pallas TPU kernel — fused nearest-center assignment.

Scan-2 hot loop of bucketization: for a block of vectors X (M, d) and the
center table C (B, d), find argmin_b d²(x, c_b) per row. Tiling: grid
(M/bm, B/bb); the running (min, argmin) pair lives in the output refs across
the center-tile loop (out block index ignores the center axis), so the
(bm, bb) distance tile never round-trips to HBM — only 2·bm values do.

d is kept whole per tile (embedding dims ≤ a few K fit VMEM comfortably:
128 rows × 1536 dims × 4 B = 768 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128
DEFAULT_BB = 128


def _assign_kernel(x_ref, c_ref, mind2_ref, idx_ref, *, bb: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        mind2_ref[...] = jnp.full_like(mind2_ref, jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...].astype(jnp.float32)           # (bm, d)
    c = c_ref[...].astype(jnp.float32)           # (bb, d)
    d2 = (jnp.sum(x * x, axis=1)[:, None]
          - 2.0 * jax.lax.dot_general(
              x, c, (((1,), (1,)), ((), ())),
              preferred_element_type=jnp.float32)
          + jnp.sum(c * c, axis=1)[None, :])     # (bm, bb)
    tile_min = jnp.min(d2, axis=1)
    tile_arg = jnp.argmin(d2, axis=1).astype(jnp.int32) + j * bb

    better = tile_min < mind2_ref[...]
    mind2_ref[...] = jnp.where(better, tile_min, mind2_ref[...])
    idx_ref[...] = jnp.where(better, tile_arg, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bb", "interpret"))
def bucket_assign(x: jax.Array, centers: jax.Array,
                  bm: int = DEFAULT_BM, bb: int = DEFAULT_BB,
                  interpret: bool = False):
    """(M, d) × (B, d) → (min_d2 (M,) f32, argmin (M,) int32).

    M and B must be multiples of bm/bb (callers pad; padded centers must be
    at +inf-distance — use `ops.bucket_assign`, which pads with +1e30 rows).
    """
    m, d = x.shape
    b, _ = centers.shape
    bm, bb = min(bm, m), min(bb, b)
    if m % bm or b % bb:
        raise ValueError(f"shapes ({m},{b}) not divisible by ({bm},{bb})")
    grid = (m // bm, b // bb)
    kernel = functools.partial(_assign_kernel, bb=bb)
    mind2, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(x, centers)
    return mind2, idx
