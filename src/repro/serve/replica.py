"""Replica sets: health-gated routing, failover, hedging, supervision.

A single-copy shard is a single point of failure: one dead SSD, one
browned-out store, one crashed session and every request routed there is
lost. This module gives ``IndexRouter`` (and standalone callers) a
replicated serving tier:

  * **ReplicaSet** — N replicas of one logical shard (same on-disk
    manifest, independent ``DiskJoinIndex`` sessions with their own
    ``BufferPool``/``QueryScheduler``). Each admitted request is routed
    to ONE replica by a pluggable policy: ``least_loaded`` scores a
    replica by queue depth x its per-request service time (seeded from
    the planner's ``WavePlan.predicted_s``, refined by an EWMA of
    observed latencies — ``repro.plan.predict_replica_service_s``),
    falling back to round-robin when no service estimate exists yet.
  * **HealthTracker** — folds per-replica outcomes (errors, deadline
    drops), the session's ``io_read_errors`` counter, and the PR 9 SLO
    burn state (``LiveObserver.slo_firing``) into one of
    ``HEALTHY``/``DEGRADED``/``DOWN``. ``DOWN`` replicas are ejected
    from routing; ``DEGRADED`` ones serve only when no healthy sibling
    can.
  * **failover** — a request that fails on one replica (store error,
    ``InjectedKill``, scheduler refusal, deadline drop with budget
    remaining) is transparently retried on a sibling with its remaining
    deadline. An optional hedging knob issues a backup probe to a second
    replica when the first exceeds its plan-predicted service by
    ``HEDGE_FACTOR`` — first successful result wins.
  * **ReplicaSupervisor** — a background thread that detects ``DOWN``
    replicas and restarts them off the request path: the dead
    scheduler's pending queue is spilled (``close(persist_queue=…)``),
    the session is reopened via ``DiskJoinIndex.reopen()``
    (``open(warm_start=True)`` under the hood), spilled requests are
    re-enqueued, and the replica is re-admitted only after a health
    probe query succeeds. Restart attempts back off exponentially up to
    a cap.

Degraded-mode coverage accounting (``Coverage``/``ShardStatus``) lives
here too: when every replica of a shard is down, the router's gather can
return partial results that SAY they are partial instead of failing the
whole fan-out — see ``RouterFuture`` in ``serve/router.py``.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED
from concurrent.futures import TimeoutError as FuturesTimeout
from concurrent.futures import wait as futures_wait

import numpy as np

from repro.core.index import DiskJoinIndex
from repro.ft.fault import InjectedKill
from repro.plan.planner import predict_replica_service_s
from repro.serve.scheduler import (AdmissionRejected, DeadlineExceeded,
                                   QueryScheduler, SchedulerClosed,
                                   SchedulerQueueFull)

HEALTHY = "healthy"
DEGRADED = "degraded"
DOWN = "down"

# backup probe fires when the first replica exceeds its predicted
# service by this factor (a cheap p95 proxy: predictions are means)
HEDGE_FACTOR = 3.0
_EWMA_ALPHA = 0.2
_MIN_RETRY_BUDGET_S = 1e-4   # don't failover a request that is already dead


class ShardUnavailable(RuntimeError):
    """Every replica of a selected shard is DOWN (or restarting) — there
    is nowhere to route the request. Under
    ``require_full_coverage=False`` the router converts this into a
    coverage gap instead of raising."""


class HealthTracker:
    """Per-replica health state machine.

    Outcome events (``record_ok``/``record_error``/``record_drop``) land
    in a sliding window; the ``state`` property folds the window's error
    and deadline-drop rates with two external signals:

      * ``pipeline_source`` (a ``PipelineStats.snapshot`` callable) —
        ``io_read_errors`` accumulated since the last ``reset()``; a
        replica absorbing transient read errors through retry/backoff is
        browned out even if every request ultimately succeeds.
      * ``slo_source`` (callable → firing-SLO count, e.g.
        ``LiveObserver.slo_firing``) — a replica whose burn-rate alerts
        are firing is degraded even before requests visibly fail.

    ``mark_down`` is the immediate ejection path (``InjectedKill``, a
    permanent store error); only ``reset()`` — the supervisor's
    post-probe re-admission — clears it.
    """

    def __init__(self, *, window: int = 32, min_events: int = 4,
                 degraded_error_rate: float = 0.1,
                 down_error_rate: float = 0.5,
                 degraded_drop_rate: float = 0.25,
                 io_error_limit: int = 8,
                 slo_source=None, pipeline_source=None):
        self.min_events = int(min_events)
        self.degraded_error_rate = float(degraded_error_rate)
        self.down_error_rate = float(down_error_rate)
        self.degraded_drop_rate = float(degraded_drop_rate)
        self.io_error_limit = int(io_error_limit)
        self._slo_source = slo_source
        self._pipeline_source = pipeline_source
        self._lock = threading.Lock()
        self._events: deque[str] = deque(maxlen=int(window))
        self._down_reason: str | None = None
        self._io_base = self._io_errors_now()
        self.errors = 0
        self.drops = 0
        self.oks = 0

    def _io_errors_now(self) -> int:
        if self._pipeline_source is None:
            return 0
        try:
            return int(self._pipeline_source().get("io_read_errors", 0))
        except Exception:
            return 0

    def record_ok(self) -> None:
        with self._lock:
            self._events.append("ok")
            self.oks += 1

    def record_error(self, exc: BaseException | None = None) -> None:
        with self._lock:
            self._events.append("err")
            self.errors += 1
            if isinstance(exc, InjectedKill):
                self._down_reason = f"injected kill: {exc}"

    def record_drop(self) -> None:
        with self._lock:
            self._events.append("drop")
            self.drops += 1

    def mark_down(self, reason: str) -> None:
        with self._lock:
            self._down_reason = reason

    def reset(self) -> None:
        """Re-admission (after a successful health probe): clear the
        window, the forced-down latch, and the io-error baseline."""
        with self._lock:
            self._events.clear()
            self._down_reason = None
        self._io_base = self._io_errors_now()

    def _rates(self) -> tuple[int, float, float]:
        n = len(self._events)
        if not n:
            return 0, 0.0, 0.0
        errs = sum(1 for e in self._events if e == "err")
        drops = sum(1 for e in self._events if e == "drop")
        return n, errs / n, drops / n

    @property
    def state(self) -> str:
        with self._lock:
            if self._down_reason is not None:
                return DOWN
            n, err_rate, drop_rate = self._rates()
        if n >= self.min_events and err_rate >= self.down_error_rate:
            return DOWN
        if n >= self.min_events and (err_rate >= self.degraded_error_rate
                                     or drop_rate >= self.degraded_drop_rate):
            return DEGRADED
        if self._io_errors_now() - self._io_base >= self.io_error_limit:
            return DEGRADED
        if self._slo_source is not None:
            try:
                if self._slo_source() > 0:
                    return DEGRADED
            except Exception:
                pass
        return HEALTHY

    def snapshot(self) -> dict:
        with self._lock:
            n, err_rate, drop_rate = self._rates()
            reason = self._down_reason
        return {
            "state": self.state, "events": n,
            "error_rate": round(err_rate, 4),
            "drop_rate": round(drop_rate, 4),
            "errors": self.errors, "drops": self.drops, "oks": self.oks,
            "io_errors_since_reset":
                self._io_errors_now() - self._io_base,
            "down_reason": reason,
        }


class Replica:
    """One replica: a ``DiskJoinIndex`` session + its wave scheduler +
    health. ``swap()`` is the supervisor's restart handoff — routing
    always reads ``index``/``scheduler`` through the attribute, so a
    swapped-in fresh session is picked up by the next request."""

    def __init__(self, index: DiskJoinIndex, scheduler: QueryScheduler,
                 health: HealthTracker, name: str):
        self.index = index
        self.scheduler = scheduler
        self.health = health
        self.name = name
        self.inflight = 0               # submitted, not yet resolved
        self.service_ewma: float | None = None   # observed s/request
        self.predicted_s: float | None = None    # planner seed (lazy)
        self.restarting = False
        self.restarts = 0
        self.next_restart_t = 0.0       # perf_counter gate for backoff
        self.backoff_s = 0.0
        self._lock = threading.Lock()

    def note_latency(self, s: float) -> None:
        with self._lock:
            self.service_ewma = (s if self.service_ewma is None else
                                 (1 - _EWMA_ALPHA) * self.service_ewma
                                 + _EWMA_ALPHA * s)

    def service_estimate(self) -> float | None:
        """Per-request service estimate: observed EWMA, else the
        planner's wave prediction (seeded on first submit)."""
        return self.service_ewma if self.service_ewma is not None \
            else self.predicted_s

    def swap(self, index: DiskJoinIndex,
             scheduler: QueryScheduler) -> None:
        with self._lock:
            self.index = index
            self.scheduler = scheduler
            self.inflight = 0
            self.service_ewma = None     # fresh pool: re-learn
            self.predicted_s = None
            self.restarts += 1
            self.backoff_s = 0.0

    def snapshot(self) -> dict:
        return {
            "name": self.name,
            "health": self.health.snapshot(),
            "inflight": self.inflight,
            "pending": self.scheduler.pending,
            "service_ewma_ms": (None if self.service_ewma is None
                                else round(self.service_ewma * 1e3, 3)),
            "restarting": self.restarting,
            "restarts": self.restarts,
        }


class ReplicaSet:
    """N replicas of one logical shard behind one submit surface.

    Parameters:
      indexes: the replica sessions (same manifest — typically N
        ``DiskJoinIndex.open`` calls on one workdir).
      epsilon: default threshold forwarded to each replica scheduler.
      scheduler: kwargs for every per-replica ``QueryScheduler``.
      policy: ``"least_loaded"`` (queue depth x per-request service via
        ``predict_replica_service_s``; round-robin tiebreak) or
        ``"round_robin"``.
      hedge: ``None`` (off), a float (backup probe after that many
        seconds), or ``"plan"`` (after ``HEDGE_FACTOR`` x the replica's
        predicted/observed service + the wave wait window).
      health: kwargs for every per-replica ``HealthTracker``.
    """

    def __init__(self, indexes: list[DiskJoinIndex], *,
                 epsilon: float | None = None,
                 scheduler: dict | None = None,
                 policy: str = "least_loaded",
                 hedge=None,
                 health: dict | None = None,
                 name: str = "shard"):
        if not indexes:
            raise ValueError("replica set needs at least one replica")
        if policy not in ("least_loaded", "round_robin"):
            raise ValueError(f"policy must be 'least_loaded' or "
                             f"'round_robin', got {policy!r}")
        if hedge is not None and hedge != "plan":
            hedge = float(hedge)
            if hedge <= 0:
                raise ValueError(f"hedge must be > 0, got {hedge}")
        self.name = name
        self.epsilon = None if epsilon is None else float(epsilon)
        self.policy = policy
        self.hedge = hedge
        self.sched_kw = dict(scheduler or {})
        self.health_kw = dict(health or {})
        self.replicas = [self._make_replica(idx, i)
                         for i, idx in enumerate(indexes)]
        self._lock = threading.Lock()
        self._rr = 0
        self.counters = {
            "submitted": 0, "failovers": 0, "submit_redirects": 0,
            "hedges": 0, "hedge_wins": 0, "unavailable": 0,
            "restarts": 0, "failed_restarts": 0,
        }

    def _make_replica(self, index: DiskJoinIndex, i: int) -> Replica:
        name = f"{self.name}/r{i}"
        rep_box: list = []   # closure cell: health sources must follow swaps

        def pipeline_source():
            return rep_box[0].index.stats.snapshot()

        def slo_source():
            live = getattr(rep_box[0].index, "live", None)
            return live.slo_firing() if live is not None else 0

        health = HealthTracker(slo_source=slo_source,
                               pipeline_source=pipeline_source,
                               **self.health_kw)
        sched = QueryScheduler(index, epsilon=self.epsilon, **self.sched_kw)
        rep = Replica(index, sched, health, name)
        rep_box.append(rep)
        return rep

    # -- routing policy -------------------------------------------------------
    def routable(self) -> list[Replica]:
        """Replicas eligible for new traffic: not DOWN, not mid-restart.
        DEGRADED replicas are kept but deprioritized by ``_pick``."""
        return [r for r in self.replicas
                if not r.restarting and r.health.state != DOWN]

    def _pick(self, exclude: list[Replica]) -> Replica | None:
        cands = [r for r in self.routable() if r not in exclude]
        if not cands:
            return None
        healthy = [r for r in cands if r.health.state == HEALTHY]
        pool = healthy or cands        # degraded only when nothing healthy
        with self._lock:
            self._rr += 1
            rr = self._rr
        if self.policy == "round_robin" or len(pool) == 1:
            return pool[rr % len(pool)]
        # least_loaded: modeled time for a NEW request to clear each
        # replica — its own predicted service plus the backlog ahead of
        # it (repro.plan.predict_replica_service_s). No estimate on any
        # candidate yet → fall back to (queue depth, round-robin).
        ests = [r.service_estimate() for r in pool]
        if any(e is None or e <= 0 for e in ests):
            return min(zip(pool, range(len(pool))),
                       key=lambda t: (t[0].scheduler.pending
                                      + t[0].inflight,
                                      (t[1] - rr) % len(pool)))[0]
        scored = [(predict_replica_service_s(
                       e, r.scheduler.pending + r.inflight), r)
                  for r, e in zip(pool, ests)]
        # near-equal scores rotate round-robin: a deterministic argmin
        # over noisy EWMAs would pin ALL idle-time traffic to whichever
        # replica happened to measure fastest, starving the others of
        # both load spread and health signal
        best = min(s for s, _ in scored)
        near = [r for s, r in scored if s <= best * 1.25]
        return near[rr % len(near)]

    def _hedge_threshold_s(self, replica: Replica) -> float | None:
        if self.hedge is None or len(self.routable()) < 2:
            return None
        if self.hedge != "plan":
            return float(self.hedge)
        base = replica.service_estimate()
        if base is None or base <= 0:
            return None
        return HEDGE_FACTOR * base + replica.scheduler.max_wait_s

    def _count(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.counters[key] += n

    # -- serving --------------------------------------------------------------
    def submit(self, q: np.ndarray, *, epsilon: float | None = None,
               k: int | None = None, deadline_s: float | None = None,
               **overrides) -> "ReplicaFuture":
        """Route one request to a replica → ``ReplicaFuture``.

        Raises at the door only when EVERY routable replica refused the
        enqueue (queue full / admission) — single-replica semantics are
        unchanged. With zero routable replicas the future is created
        anyway and raises ``ShardUnavailable`` at gather, so the
        router's coverage accounting can excuse it.
        """
        self._count("submitted")
        return ReplicaFuture(self, q, epsilon=epsilon, k=k,
                             deadline_s=deadline_s, overrides=overrides)

    def query(self, q: np.ndarray, *, timeout: float | None = None,
              **kw) -> tuple[np.ndarray, np.ndarray]:
        return self.submit(q, **kw).result(timeout=timeout)

    # -- telemetry / lifecycle ------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self.counters)
        return {
            "name": self.name,
            "policy": self.policy,
            "hedge": self.hedge,
            "counters": counters,
            "replicas": [r.snapshot() for r in self.replicas],
        }

    def close(self, *, close_indexes: bool = False) -> None:
        for r in self.replicas:
            r.scheduler.close()
        if close_indexes:
            for r in self.replicas:
                r.index.close()


class ReplicaFuture:
    """Handle for one request routed through a ``ReplicaSet``: retries
    on sibling replicas when an attempt fails, optionally hedges a
    backup probe, and records outcomes into the replicas' health.

    ``result(timeout)`` → (ids, distances) like ``QueryFuture``; raises
    ``ShardUnavailable`` when no replica could take the request, or the
    last attempt's error once every sibling has been tried.
    """

    def __init__(self, rset: ReplicaSet, q: np.ndarray, *,
                 epsilon: float | None, k: int | None,
                 deadline_s: float | None, overrides: dict):
        self._rset = rset
        self._q = q
        self._epsilon = epsilon
        self._k = k
        self._overrides = dict(overrides)
        self._t0 = time.perf_counter()
        self._deadline_t = (None if deadline_s is None
                            else self._t0 + float(deadline_s))
        self._tried: list[Replica] = []
        self._fut = None
        self._replica: Replica | None = None
        self._dead_exc: Exception | None = None
        self.latency_s: float | None = None
        self.attempts = 0
        self.hedged = False
        self._submit_attempt(first=True)

    # -- submission -----------------------------------------------------------
    def _remaining_deadline_s(self) -> float | None:
        if self._deadline_t is None:
            return None
        return max(self._deadline_t - time.perf_counter(), 1e-9)

    def _submit_to(self, replica: Replica):
        fut = replica.scheduler.submit(
            self._q, epsilon=self._epsilon, k=self._k,
            deadline_s=self._remaining_deadline_s(), **self._overrides)
        self.attempts += 1
        replica.inflight += 1
        if replica.predicted_s is None and (
                self._rset.policy == "least_loaded"
                or self._rset.hedge == "plan"):
            try:
                p = replica.scheduler._predict_service_s(
                    np.atleast_2d(np.asarray(self._q, np.float32)),
                    self._effective_overrides(replica))
                replica.predicted_s = p if p is None else float(p)
            except Exception:
                pass

        def _done(_f, r=replica):
            r.inflight = max(0, r.inflight - 1)

        fut.add_done_callback(_done)
        return fut

    def _effective_overrides(self, replica: Replica) -> dict:
        ov = dict(replica.scheduler._overrides)
        ov.update(self._overrides)
        eps = (replica.scheduler.epsilon if self._epsilon is None
               else float(self._epsilon))
        if eps is not None:
            ov["epsilon"] = eps
        return ov

    def _submit_attempt(self, first: bool = False) -> bool:
        """Enqueue on the best untried replica. Returns False when no
        routable replica remains (``_dead_exc`` set). Door refusals
        (queue full / admission) cascade to the next replica; if every
        candidate refuses, the last refusal is raised — backpressure
        must stay visible."""
        last_refusal = None
        while True:
            replica = self._rset._pick(self._tried)
            if replica is None:
                if last_refusal is not None:
                    raise last_refusal
                self._dead_exc = ShardUnavailable(
                    f"{self._rset.name}: no routable replica "
                    f"({len(self._rset.replicas)} configured, all "
                    f"down or restarting)")
                if not first:
                    return False
                self._rset._count("unavailable")
                return False
            self._tried.append(replica)
            try:
                fut = self._submit_to(replica)
            except (SchedulerQueueFull, AdmissionRejected,
                    SchedulerClosed) as e:
                last_refusal = e
                self._rset._count("submit_redirects")
                continue
            self._fut, self._replica = fut, replica
            return True

    # -- gather ---------------------------------------------------------------
    @staticmethod
    def _retryable(exc: BaseException) -> bool:
        if isinstance(exc, (FuturesTimeout, TimeoutError)):
            return False             # caller timeout, not replica death
        if isinstance(exc, DeadlineExceeded):
            return True              # budget check happens at the call
        return isinstance(exc, (OSError, InjectedKill, SchedulerClosed,
                                SchedulerQueueFull, AdmissionRejected))

    def _record(self, replica: Replica, exc: BaseException | None) -> None:
        if exc is None:
            replica.health.record_ok()
        elif isinstance(exc, DeadlineExceeded):
            replica.health.record_drop()
        elif isinstance(exc, (OSError, InjectedKill)):
            replica.health.record_error(exc)
        # scheduler refusals are load signals, not health signals

    def done(self) -> bool:
        if self._dead_exc is not None and self._fut is None:
            return True
        return self._fut is not None and self._fut.done()

    def result(self, timeout: float | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
        end = (None if timeout is None
               else time.perf_counter() + timeout)
        while True:
            if self._fut is None:
                raise self._dead_exc or ShardUnavailable(self._rset.name)
            try:
                out = self._wait_current(end)
            except (FuturesTimeout, TimeoutError):
                raise
            except BaseException as e:
                self._record(self._replica, e)
                if not self._retryable(e):
                    raise
                rem = self._remaining_deadline_s()
                if rem is not None and rem <= _MIN_RETRY_BUDGET_S:
                    raise      # budget exhausted: the drop is final
                if not self._submit_attempt():
                    raise      # no sibling left: propagate last error
                self._rset._count("failovers")
                continue
            self._record(self._replica, None)
            self._replica.note_latency(time.perf_counter() - self._t0)
            self.latency_s = time.perf_counter() - self._t0
            return out

    def _wait_current(self, end: float | None):
        """Wait on the current attempt; fire a backup probe once the
        hedge threshold passes. First successful result wins; if the
        winner errors, the other attempt is still consulted before the
        error escalates to the failover loop."""
        fut = self._fut
        hedge_s = (None if self.hedged
                   else self._rset._hedge_threshold_s(self._replica))
        if hedge_s is not None:
            rem = None if end is None else max(0.0, end - time.perf_counter())
            wait_s = hedge_s if rem is None else min(hedge_s, rem)
            try:
                return fut.result(timeout=wait_s)
            except FuturesTimeout:
                if end is not None and time.perf_counter() >= end:
                    raise
                backup = self._launch_hedge()
                if backup is not None:
                    return self._wait_hedged([fut, backup], end)
        rem = None if end is None else max(0.0, end - time.perf_counter())
        return fut.result(timeout=rem)

    def _launch_hedge(self):
        sibling = self._rset._pick(self._tried)
        if sibling is None:
            return None
        self._tried.append(sibling)
        try:
            backup = self._submit_to(sibling)
        except (SchedulerQueueFull, AdmissionRejected, SchedulerClosed):
            return None
        self.hedged = True
        self._rset._count("hedges")
        self._hedge_primary = self._fut
        return backup

    def _wait_hedged(self, futs: list, end: float | None):
        errors: list[BaseException] = []
        pending = list(futs)
        while pending:
            rem = None if end is None else max(0.0, end - time.perf_counter())
            done, not_done = futures_wait(pending, timeout=rem,
                                          return_when=FIRST_COMPLETED)
            if not done:
                raise FuturesTimeout()
            for f in done:
                try:
                    out = f.result(timeout=0)
                except BaseException as e:
                    errors.append(e)
                    continue
                if f is not getattr(self, "_hedge_primary", None):
                    self._rset._count("hedge_wins")
                    # the winner is the replica whose health gets credit
                    self._replica = self._tried[-1]
                return out
            pending = list(not_done)
        raise errors[0]


@dataclasses.dataclass
class ShardStatus:
    """Per-shard outcome of one routed request's gather."""

    shard: int
    status: str                  # "ok" | "unavailable" | "deadline" | "error"
    error: str | None = None     # exception repr for non-ok shards

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Coverage:
    """Which shards actually answered a fan-out: the degraded-mode
    contract. ``answered``/``total`` count the shards the request was
    routed to; ``statuses`` carries the per-shard outcome."""

    answered: int
    total: int
    statuses: list[ShardStatus]

    @property
    def complete(self) -> bool:
        return self.answered == self.total

    def to_dict(self) -> dict:
        return {"answered": self.answered, "total": self.total,
                "complete": self.complete,
                "statuses": [s.to_dict() for s in self.statuses]}


class ReplicaSupervisor:
    """Detects DOWN replicas and restarts them off the request path.

    The restart sequence per dead replica:

      1. spill its scheduler's pending queue
         (``QueryScheduler.close(persist_queue=…)`` — the ft queue
         checkpoint; spilled requests ALSO fail over to siblings, the
         resumed copies are recomputed work, not duplicate deliveries);
      2. close the dead session (best effort — it may be wedged);
      3. ``DiskJoinIndex.reopen()`` → ``open(warm_start=True)``: a fresh
         session pre-faulted from the residency snapshot;
      4. a fresh ``QueryScheduler`` with ``resume_queue=`` re-enqueues
         the spilled requests with their remaining deadlines;
      5. a health probe query (the shard's first center — must hit) on
         the fresh scheduler; only on success is the replica swapped in
         and its health reset. Any failure re-arms the restart with
         exponentially backed-off delay (capped).

    ``target`` is an ``IndexRouter``, a ``ReplicaSet`` or a list of
    sets. ``start()``/``close()`` manage the poll thread; ``poll_once``
    is the synchronous core (tests drive it directly).
    """

    def __init__(self, target, *, poll_s: float = 0.2,
                 backoff_s: float = 0.25, backoff_cap_s: float = 8.0,
                 warm_start: bool = True, persist_queue: bool = True,
                 probe_timeout_s: float = 30.0, on_event=None):
        if hasattr(target, "replica_sets"):
            self.sets = list(target.replica_sets)
        elif isinstance(target, ReplicaSet):
            self.sets = [target]
        else:
            self.sets = list(target)
        self.poll_s = float(poll_s)
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.warm_start = bool(warm_start)
        self.persist_queue = bool(persist_queue)
        self.probe_timeout_s = float(probe_timeout_s)
        self._on_event = on_event
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.restarts = 0
        self.failed_restarts = 0

    def _event(self, kind: str, replica: Replica, **kw) -> None:
        if self._on_event is not None:
            try:
                self._on_event({"event": kind, "replica": replica.name,
                                **kw})
            except Exception:
                pass

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "ReplicaSupervisor":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="diskjoin-replica-supervisor",
                daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "ReplicaSupervisor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            try:
                self.poll_once()
            except Exception:
                pass           # the supervisor itself must never die

    # -- restart core ---------------------------------------------------------
    def poll_once(self) -> int:
        """Scan every set, restart due DOWN replicas. Returns restarts
        performed this pass."""
        n = 0
        for rset in self.sets:
            for replica in rset.replicas:
                if replica.restarting:
                    continue
                if replica.health.state != DOWN:
                    continue
                if time.perf_counter() < replica.next_restart_t:
                    continue
                if self._restart(rset, replica):
                    n += 1
        return n

    def _restart(self, rset: ReplicaSet, replica: Replica) -> bool:
        replica.restarting = True
        self._event("restart_begin", replica)
        try:
            workdir = replica.index.workdir
            qpath = None
            if self.persist_queue:
                qpath = os.path.join(workdir,
                                     f"pending_queue_{replica.restarts}.json")
            try:
                replica.scheduler.close(persist_queue=qpath)
            except Exception:
                pass
            try:
                replica.index.close()
            except Exception:
                pass           # a dead session may fail its own teardown
            index = DiskJoinIndex.open(workdir,
                                       replica.index.query_defaults,
                                       warm_start=self.warm_start)
            try:
                sched = QueryScheduler(index, epsilon=rset.epsilon,
                                       resume_queue=qpath,
                                       **rset.sched_kw)
                # health probe: the first center must answer (it is the
                # center of a real bucket — an empty result is still a
                # successful read path)
                probe = np.ascontiguousarray(index.meta.centers[0],
                                             dtype=np.float32)
                sched.query(probe, timeout=self.probe_timeout_s)
            except BaseException:
                try:
                    index.close()
                except Exception:
                    pass
                raise
        except Exception as e:
            replica.backoff_s = min(
                max(self.backoff_s, replica.backoff_s * 2),
                self.backoff_cap_s)
            replica.next_restart_t = time.perf_counter() + replica.backoff_s
            self.failed_restarts += 1
            rset._count("failed_restarts")
            self._event("restart_failed", replica, error=repr(e),
                        backoff_s=replica.backoff_s)
            return False
        else:
            replica.swap(index, sched)
            replica.health.reset()
            self.restarts += 1
            rset._count("restarts")
            self._event("restart_ok", replica,
                        resumed=len(sched.resumed))
            return True
        finally:
            replica.restarting = False
