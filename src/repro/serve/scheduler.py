"""Wave-batched query scheduler: probe-sharing across concurrent requests.

DiskJoin's regime is I/O-bound, so the dominant serving cost is candidate-
bucket reads — and concurrent ε-range queries over a clustered corpus probe
heavily *overlapping* bucket sets. The synchronous facade
(``VectorQueryService`` calling ``DiskJoinIndex.query_batch`` per request)
makes N callers probing the same hot bucket pay N reads. Work-sharing
vector-join systems (Kim et al., PAPERS.md) show that merging overlapping
probe work across concurrent threshold queries is the dominant win in
exactly this setting.

``QueryScheduler`` is that merge point, mirroring the wave design of
``serve/engine.py``:

  1. **queue** — ``submit`` validates the request eagerly and enqueues it
     into a bounded queue (admission control: ``SchedulerQueueFull`` when
     ``max_queue`` requests are already pending), returning a
     ``QueryFuture``;
  2. **wave** — a drain thread forms waves of up to ``wave_size`` requests,
     waiting at most ``max_wait_s`` past the first pending request (size OR
     time-window trigger);
  3. **deadline** — requests whose deadline already passed are dropped
     *before any read* and resolve with ``DeadlineExceeded``
     (``PipelineStats.deadline_drops``); requests that expire *mid-wave*
     are cancelled between buckets — remaining reads for buckets only
     they probe are skipped (``midwave_skipped_reads``) and their future
     raises ``DeadlineExceeded`` too (``deadline_drops_midwave``).
     With ``admission="estimate"`` the planner (``repro.plan``) predicts
     each deadline request's wave service time at ``submit`` and sheds
     predicted-doomed requests before they even enqueue
     (``AdmissionRejected``, ``PipelineStats.admission_rejects``) —
     distinct from the capacity bound ``SchedulerQueueFull``;
  4. **shared probe** — the wave is planned once
     (``DiskJoinIndex.plan_probes``: center index + triangle inequality +
     Eq. 3 pruning, no disk I/O), the per-query candidate-bucket sets are
     unioned, and ``execute_probes`` issues ONE read per distinct bucket
     through the session's shared ``BufferPool``/prefetcher — the resident
     slab fans out to every member query's verify
     (``PipelineStats.shared_probe_reads`` / ``reads_saved_by_sharing``);
  5. **future** — results are ordered deterministically (distance, then id)
     and delivered; ``QueryFuture.latency_s`` records the true
     enqueue→complete latency of *that request* (not a share of the wave's
     wall time), and the scheduler keeps a separate per-wave histogram.

Requests carrying different query-time overrides (ε, io_mode, …) are
grouped within the wave and share probes within their group only — one
``plan``/``execute`` cycle needs one config.

Thread model: any number of submitter threads; ONE drain thread executes
waves, so scheduler traffic presents to the index exactly like the
single-threaded ``query_batch`` caller the session pool's liveness
reasoning assumes (warm pins, one transient slab per miss, fallback reads
under contention) — safe to race against concurrent batch joins.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

from repro.core.index import DiskJoinIndex
from repro.core.types import BUILD_TIME_FIELDS, QUERY_TIME_FIELDS
from repro.ft.atomic import AsyncCommitter, atomic_write_json
from repro.obs import get_tracer

QUEUE_SPILL_FORMAT = "diskjoin-queue/v1"


class DeadlineExceeded(Exception):
    """The request's deadline passed before its wave started reading, or
    (mid-wave cancellation) while the wave was still reading buckets —
    the message says which."""


class SchedulerClosed(RuntimeError):
    """submit() after close()."""


class SchedulerQueueFull(RuntimeError):
    """Admission control: the bounded request queue is at capacity."""


class AdmissionRejected(RuntimeError):
    """Estimate-based admission (``admission="estimate"``): the planner
    predicts the request cannot meet its deadline, so it is shed at the
    door before any queueing or disk read. Distinct from
    ``SchedulerQueueFull`` — that is the *capacity* bound; this is the
    *feasibility* bound. Carries the model's numbers so callers can
    re-submit with a looser deadline — ``suggested_deadline_s`` is the
    smallest deadline the model considers feasible (prediction plus the
    wave wait window, with a 25% slack margin): re-pricing instead of
    turning traffic away blind."""

    def __init__(self, msg: str, predicted_s: float | None = None,
                 deadline_s: float | None = None,
                 suggested_deadline_s: float | None = None):
        super().__init__(msg)
        self.predicted_s = predicted_s
        self.deadline_s = deadline_s
        self.suggested_deadline_s = suggested_deadline_s


def _check_k(k) -> int | None:
    if k is None:
        return None
    k = int(k)
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    return k


def order_result(ids: np.ndarray, dists: np.ndarray,
                 k: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic result ordering: by distance, ties broken by vector
    id — identical queries return identical orderings regardless of
    io_mode, striping or candidate-bucket read order."""
    order = np.lexsort((ids, dists))
    k = _check_k(k)
    if k is not None:
        order = order[:k]
    return ids[order], dists[order]


def summarize_waves(waves: list[tuple[int, float]]) -> dict:
    """Percentile summary of a (size, service seconds) wave histogram —
    one schema for the scheduler's and the direct service's snapshots."""
    sizes = np.asarray([w[0] for w in waves], np.float64)
    svc = np.asarray([w[1] for w in waves], np.float64) * 1e3
    return {
        "count": len(waves),
        "size_mean": float(sizes.mean()) if sizes.size else 0.0,
        "size_max": int(sizes.max()) if sizes.size else 0,
        "service_p50_ms": (float(np.percentile(svc, 50))
                           if svc.size else 0.0),
        "service_p95_ms": (float(np.percentile(svc, 95))
                           if svc.size else 0.0),
    }


class QueryFuture(Future):
    """Handle for one scheduled request.

    ``result(timeout)`` → (ids, distances), nearest first with ties broken
    by id, truncated to the request's ``k``. Raises ``DeadlineExceeded`` if
    the request expired pre-read, ``SchedulerClosed`` if the scheduler shut
    down underneath it. ``latency_s`` (set on completion) is the request's
    true enqueue→complete latency.
    """

    latency_s: float | None = None


@dataclasses.dataclass
class _Request:
    q: np.ndarray                 # (dim,) float32, validated
    k: int | None
    overrides: tuple              # sorted (key, value) pairs — group key
    enqueue_t: float
    deadline_t: float | None
    future: QueryFuture
    rid: int = 0                  # request id: links trace async events


class QueryScheduler:
    """Wave-batched serving frontend over one ``DiskJoinIndex`` session.

    Parameters:
      index: the session to serve from.
      epsilon: default threshold (falls back to the index's query-time
        defaults; required if the index has none).
      wave_size: max requests per wave (size trigger).
      max_wait_s: max time a wave waits past its first request before
        executing partially filled (time-window trigger). 0 drains
        whatever is queued without waiting.
      max_queue: admission bound — ``submit`` raises
        ``SchedulerQueueFull`` beyond this many pending requests.
      admission: "queue" (default) admits anything the queue has room
        for; "estimate" additionally predicts each *deadline* request's
        wave service time via the session planner (``repro.plan`` —
        sketch-based probe cardinality x calibrated read/verify costs)
        and raises ``AdmissionRejected`` when the prediction says the
        deadline cannot be met even if the wave started immediately.
        Requests without a deadline are never estimate-rejected.
      share_probes: plan the wave once and read each distinct bucket once
        (the point of this class). False executes members independently —
        wave batching without sharing, kept for A/B measurement
        (``benchmarks/fig22_scheduler.py``'s "naive-batch").
      **overrides: query-time config overrides applied to every request
        (e.g. ``io_mode="prefetch"``), validated eagerly.
    """

    def __init__(self, index: DiskJoinIndex, *,
                 epsilon: float | None = None,
                 wave_size: int = 32, max_wait_s: float = 0.002,
                 max_queue: int = 1024, share_probes: bool = True,
                 admission: str = "queue",
                 latency_window: int = 8192,
                 resume_queue: str | None = None, **overrides):
        if wave_size < 1:
            raise ValueError(f"wave_size must be >= 1, got {wave_size}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        if admission not in ("queue", "estimate"):
            raise ValueError(f"admission must be 'queue' or 'estimate', "
                             f"got {admission!r}")
        self.index = index
        self.wave_size = int(wave_size)
        self.max_wait_s = float(max_wait_s)
        self.max_queue = int(max_queue)
        self.share_probes = bool(share_probes)
        self.admission = admission
        self._check_overrides(overrides)
        self._overrides = dict(overrides)
        if epsilon is None and "epsilon" not in overrides \
                and index.query_defaults is None:
            raise ValueError(
                "epsilon required: the index has no query-time defaults")
        self.epsilon = None if epsilon is None else float(epsilon)

        self._queue: deque[_Request] = deque()
        self._cond = threading.Condition()
        self._closed = False
        # telemetry (under _stats_lock; the drain thread and submitters
        # both touch it)
        self._stats_lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.rejected = 0
        self.admission_rejects = 0
        self.deadline_drops = 0
        self.deadline_drops_midwave = 0
        self.waves = 0
        self._rid = 0            # request ids for trace async linkage
        self._latencies: deque[float] = deque(maxlen=int(latency_window))
        self._wave_hist: deque[tuple[int, float]] = deque(
            maxlen=int(latency_window))
        # queue checkpoint (repro.ft): a predecessor scheduler that was
        # closed with persist_queue= spilled its admitted-but-unserved
        # requests; re-enqueued below with their remaining deadlines
        self.resumed: list[QueryFuture] = []
        self.resume_dropped = 0
        # fold wave/latency counters into the session's metrics surface;
        # keep the returned (possibly suffixed) key for close()
        self._metrics_key = index.metrics.register_provider(
            "scheduler", self._metrics_section)
        self._drain = threading.Thread(target=self._drain_loop,
                                       name="diskjoin-serve-drain",
                                       daemon=True)
        self._drain.start()
        if resume_queue is not None:
            self._resume_from(resume_queue)

    @staticmethod
    def _check_overrides(overrides: dict) -> None:
        bad = sorted(set(overrides) & BUILD_TIME_FIELDS)
        if bad:
            raise ValueError(
                f"build-time parameter(s) {bad} are fixed by the on-disk "
                f"index; rebuild with DiskJoinIndex.build to change them")
        unknown = sorted(set(overrides) - QUERY_TIME_FIELDS)
        if unknown:
            raise TypeError(f"unknown query-time parameter(s) {unknown}")

    # -- submission -----------------------------------------------------------
    def submit(self, q: np.ndarray, *, epsilon: float | None = None,
               k: int | None = None, deadline_s: float | None = None,
               **overrides) -> QueryFuture:
        """Enqueue one ε-range request → ``QueryFuture``.

        ``deadline_s`` is a relative deadline from now; a request whose
        deadline passes while it waits is dropped before any disk read
        and its future raises ``DeadlineExceeded`` (a deadline that
        expires while its wave is already reading cancels the remaining
        work mid-wave and raises the same error). Raises
        ``SchedulerQueueFull`` when ``max_queue`` requests are pending
        (admission control — shed load at the door, not after the reads)
        and, under ``admission="estimate"``, ``AdmissionRejected`` when
        the planner predicts the deadline is infeasible.
        """
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        k = _check_k(k)
        q = self.index._validate_queries(q)
        if q.shape[0] != 1:
            raise ValueError(
                f"submit takes one query vector, got a batch of "
                f"{q.shape[0]}; submit them individually to share waves")
        ov = dict(self._overrides)
        ov.update(overrides)
        eps = self.epsilon if epsilon is None else float(epsilon)
        if eps is not None:
            ov["epsilon"] = eps
        self._check_overrides(ov)
        if self.admission == "estimate" and deadline_s is not None:
            pred = self._predict_service_s(q, ov)
            # even an instantly-formed wave waits out the batching window
            if pred is not None and self.max_wait_s + pred > deadline_s:
                self.index.stats.add("admission_rejects", 1)
                with self._stats_lock:
                    self.admission_rejects += 1
                suggested = (self.max_wait_s + pred) * 1.25
                get_tracer().instant(
                    "serve.admission_reject", predicted_s=pred,
                    deadline_s=float(deadline_s),
                    suggested_deadline_s=suggested)
                raise AdmissionRejected(
                    f"predicted service {pred * 1e3:.2f}ms (+ up to "
                    f"{self.max_wait_s * 1e3:.2f}ms wave wait) exceeds "
                    f"the {deadline_s * 1e3:.2f}ms deadline; rejected "
                    f"before any read (smallest feasible deadline_s "
                    f"~= {suggested * 1e3:.2f}ms)", predicted_s=pred,
                    deadline_s=float(deadline_s),
                    suggested_deadline_s=suggested)
        fut = QueryFuture()
        now = time.perf_counter()
        req = _Request(q=q[0], k=k,
                       overrides=tuple(sorted(ov.items())),
                       enqueue_t=now,
                       deadline_t=None if deadline_s is None
                       else now + float(deadline_s),
                       future=fut)
        with self._cond:
            if self._closed:
                raise SchedulerClosed("scheduler is closed")
            if len(self._queue) >= self.max_queue:
                with self._stats_lock:
                    self.rejected += 1
                raise SchedulerQueueFull(
                    f"request queue full ({self.max_queue} pending)")
            self._rid += 1
            req.rid = self._rid
            self._queue.append(req)
            self._cond.notify_all()
        # async begin on the submitter thread; the matching end fires on
        # the drain thread (with the wave id) — one interval per request
        get_tracer().async_begin("serve.request", req.rid)
        with self._stats_lock:
            self.submitted += 1
        return fut

    def _predict_service_s(self, q: np.ndarray, ov: dict) -> float | None:
        """Planner-predicted wave service time for one request: probe the
        candidate buckets (metadata only, no reads), then cost the wave
        plan (reads for cold probes + verify over estimated pair counts).
        Returns None when no prediction is possible (admission must fail
        open — a broken estimator should never turn into dropped traffic)."""
        try:
            cfg = self.index._resolve(ov)
            Q = np.atleast_2d(np.asarray(q))
            per_q = self.index.plan_probes(Q, **ov)
            wplan = self.index._planner_for(cfg).plan_wave(
                Q, per_q, self.index.meta, cfg, self.index.bucket_capacity,
                warm=set(self.index.warm_buckets()))
            return float(wplan.predicted_s)
        except Exception:
            return None

    def query(self, q: np.ndarray, *, epsilon: float | None = None,
              k: int | None = None, deadline_s: float | None = None,
              timeout: float | None = None,
              **overrides) -> tuple[np.ndarray, np.ndarray]:
        """Synchronous convenience: ``submit`` + wait."""
        return self.submit(q, epsilon=epsilon, k=k, deadline_s=deadline_s,
                           **overrides).result(timeout=timeout)

    # -- queue checkpoint (repro.ft) ------------------------------------------
    def _resume_from(self, path: str) -> None:
        """Re-enqueue requests a predecessor spilled with
        ``close(persist_queue=…)``. Each rides in with its remaining
        deadline budget — one that expired during the restart goes
        through the normal pre-read drop path (an honest
        ``DeadlineExceeded``, not silent loss). The spill file is
        consumed (removed) so a crash loop cannot double-resume it."""
        if not os.path.exists(path):
            return
        with open(path) as f:
            payload = json.load(f)
        if payload.get("format") != QUEUE_SPILL_FORMAT:
            raise ValueError(f"{path}: not a {QUEUE_SPILL_FORMAT} spill")
        os.remove(path)
        # deadlines are wall-clock promises to callers: time spent down
        # between spill and resume is charged against each request's
        # remaining budget (perf_counter does not survive a process
        # restart, so the spill stamps wall time)
        downtime = max(0.0, time.time() - payload.get("spilled_at_unix",
                                                      time.time()))
        for rec in payload["requests"]:
            ov = dict(rec["overrides"])
            eps = ov.pop("epsilon", None)
            rem = rec["remaining_s"]
            if rem is not None:
                rem -= downtime
            try:
                fut = self.submit(
                    np.asarray(rec["q"], np.float32),
                    epsilon=eps, k=rec["k"],
                    deadline_s=None if rem is None else max(rem, 1e-9),
                    **ov)
            except (AdmissionRejected, SchedulerQueueFull):
                self.resume_dropped += 1
                continue
            self.resumed.append(fut)

    def _spill_queue(self, path: str, spilled: list[_Request]) -> None:
        """Persist admitted-but-unserved requests through the ft async
        committer (same atomic-write discipline as checkpoints)."""
        now = time.perf_counter()
        payload = {
            "format": QUEUE_SPILL_FORMAT,
            "spilled_at_unix": time.time(),
            "requests": [{
                "q": [float(v) for v in r.q],
                "k": r.k,
                "overrides": [[k, v] for k, v in r.overrides],
                "remaining_s": (None if r.deadline_t is None
                                else r.deadline_t - now),
            } for r in spilled],
        }
        committer = AsyncCommitter(name="queue-spill")
        try:
            committer.submit(lambda: atomic_write_json(path, payload))
        finally:
            committer.close()

    @property
    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    # -- wave formation -------------------------------------------------------
    def _drain_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait()
                if not self._queue:       # closed and fully drained
                    return
                # time-window trigger: wait for the wave to fill, but at
                # most max_wait_s past the FIRST pending request
                window_end = self._queue[0].enqueue_t + self.max_wait_s
                while (len(self._queue) < self.wave_size
                       and not self._closed):
                    remaining = window_end - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
                wave = [self._queue.popleft()
                        for _ in range(min(self.wave_size,
                                           len(self._queue)))]
            try:
                self._run_wave(wave)
            except BaseException as e:      # never kill the drain thread
                for r in wave:
                    if not r.future.done():
                        r.future.set_exception(e)

    # -- wave execution -------------------------------------------------------
    def _run_wave(self, wave: list[_Request]) -> None:
        t0 = time.perf_counter()
        tracer = get_tracer()
        with self._stats_lock:
            wave_id = self.waves + 1
        with tracer.span("serve.wave", wave=wave_id, size=len(wave)):
            # transition every member to RUNNING: a client that cancel()ed
            # a pending future drops out here, and no later cancel can race
            # the set_result/set_exception below (InvalidStateError-free)
            wave = [r for r in wave
                    if r.future.set_running_or_notify_cancel()]
            live: list[_Request] = []
            drops = 0
            for r in wave:
                if r.deadline_t is not None and t0 > r.deadline_t:
                    r.future.latency_s = t0 - r.enqueue_t
                    r.future.set_exception(DeadlineExceeded(
                        f"deadline passed {t0 - r.deadline_t:.4f}s before "
                        f"the wave started (no read was issued)"))
                    tracer.async_end("serve.request", r.rid, wave=wave_id,
                                     dropped=True)
                    drops += 1
                else:
                    live.append(r)
            if drops:
                self.index.stats.add("deadline_drops", drops)
                with self._stats_lock:
                    self.deadline_drops += drops

            # group by effective query-time config: probe sharing needs one
            # plan/execute cycle per config (most traffic uses the defaults
            # and lands in a single group)
            groups: dict[tuple, list[_Request]] = {}
            for r in live:
                groups.setdefault(r.overrides, []).append(r)
            for key, members in groups.items():
                self._run_group(dict(key), members, wave_id)

        self.index.stats.add("waves", 1)
        with self._stats_lock:
            self.waves += 1
            self._wave_hist.append((len(wave), time.perf_counter() - t0))

    def _run_group(self, ov: dict, members: list[_Request],
                   wave_id: int = 0) -> None:
        tracer = get_tracer()
        Q = np.stack([r.q for r in members])

        # mid-wave cancellation: execute_probes consults cancel(qi) before
        # fanning each bucket out (and skips reads no live prober needs).
        # Expiry is sticky — once a member misses its deadline it stays
        # cancelled for the rest of the wave, and its future raises below.
        deadlines = [r.deadline_t for r in members]
        expired: set[int] = set()
        cancel = None
        if any(d is not None for d in deadlines):
            def cancel(qi: int) -> bool:
                if qi in expired:
                    return True
                dl = deadlines[qi]
                if dl is not None and time.perf_counter() > dl:
                    expired.add(qi)
                    return True
                return False

        try:
            plan = self.index.plan_probes(Q, **ov)
            if self.share_probes:
                refs = sum(len(p) for p in plan)
                distinct = len({int(b) for p in plan for b in p})
                if distinct:
                    self.index.stats.add("shared_probe_reads", distinct)
                    self.index.stats.add("reads_saved_by_sharing",
                                         refs - distinct)
                results = self.index.execute_probes(Q, plan, cancel=cancel,
                                                    **ov)
            else:
                # A/B baseline: per-request execution, no sharing
                results = []
                for i in range(len(members)):
                    sub_cancel = (None if cancel is None
                                  else lambda qi, i=i: cancel(i))
                    results.extend(self.index.execute_probes(
                        Q[i:i + 1], [plan[i]], cancel=sub_cancel, **ov))
        except BaseException as e:
            now = time.perf_counter()
            for r in members:
                r.future.latency_s = now - r.enqueue_t
                r.future.set_exception(e)
                tracer.async_end("serve.request", r.rid, wave=wave_id,
                                 error=type(e).__name__)
            return
        now = time.perf_counter()
        lats = []
        midwave = 0
        for qi, (r, (ids, dists)) in enumerate(zip(members, results)):
            r.future.latency_s = now - r.enqueue_t
            if qi in expired:
                # cancelled mid-wave: its partial result set is discarded
                # (a deadline miss must not masquerade as a complete,
                # possibly-truncated answer)
                r.future.set_exception(DeadlineExceeded(
                    f"deadline passed mid-wave "
                    f"({now - r.deadline_t:.4f}s over); remaining probe "
                    f"work was cancelled"))
                tracer.async_end("serve.request", r.rid, wave=wave_id,
                                 dropped=True, midwave=True)
                midwave += 1
                continue
            lats.append(r.future.latency_s)
            r.future.set_result(order_result(ids, dists, r.k))
            tracer.async_end("serve.request", r.rid, wave=wave_id)
        if midwave:
            self.index.stats.add("deadline_drops", midwave)
            self.index.stats.add("deadline_drops_midwave", midwave)
        with self._stats_lock:
            self.completed += len(members) - midwave
            self.deadline_drops += midwave
            self.deadline_drops_midwave += midwave
            self._latencies.extend(lats)

    # -- telemetry / lifecycle ------------------------------------------------
    def _metrics_section(self) -> dict:
        """Provider for the index session's ``MetricsRegistry``: the
        scheduler's counters, latency percentiles and wave summary —
        without the pipeline section the registry already carries."""
        with self._stats_lock:
            lats = np.asarray(self._latencies, np.float64)
            waves = list(self._wave_hist)
            d = {
                "submitted": self.submitted,
                "completed": self.completed,
                "rejected": self.rejected,
                "admission_rejects": self.admission_rejects,
                "deadline_drops": self.deadline_drops,
                "deadline_drops_midwave": self.deadline_drops_midwave,
                "waves": self.waves,
            }
        d["latency_p50_ms"] = (float(np.percentile(lats, 50)) * 1e3
                               if lats.size else 0.0)
        d["latency_p95_ms"] = (float(np.percentile(lats, 95)) * 1e3
                               if lats.size else 0.0)
        d["wave"] = summarize_waves(waves)
        return d

    def snapshot(self) -> dict:
        """Scheduler counters, true per-request latency percentiles, the
        per-wave histogram summary, and the index session's PipelineStats
        (one surface for waves, shared reads, joins and queries)."""
        with self._stats_lock:
            lats = np.asarray(self._latencies, np.float64)
            waves = list(self._wave_hist)
            d = {
                "submitted": self.submitted,
                "completed": self.completed,
                "rejected": self.rejected,
                "admission_rejects": self.admission_rejects,
                "deadline_drops": self.deadline_drops,
                "deadline_drops_midwave": self.deadline_drops_midwave,
                "waves": self.waves,
            }
        d["pending"] = self.pending
        d["resumed"] = len(self.resumed)
        d["resume_dropped"] = self.resume_dropped
        d["latency_p50_ms"] = (float(np.percentile(lats, 50)) * 1e3
                               if lats.size else 0.0)
        d["latency_p95_ms"] = (float(np.percentile(lats, 95)) * 1e3
                               if lats.size else 0.0)
        d["latency_mean_ms"] = (float(lats.mean()) * 1e3
                                if lats.size else 0.0)
        d["wave"] = summarize_waves(waves)
        d["pipeline"] = self.index.pipeline_snapshot()
        return d

    def close(self, persist_queue: str | None = None) -> None:
        """Stop accepting requests, drain every pending wave, join the
        drain thread. Pending futures complete normally (or with their
        deadline/config error) — close never abandons accepted work.

        ``persist_queue`` is the supervised-restart path: instead of
        executing the pending queue (pointless against a dead store),
        spill it to ``persist_queue`` via the ft ``AsyncCommitter``; a
        successor scheduler opened with ``resume_queue=`` re-enqueues
        every spilled request with its remaining deadline. The spilled
        futures resolve with ``SchedulerClosed`` so a replica-set
        caller fails over immediately rather than waiting on a corpse.
        """
        spilled: list[_Request] = []
        with self._cond:
            if self._closed:
                return
            self._closed = True
            if persist_queue is not None:
                while self._queue:
                    spilled.append(self._queue.popleft())
            self._cond.notify_all()
        self._drain.join()
        if persist_queue is not None:
            self._spill_queue(persist_queue, spilled)
            exc = SchedulerClosed(
                f"scheduler closed for restart; request spilled to "
                f"{persist_queue} and will be re-executed on resume")
            for r in spilled:
                if r.future.set_running_or_notify_cancel():
                    r.future.set_exception(exc)
        # a closed scheduler must not linger on the session's metrics
        # surface (tests open several schedulers per index)
        self.index.metrics.unregister_provider(self._metrics_key)

    def __enter__(self) -> "QueryScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
