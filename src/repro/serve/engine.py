"""Batched serving engine — wave (iteration-level) batching.

Requests are drained in *waves*: up to ``slots`` queued requests with equal
prompt length form a wave (equal lengths share one cache timeline — the
per-layer rolling caches track one absolute position stream). Each wave:

  1. batched prompt fill: one decode step per prompt token, whole wave at
     once (cache build == the serving prefill path, so what's benchmarked
     is what runs);
  2. batched generation until every member hits EOS/max-new-tokens.

Exactly one compiled decode step serves prefill + generation (fixed shapes:
(slots, 1) tokens). Mixed prompt lengths queue into separate waves —
per-sequence position streams (paged caches) are the documented extension.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build_model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)


class ServeEngine:
    """Wave-batched engine for decoder-only archs."""

    def __init__(self, cfg: ArchConfig, *, slots: int = 4,
                 max_seq: int = 512, params=None, rng=None):
        self.cfg = cfg
        self.bundle = build_model(cfg)
        self.slots = slots
        self.max_seq = max_seq
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else self.bundle.init(rng)
        self._decode = jax.jit(self.bundle.decode)
        self._queue: deque[Request] = deque()
        self._uid = 0
        self.stats = {"waves": 0, "steps": 0, "requests": 0}

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        self._uid += 1
        self._queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                   max_new_tokens, eos_id))
        return self._uid

    def run(self, max_steps: int = 100_000) -> dict[int, list[int]]:
        results: dict[int, list[int]] = {}
        budget = max_steps
        while self._queue and budget > 0:
            wave = self._next_wave()
            budget -= self._run_wave(wave, results, budget)
        return results

    # -- internals -----------------------------------------------------------
    def _next_wave(self) -> list[Request]:
        """Pop up to ``slots`` queued requests sharing the first request's
        prompt length (equal lengths share a cache timeline)."""
        first = self._queue.popleft()
        wave = [first]
        plen = len(first.prompt)
        rest = deque()
        while self._queue and len(wave) < self.slots:
            r = self._queue.popleft()
            if len(r.prompt) == plen:
                wave.append(r)
            else:
                rest.append(r)
        self._queue.extendleft(reversed(rest))
        return wave

    def _run_wave(self, wave: list[Request],
                  results: dict[int, list[int]], budget: int) -> int:
        b = self.slots
        plen = len(wave[0].prompt)
        caches = self.bundle.init_cache(b, self.max_seq)
        tokens = np.zeros((b, plen), np.int32)
        for i, req in enumerate(wave):
            tokens[i] = req.prompt
        steps = 0

        # 1) prompt fill — batched decode over prompt tokens
        logits = None
        for t in range(plen):
            logits, caches = self._decode(
                self.params, jnp.asarray(tokens[:, t:t + 1]), caches)
            steps += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i, req in enumerate(wave):
            req.generated.append(int(nxt[i]))

        # 2) generation — batched greedy until the wave drains
        active = np.ones(b, bool)
        active[len(wave):] = False
        while active.any() and steps < budget:
            cur = np.zeros((b, 1), np.int32)
            for i, req in enumerate(wave):
                cur[i, 0] = req.generated[-1]
            logits, caches = self._decode(self.params, jnp.asarray(cur),
                                          caches)
            steps += 1
            nxt = np.asarray(jnp.argmax(logits, -1))
            for i, req in enumerate(wave):
                if not active[i]:
                    continue
                req.generated.append(int(nxt[i]))
                done = (len(req.generated) >= req.max_new_tokens
                        or (req.eos_id is not None
                            and nxt[i] == req.eos_id))
                if done:
                    active[i] = False
                    results[req.uid] = req.generated[:req.max_new_tokens]
        for req in wave:  # budget exhaustion still returns partials
            results.setdefault(req.uid, req.generated)
        self.stats["waves"] += 1
        self.stats["steps"] += steps
        self.stats["requests"] += len(wave)
        return steps
