"""Serving: batched continuous-decode engine + online vector queries.

``ServeEngine`` — wave-batched LM decode serving.
``VectorQueryService`` — ε-range point lookups over a ``DiskJoinIndex``
session, sharing the index's BufferPool/prefetcher and PipelineStats with
batch joins (ROADMAP "serving integration").
``QueryScheduler`` — wave-batched request queue with probe-sharing,
per-request deadlines, admission control and queue checkpointing
(ROADMAP "serving hardening"); ``IndexRouter`` fronts multiple index
shards with scatter/gather over health-gated replica sets
(``ReplicaSet``/``HealthTracker``/``ReplicaSupervisor`` in
``serve.replica`` — failover, hedging, supervised restart, degraded-mode
coverage). See README.md in this package for the request lifecycle.
"""
from repro.serve.engine import Request, ServeEngine
from repro.serve.query_service import VectorQueryService
from repro.serve.replica import (DEGRADED, DOWN, HEALTHY, Coverage,
                                 HealthTracker, Replica, ReplicaFuture,
                                 ReplicaSet, ReplicaSupervisor,
                                 ShardStatus, ShardUnavailable)
from repro.serve.router import IndexRouter, RouterFuture
from repro.serve.scheduler import (AdmissionRejected, DeadlineExceeded,
                                   QueryFuture, QueryScheduler,
                                   SchedulerClosed, SchedulerQueueFull,
                                   order_result)

__all__ = ["Request", "ServeEngine", "VectorQueryService",
           "QueryScheduler", "QueryFuture", "IndexRouter", "RouterFuture",
           "AdmissionRejected", "DeadlineExceeded", "SchedulerClosed",
           "SchedulerQueueFull", "order_result",
           "Replica", "ReplicaSet", "ReplicaFuture", "ReplicaSupervisor",
           "HealthTracker", "Coverage", "ShardStatus", "ShardUnavailable",
           "HEALTHY", "DEGRADED", "DOWN"]
