"""Serving: batched continuous-decode engine + online vector queries.

``ServeEngine`` — wave-batched LM decode serving.
``VectorQueryService`` — ε-range point lookups over a ``DiskJoinIndex``
session, sharing the index's BufferPool/prefetcher and PipelineStats with
batch joins (ROADMAP "serving integration").
"""
from repro.serve.engine import Request, ServeEngine
from repro.serve.query_service import VectorQueryService

__all__ = ["Request", "ServeEngine", "VectorQueryService"]
