"""Serving: batched continuous-decode engine."""
from repro.serve.engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
