"""Online ε-range vector query serving (ROADMAP: serving integration).

``VectorQueryService`` is a thin facade over a ``DiskJoinIndex`` session:
point queries route their candidate-bucket reads through the index's
shared ``BufferPool``/prefetcher and verify path, so online traffic and
any concurrently-running batch joins share one slab memory budget and one
``PipelineStats`` telemetry surface. The service itself only adds request
accounting (count + latency percentiles) and optional top-k truncation.
"""
from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.core.index import DiskJoinIndex


class VectorQueryService:
    """Serve ε-range point lookups from a built DiskJoin index.

    ``epsilon`` is the default threshold (falls back to the index's
    query-time default); per-request ``epsilon=``/``io_mode=`` overrides
    pass straight through to ``DiskJoinIndex.query_batch``.
    """

    def __init__(self, index: DiskJoinIndex, *,
                 epsilon: float | None = None,
                 latency_window: int = 4096):
        self.index = index
        if epsilon is None:
            if index.query_defaults is None:
                raise ValueError(
                    "epsilon required: the index has no query-time defaults")
            epsilon = index.query_defaults.epsilon
        self.epsilon = float(epsilon)
        self.requests = 0
        self._latencies: deque[float] = deque(maxlen=int(latency_window))
        self._lock = threading.Lock()

    # -- serving --------------------------------------------------------------
    def query(self, q: np.ndarray, epsilon: float | None = None,
              k: int | None = None,
              **overrides) -> tuple[np.ndarray, np.ndarray]:
        """One ε-range lookup → (ids, distances), nearest first.

        ``k`` truncates to the k nearest matches inside the ε ball."""
        return self.query_batch(np.asarray(q, np.float32)[None, :],
                                epsilon, k=k, **overrides)[0]

    def query_batch(self, Q: np.ndarray, epsilon: float | None = None,
                    k: int | None = None, **overrides
                    ) -> list[tuple[np.ndarray, np.ndarray]]:
        eps = self.epsilon if epsilon is None else float(epsilon)
        t0 = time.perf_counter()
        raw = self.index.query_batch(Q, eps, **overrides)
        dt = time.perf_counter() - t0
        out = []
        for ids, dists in raw:
            order = np.argsort(dists, kind="stable")
            if k is not None:
                order = order[:int(k)]
            out.append((ids[order], dists[order]))
        with self._lock:
            self.requests += len(out)
            # one request batch = one service round trip; attribute the
            # wall time evenly so percentiles stay per-request meaningful
            self._latencies.extend([dt / max(1, len(out))] * len(out))
        return out

    # -- telemetry ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Service counters + the index session's PipelineStats (one
        surface for online reads and batch-join loads)."""
        with self._lock:
            lats = np.asarray(self._latencies, np.float64)
            requests = self.requests
        d = {
            "requests": requests,
            "latency_p50_ms": (float(np.percentile(lats, 50)) * 1e3
                               if lats.size else 0.0),
            "latency_p95_ms": (float(np.percentile(lats, 95)) * 1e3
                               if lats.size else 0.0),
            "latency_mean_ms": (float(lats.mean()) * 1e3
                                if lats.size else 0.0),
        }
        d["pipeline"] = self.index.pipeline_snapshot()
        return d
