"""Online ε-range vector query serving (ROADMAP: serving integration).

``VectorQueryService`` is the synchronous facade over a ``DiskJoinIndex``
session: point queries route their candidate-bucket reads through the
index's shared ``BufferPool``/prefetcher and verify path, so online
traffic and any concurrently-running batch joins share one slab memory
budget and one ``PipelineStats`` telemetry surface.

Two serving modes:

* **direct** (default): each call runs ``DiskJoinIndex.query_batch``
  inline. Latency accounting is per *request as the caller experienced
  it* — every member of a batch records the batch's full wall time
  (a request is not done until its batch returns), and a separate
  per-batch ("wave") histogram keeps batch size/service time, so p95
  stays meaningful under mixed batch sizes.
* **scheduled**: construct with ``scheduler=`` (a
  ``repro.serve.QueryScheduler``, or ``True`` to own a default one) and
  calls enqueue into the shared wave scheduler — concurrent callers'
  overlapping probes collapse into one read per distinct bucket, and the
  recorded latency is the request's true enqueue→complete time.

Result ordering is deterministic in both modes: nearest first, ties
broken by vector id (identical queries return identical orderings across
io_mode and striping configurations).
"""
from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.core.index import DiskJoinIndex
from repro.serve.scheduler import (QueryScheduler, order_result,
                                   summarize_waves)


class VectorQueryService:
    """Serve ε-range point lookups from a built DiskJoin index.

    ``epsilon`` is the default threshold (falls back to the index's
    query-time default); per-request ``epsilon=``/``io_mode=`` overrides
    pass straight through. ``k`` truncates to the k nearest matches
    inside the ε ball.
    """

    def __init__(self, index: DiskJoinIndex, *,
                 epsilon: float | None = None,
                 latency_window: int = 4096,
                 scheduler: QueryScheduler | bool | None = None):
        self.index = index
        if epsilon is None:
            if index.query_defaults is None:
                raise ValueError(
                    "epsilon required: the index has no query-time defaults")
            epsilon = index.query_defaults.epsilon
        self.epsilon = float(epsilon)
        self._owns_scheduler = scheduler is True
        if scheduler is True:
            scheduler = QueryScheduler(index, epsilon=self.epsilon)
        self.scheduler = scheduler or None
        self.requests = 0
        self._latencies: deque[float] = deque(maxlen=int(latency_window))
        # per-wave histogram: (batch size, service seconds) — separate
        # from per-request latency so mixed batch sizes stay analyzable
        self._waves: deque[tuple[int, float]] = deque(
            maxlen=int(latency_window))
        self._lock = threading.Lock()
        # request counts + latency percentiles on the session's metrics
        # surface, alongside the pipeline/io/scheduler sections
        self._metrics_key = index.metrics.register_provider(
            "service", self._metrics_section)

    # -- serving --------------------------------------------------------------
    def query(self, q: np.ndarray, epsilon: float | None = None,
              k: int | None = None,
              **overrides) -> tuple[np.ndarray, np.ndarray]:
        """One ε-range lookup → (ids, distances), nearest first (ties by
        id)."""
        return self.query_batch(np.asarray(q, np.float32)[None, :],
                                epsilon, k=k, **overrides)[0]

    def query_batch(self, Q: np.ndarray, epsilon: float | None = None,
                    k: int | None = None, **overrides
                    ) -> list[tuple[np.ndarray, np.ndarray]]:
        eps = self.epsilon if epsilon is None else float(epsilon)
        if self.scheduler is not None:
            return self._query_batch_scheduled(Q, eps, k, overrides)
        t0 = time.perf_counter()
        raw = self.index.query_batch(Q, eps, **overrides)
        dt = time.perf_counter() - t0
        out = [order_result(ids, dists, k) for ids, dists in raw]
        with self._lock:
            self.requests += len(out)
            # a member request completes when its batch does: each one
            # records the full batch wall time (true caller-observed
            # latency), and the batch itself lands in the wave histogram
            self._latencies.extend([dt] * len(out))
            self._waves.append((len(out), dt))
        return out

    def _query_batch_scheduled(self, Q: np.ndarray, eps: float,
                               k: int | None, overrides: dict
                               ) -> list[tuple[np.ndarray, np.ndarray]]:
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        futs = [self.scheduler.submit(q, epsilon=eps, k=k, **overrides)
                for q in Q]
        out = [f.result() for f in futs]
        with self._lock:
            self.requests += len(out)
            # true enqueue→complete latency, as recorded by the scheduler
            self._latencies.extend(f.latency_s for f in futs)
        return out

    # -- telemetry ------------------------------------------------------------
    def _metrics_section(self) -> dict:
        """Provider for the index session's ``MetricsRegistry``: request
        count and true per-request latency percentiles."""
        with self._lock:
            lats = np.asarray(self._latencies, np.float64)
            requests = self.requests
        return {
            "requests": requests,
            "latency_p50_ms": (float(np.percentile(lats, 50)) * 1e3
                               if lats.size else 0.0),
            "latency_p95_ms": (float(np.percentile(lats, 95)) * 1e3
                               if lats.size else 0.0),
            "latency_p99_ms": (float(np.percentile(lats, 99)) * 1e3
                               if lats.size else 0.0),
        }

    def snapshot(self) -> dict:
        """Service counters + the index session's PipelineStats (one
        surface for online reads and batch-join loads). ``latency_*`` are
        true per-request figures; ``wave`` summarizes the per-batch
        histogram (direct mode) or defers to the scheduler's own waves."""
        with self._lock:
            lats = np.asarray(self._latencies, np.float64)
            waves = list(self._waves)
            requests = self.requests
        d = {
            "requests": requests,
            "latency_p50_ms": (float(np.percentile(lats, 50)) * 1e3
                               if lats.size else 0.0),
            "latency_p95_ms": (float(np.percentile(lats, 95)) * 1e3
                               if lats.size else 0.0),
            "latency_mean_ms": (float(lats.mean()) * 1e3
                                if lats.size else 0.0),
        }
        if self.scheduler is not None:
            sched = self.scheduler.snapshot()
            d["wave"] = sched["wave"]
            d["scheduler"] = {key: sched[key] for key in
                              ("submitted", "completed", "rejected",
                               "deadline_drops", "waves", "pending")}
        else:
            d["wave"] = summarize_waves(waves)
        d["pipeline"] = self.index.pipeline_snapshot()
        return d

    def close(self) -> None:
        """Close the service's own scheduler (no-op for an injected one —
        its owner closes it; the index always belongs to the caller)."""
        if self._owns_scheduler and self.scheduler is not None:
            self.scheduler.close()
        self.index.metrics.unregister_provider(self._metrics_key)
