"""Multi-index router: one serving front door over sharded corpora.

A billion-scale corpus is built as several ``DiskJoinIndex`` shards
(separate bucketizations, separate stores — often separate machines'
worth of SSDs). ``IndexRouter`` fronts them with the same request surface
as a single index:

  * **route** — a request only scatters to shards that can possibly
    answer it: shard ``s`` is selected iff some bucket of ``s`` satisfies
    the center-index proximity test ``‖q − c_b‖ − r_b ≤ ε`` (the same
    triangle-inequality bound ``plan_probes`` uses, evaluated against the
    shard's in-memory centers/radii — no disk I/O). A query deep inside
    one shard's clusters skips the others entirely.
  * **scatter/gather** — selected shards receive the request through
    their own per-shard ``QueryScheduler``, so each shard forms its own
    waves and shares probes across ALL concurrent traffic it sees
    (including requests scattered by other router calls). The returned
    ``RouterFuture`` gathers the shard futures.
  * **merge** — shard-local ids are offset into one global id space
    (``id_offsets``; defaults to cumulative shard sizes, matching shards
    built from consecutive slices of one dataset) and the merged ε-result
    is ordered deterministically (distance, then global id) — exactly the
    ordering an unsharded index over the concatenated dataset returns.

Deadline semantics are strict: a request resolves with
``DeadlineExceeded`` if ANY selected shard dropped it — a partial answer
is not an ε-range answer.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.index import DiskJoinIndex
from repro.io import PipelineStats
from repro.obs import MetricsRegistry
from repro.obs.live import merge_live_sections
from repro.serve.scheduler import QueryScheduler, _check_k, order_result

_EMPTY = (np.zeros(0, np.int64), np.zeros(0, np.float32))


class RouterFuture:
    """Gather handle over the selected shards' ``QueryFuture``s.

    ``result(timeout)`` waits for every part, offsets shard-local ids into
    the router's global id space, merges, and orders deterministically
    (distance, then global id; truncated to the request's ``k``). Raises
    the first shard exception (``DeadlineExceeded`` included) — strict
    all-or-nothing semantics.
    """

    def __init__(self, parts: list[tuple], k: int | None):
        self._parts = parts          # [(QueryFuture, id_offset), ...]
        self._k = k

    def done(self) -> bool:
        return all(f.done() for f, _ in self._parts)

    @property
    def latency_s(self) -> float | None:
        """Slowest part's enqueue→complete latency (None until done)."""
        lats = [f.latency_s for f, _ in self._parts]
        if not lats:
            return 0.0
        return None if any(v is None for v in lats) else max(lats)

    def result(self, timeout: float | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
        if not self._parts:
            return _EMPTY
        end = None if timeout is None else time.perf_counter() + timeout
        acc_i, acc_d = [], []
        for fut, off in self._parts:
            rem = (None if end is None
                   else max(0.0, end - time.perf_counter()))
            ids, dists = fut.result(timeout=rem)
            acc_i.append(ids + off)
            acc_d.append(dists)
        return order_result(np.concatenate(acc_i), np.concatenate(acc_d),
                            self._k)


class IndexRouter:
    """Scatter/gather ε-range serving over multiple ``DiskJoinIndex``
    shards, each behind its own wave scheduler.

    Parameters:
      shards: the member sessions (all must share one vector dim).
      epsilon: default threshold; None falls back to each shard's own
        query-time defaults (every shard must then have them).
      id_offsets: global id base per shard; defaults to cumulative shard
        sizes (shard i's local id ``j`` maps to ``offsets[i] + j``).
      scheduler: kwargs forwarded to every per-shard ``QueryScheduler``
        (wave_size, max_wait_s, max_queue, share_probes, io_mode=…, …).
      close_shards: make ``close()`` also close the member indexes.
    """

    def __init__(self, shards: list[DiskJoinIndex], *,
                 epsilon: float | None = None,
                 id_offsets: list[int] | None = None,
                 scheduler: dict | None = None,
                 close_shards: bool = False):
        if not shards:
            raise ValueError("router needs at least one shard")
        dims = {s.dim for s in shards}
        if len(dims) != 1:
            raise ValueError(f"shards disagree on vector dim: {sorted(dims)}")
        self.dim = dims.pop()
        if epsilon is None:
            missing = [i for i, s in enumerate(shards)
                       if s.query_defaults is None]
            if missing:
                raise ValueError(
                    f"epsilon required: shard(s) {missing} have no "
                    f"query-time defaults")
        self.shards = list(shards)
        self.epsilon = None if epsilon is None else float(epsilon)
        if id_offsets is None:
            sizes = [s.num_vectors for s in shards]
            id_offsets = [0] + list(np.cumsum(sizes[:-1], dtype=np.int64))
        if len(id_offsets) != len(shards):
            raise ValueError(f"{len(id_offsets)} id_offsets for "
                             f"{len(shards)} shards")
        self.id_offsets = [int(o) for o in id_offsets]
        self.schedulers = [QueryScheduler(s, epsilon=epsilon,
                                          **dict(scheduler or {}))
                           for s in shards]
        self._close_shards = bool(close_shards)
        self.requests = 0
        self.scattered = 0

    # -- routing --------------------------------------------------------------
    def _effective_eps(self, shard: DiskJoinIndex,
                       epsilon: float | None) -> float:
        if epsilon is not None:
            return float(epsilon)
        if self.epsilon is not None:
            return self.epsilon
        return float(shard.query_defaults.epsilon)

    def route(self, q: np.ndarray,
              epsilon: float | None = None) -> list[int]:
        """Shard indices whose center-index proximity test admits ``q`` —
        the shards that can possibly hold an ε-neighbor (in-memory test,
        no disk reads). Validates the query the same way the shards do
        (dim + finiteness): a NaN query must raise, not silently admit
        zero shards and read as "no neighbors"."""
        q = self.shards[0]._validate_queries(q)[0]
        out = []
        for si, shard in enumerate(self.shards):
            eps = self._effective_eps(shard, epsilon)
            d = np.linalg.norm(shard.meta.centers - q[None, :], axis=1)
            if np.any(d - shard.meta.radii <= eps):
                out.append(si)
        return out

    # -- serving --------------------------------------------------------------
    def submit(self, q: np.ndarray, *, epsilon: float | None = None,
               k: int | None = None, deadline_s: float | None = None,
               **overrides) -> RouterFuture:
        """Scatter one request to the admitted shards → ``RouterFuture``.

        Per-shard truncation to ``k`` is sound (the k nearest of the union
        lie within the union of per-shard k-nearest); the gather merges
        and truncates again globally.
        """
        k = _check_k(k)
        selected = self.route(q, epsilon)
        parts = []
        for si in selected:
            fut = self.schedulers[si].submit(
                q, epsilon=epsilon, k=k, deadline_s=deadline_s,
                **overrides)
            parts.append((fut, self.id_offsets[si]))
        self.requests += 1
        self.scattered += len(parts)
        return RouterFuture(parts, k)

    def query(self, q: np.ndarray, *, epsilon: float | None = None,
              k: int | None = None, deadline_s: float | None = None,
              timeout: float | None = None,
              **overrides) -> tuple[np.ndarray, np.ndarray]:
        """Synchronous scatter/gather for one query."""
        return self.submit(q, epsilon=epsilon, k=k, deadline_s=deadline_s,
                           **overrides).result(timeout=timeout)

    def query_batch(self, Q: np.ndarray, *, epsilon: float | None = None,
                    k: int | None = None, deadline_s: float | None = None,
                    timeout: float | None = None, **overrides
                    ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Submit a batch (members share shard waves), gather all."""
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        futs = [self.submit(q, epsilon=epsilon, k=k, deadline_s=deadline_s,
                            **overrides) for q in Q]
        return [f.result(timeout=timeout) for f in futs]

    # -- telemetry / lifecycle ------------------------------------------------
    def pipeline_snapshot(self) -> dict:
        """Fleet-level ``PipelineStats`` rollup over every shard session
        (``PipelineStats.merge``: counters sum, gauges max, per-device
        lists concatenate — shards own distinct devices)."""
        return PipelineStats.merge([s.stats.snapshot()
                                    for s in self.shards])

    def metrics_snapshot(self) -> dict:
        """Fleet-level ``MetricsRegistry`` rollup over the shards'
        sessions, with the pipeline sections re-merged domain-aware."""
        merged = MetricsRegistry.merge([s.metrics_snapshot()
                                        for s in self.shards])
        if isinstance(merged.get("pipeline"), list):
            merged["pipeline"] = PipelineStats.merge(merged["pipeline"])
        if isinstance(merged.get("live"), list):
            # per-shard rollup windows share log-bucket bounds, so the
            # span histograms merge exactly (same path as _merge_hist)
            merged["live"] = merge_live_sections(merged["live"])
        return merged

    def attach_live(self, **kw) -> list:
        """``DiskJoinIndex.attach_live`` on every shard (same kwargs);
        returns the per-shard observers. ``repro.obs.dash`` renders a
        router by merging these shards' live sections."""
        return [s.attach_live(**kw) for s in self.shards]

    def detach_live(self) -> None:
        for s in self.shards:
            if s.live is not None:
                s.detach_live()

    def snapshot(self) -> dict:
        """Router fan-out counters plus every shard scheduler's snapshot
        and the merged fleet pipeline view."""
        return {
            "requests": self.requests,
            "scattered": self.scattered,
            "fanout_mean": self.scattered / self.requests
            if self.requests else 0.0,
            "num_shards": len(self.shards),
            "shards": [s.snapshot() for s in self.schedulers],
            "pipeline": self.pipeline_snapshot(),
        }

    def close(self) -> None:
        for s in self.schedulers:
            s.close()
        if self._close_shards:
            for s in self.shards:
                s.close()

    def __enter__(self) -> "IndexRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
