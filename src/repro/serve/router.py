"""Multi-index router: one serving front door over sharded corpora.

A billion-scale corpus is built as several ``DiskJoinIndex`` shards
(separate bucketizations, separate stores — often separate machines'
worth of SSDs). ``IndexRouter`` fronts them with the same request surface
as a single index:

  * **route** — a request only scatters to shards that can possibly
    answer it: shard ``s`` is selected iff some bucket of ``s`` satisfies
    the center-index proximity test ``‖q − c_b‖ − r_b ≤ ε`` (the same
    triangle-inequality bound ``plan_probes`` uses, evaluated against the
    shard's in-memory centers/radii — no disk I/O). A query deep inside
    one shard's clusters skips the others entirely.
  * **replicate** — each logical shard may be a LIST of replica sessions
    (same manifest, independent ``BufferPool``/``QueryScheduler``): the
    request goes to ONE replica chosen by the set's routing policy
    (least-loaded by queue depth x predicted service, health-gated:
    ``DOWN`` replicas are ejected, ``DEGRADED`` deprioritized), fails
    over to a sibling when an attempt dies, and can hedge a backup probe
    — see ``serve/replica.py``.
  * **scatter/gather** — each selected shard's replica set forms its own
    waves and shares probes across ALL concurrent traffic it sees. The
    returned ``RouterFuture`` gathers the per-shard futures.
  * **merge** — shard-local ids are offset into one global id space
    (``id_offsets``; defaults to cumulative shard sizes) and the merged
    ε-result is ordered deterministically (distance, then global id) —
    exactly the ordering an unsharded index over the concatenated
    dataset returns. With every replica healthy, replicated routing is
    byte-identical to single-copy routing (replicas serve the same
    manifest).

Coverage contract: by default deadline/availability semantics are strict
— a request resolves with the underlying error if ANY selected shard
failed it (a silently partial answer is not an ε-range answer). With
``require_full_coverage=False`` a shard whose every replica is dead (or
that dropped its deadline) becomes a COVERAGE GAP instead: ``result()``
returns the surviving shards' merge and ``RouterFuture.coverage`` says
exactly which shards answered (``Coverage.answered/total`` plus
per-shard ``ShardStatus``). Callers that can tolerate partial recall
(e.g. best-effort retrieval under an outage) opt in; callers that cannot
keep the default and get the exception.
"""
from __future__ import annotations

import time
from concurrent.futures import TimeoutError as FuturesTimeout

import numpy as np

from repro.core.index import DiskJoinIndex
from repro.io import PipelineStats
from repro.obs import MetricsRegistry
from repro.obs.live import merge_live_sections
from repro.serve.replica import (Coverage, ReplicaSet, ShardStatus,
                                 ShardUnavailable)
from repro.serve.scheduler import (DeadlineExceeded, _check_k,
                                   order_result)

_EMPTY = (np.zeros(0, np.int64), np.zeros(0, np.float32))


class RouterFuture:
    """Gather handle over the selected shards' replica futures.

    ``result(timeout)`` waits for every part, offsets shard-local ids
    into the router's global id space, merges, and orders
    deterministically (distance, then global id; truncated to the
    request's ``k``).

    Strict mode (``require_full_coverage=True``, the default): raises
    the first shard exception (``DeadlineExceeded``,
    ``ShardUnavailable``, a store error that exhausted every replica) —
    all-or-nothing semantics.

    Degraded mode (``require_full_coverage=False``): shard-level
    failures become coverage gaps — ``result()`` returns the surviving
    shards' merge and ``self.coverage`` records per-shard status.
    Gather-level ``TimeoutError`` and request-validation errors always
    raise; they are caller problems, not shard outages.
    """

    def __init__(self, parts: list[tuple], k: int | None,
                 require_full_coverage: bool = True):
        self._parts = parts     # [(future, id_offset, shard_index), ...]
        self._k = k
        self._require_full = bool(require_full_coverage)
        self.coverage: Coverage | None = None

    def done(self) -> bool:
        return all(f.done() for f, _, _ in self._parts)

    @property
    def latency_s(self) -> float | None:
        """Slowest part's enqueue→complete latency (None until done)."""
        lats = [f.latency_s for f, _, _ in self._parts]
        if not lats:
            return 0.0
        return None if any(v is None for v in lats) else max(lats)

    def result(self, timeout: float | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
        if not self._parts:
            self.coverage = Coverage(answered=0, total=0, statuses=[])
            return _EMPTY
        end = None if timeout is None else time.perf_counter() + timeout
        acc_i, acc_d, statuses = [], [], []
        for fut, off, si in self._parts:
            rem = (None if end is None
                   else max(0.0, end - time.perf_counter()))
            try:
                ids, dists = fut.result(timeout=rem)
            except (FuturesTimeout, TimeoutError):
                raise               # the gather timed out, not the shard
            except (ValueError, TypeError):
                raise               # bad request: never a coverage gap
            except ShardUnavailable as e:
                if self._require_full:
                    raise
                statuses.append(ShardStatus(si, "unavailable", repr(e)))
                continue
            except DeadlineExceeded as e:
                if self._require_full:
                    raise
                statuses.append(ShardStatus(si, "deadline", repr(e)))
                continue
            except (OSError, RuntimeError) as e:
                if self._require_full:
                    raise
                statuses.append(ShardStatus(si, "error", repr(e)))
                continue
            statuses.append(ShardStatus(si, "ok"))
            acc_i.append(ids + off)
            acc_d.append(dists)
        self.coverage = Coverage(
            answered=sum(1 for s in statuses if s.status == "ok"),
            total=len(self._parts), statuses=statuses)
        if not acc_i:
            return _EMPTY
        return order_result(np.concatenate(acc_i), np.concatenate(acc_d),
                            self._k)


class IndexRouter:
    """Scatter/gather ε-range serving over multiple ``DiskJoinIndex``
    shards, each behind a health-gated replica set.

    Parameters:
      shards: the member sessions. Each entry is either one
        ``DiskJoinIndex`` (single copy) or a LIST of sessions over the
        same manifest (a replica set — typically N ``open()`` calls on
        one workdir).
      epsilon: default threshold; None falls back to each shard's own
        query-time defaults (every shard must then have them).
      id_offsets: global id base per logical shard; defaults to
        cumulative shard sizes.
      scheduler: kwargs forwarded to every per-replica
        ``QueryScheduler`` (wave_size, max_wait_s, max_queue, …).
      policy / hedge / health: forwarded to every ``ReplicaSet`` —
        routing policy (``"least_loaded"``/``"round_robin"``), hedging
        knob (None / seconds / ``"plan"``) and ``HealthTracker`` kwargs.
      require_full_coverage: default strictness of ``RouterFuture``
        gathers (overridable per request).
      close_shards: make ``close()`` also close the member indexes.
    """

    def __init__(self, shards: list, *,
                 epsilon: float | None = None,
                 id_offsets: list[int] | None = None,
                 scheduler: dict | None = None,
                 close_shards: bool = False,
                 policy: str = "least_loaded",
                 hedge=None,
                 health: dict | None = None,
                 require_full_coverage: bool = True):
        if not shards:
            raise ValueError("router needs at least one shard")
        groups = [list(s) if isinstance(s, (list, tuple)) else [s]
                  for s in shards]
        if any(not g for g in groups):
            raise ValueError("a shard's replica list cannot be empty")
        flat = [r for g in groups for r in g]
        dims = {s.dim for s in flat}
        if len(dims) != 1:
            raise ValueError(f"shards disagree on vector dim: {sorted(dims)}")
        self.dim = dims.pop()
        if epsilon is None:
            missing = [i for i, g in enumerate(groups)
                       if any(s.query_defaults is None for s in g)]
            if missing:
                raise ValueError(
                    f"epsilon required: shard(s) {missing} have no "
                    f"query-time defaults")
        # primaries: routing metadata (centers/radii/sizes — identical
        # across a set's replicas, which serve the same manifest)
        self.shards = [g[0] for g in groups]
        self.epsilon = None if epsilon is None else float(epsilon)
        if id_offsets is None:
            sizes = [s.num_vectors for s in self.shards]
            id_offsets = [0] + list(np.cumsum(sizes[:-1], dtype=np.int64))
        if len(id_offsets) != len(groups):
            raise ValueError(f"{len(id_offsets)} id_offsets for "
                             f"{len(groups)} shards")
        self.id_offsets = [int(o) for o in id_offsets]
        self.replica_sets = [
            ReplicaSet(g, epsilon=epsilon, scheduler=scheduler,
                       policy=policy, hedge=hedge, health=health,
                       name=f"shard{i}")
            for i, g in enumerate(groups)]
        self.require_full_coverage = bool(require_full_coverage)
        self._close_shards = bool(close_shards)
        self.requests = 0
        self.scattered = 0

    @property
    def all_indexes(self) -> list[DiskJoinIndex]:
        """Every replica session across every logical shard."""
        return [r.index for rset in self.replica_sets
                for r in rset.replicas]

    @property
    def schedulers(self) -> list:
        """Every replica scheduler (flat; one per replica session)."""
        return [r.scheduler for rset in self.replica_sets
                for r in rset.replicas]

    # -- routing --------------------------------------------------------------
    def _effective_eps(self, shard: DiskJoinIndex,
                       epsilon: float | None) -> float:
        if epsilon is not None:
            return float(epsilon)
        if self.epsilon is not None:
            return self.epsilon
        return float(shard.query_defaults.epsilon)

    def route(self, q: np.ndarray,
              epsilon: float | None = None) -> list[int]:
        """Shard indices whose center-index proximity test admits ``q`` —
        the shards that can possibly hold an ε-neighbor (in-memory test,
        no disk reads). Validates the query the same way the shards do
        (dim + finiteness): a NaN query must raise, not silently admit
        zero shards and read as "no neighbors"."""
        q = self.shards[0]._validate_queries(q)[0]
        out = []
        for si, shard in enumerate(self.shards):
            eps = self._effective_eps(shard, epsilon)
            d = np.linalg.norm(shard.meta.centers - q[None, :], axis=1)
            if np.any(d - shard.meta.radii <= eps):
                out.append(si)
        return out

    # -- serving --------------------------------------------------------------
    def submit(self, q: np.ndarray, *, epsilon: float | None = None,
               k: int | None = None, deadline_s: float | None = None,
               require_full_coverage: bool | None = None,
               **overrides) -> RouterFuture:
        """Scatter one request to the admitted shards → ``RouterFuture``.

        Per-shard truncation to ``k`` is sound (the k nearest of the union
        lie within the union of per-shard k-nearest); the gather merges
        and truncates again globally. ``require_full_coverage`` overrides
        the router default for this request only.
        """
        k = _check_k(k)
        selected = self.route(q, epsilon)
        parts = []
        for si in selected:
            fut = self.replica_sets[si].submit(
                q, epsilon=epsilon, k=k, deadline_s=deadline_s,
                **overrides)
            parts.append((fut, self.id_offsets[si], si))
        self.requests += 1
        self.scattered += len(parts)
        strict = (self.require_full_coverage
                  if require_full_coverage is None
                  else bool(require_full_coverage))
        return RouterFuture(parts, k, require_full_coverage=strict)

    def query(self, q: np.ndarray, *, epsilon: float | None = None,
              k: int | None = None, deadline_s: float | None = None,
              timeout: float | None = None,
              require_full_coverage: bool | None = None,
              **overrides) -> tuple[np.ndarray, np.ndarray]:
        """Synchronous scatter/gather for one query."""
        return self.submit(q, epsilon=epsilon, k=k, deadline_s=deadline_s,
                           require_full_coverage=require_full_coverage,
                           **overrides).result(timeout=timeout)

    def query_batch(self, Q: np.ndarray, *, epsilon: float | None = None,
                    k: int | None = None, deadline_s: float | None = None,
                    timeout: float | None = None, **overrides
                    ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Submit a batch (members share shard waves), gather all."""
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        futs = [self.submit(q, epsilon=epsilon, k=k, deadline_s=deadline_s,
                            **overrides) for q in Q]
        return [f.result(timeout=timeout) for f in futs]

    # -- telemetry / lifecycle ------------------------------------------------
    def pipeline_snapshot(self) -> dict:
        """Fleet-level ``PipelineStats`` rollup over every replica
        session (``PipelineStats.merge``: counters sum, gauges max,
        per-device lists concatenate — sessions own distinct pools)."""
        return PipelineStats.merge([s.stats.snapshot()
                                    for s in self.all_indexes])

    def metrics_snapshot(self) -> dict:
        """Fleet-level ``MetricsRegistry`` rollup over every replica
        session, with the pipeline sections re-merged domain-aware."""
        merged = MetricsRegistry.merge([s.metrics_snapshot()
                                        for s in self.all_indexes])
        if isinstance(merged.get("pipeline"), list):
            merged["pipeline"] = PipelineStats.merge(merged["pipeline"])
        if isinstance(merged.get("live"), list):
            # per-session rollup windows share log-bucket bounds, so the
            # span histograms merge exactly (same path as _merge_hist)
            merged["live"] = merge_live_sections(merged["live"])
        return merged

    def attach_live(self, **kw) -> list:
        """``DiskJoinIndex.attach_live`` on every replica session (same
        kwargs); returns the observers. Attaching live also arms the
        health trackers' SLO fold (``HealthTracker`` consults
        ``LiveObserver.slo_firing``)."""
        return [s.attach_live(**kw) for s in self.all_indexes]

    def detach_live(self) -> None:
        for s in self.all_indexes:
            if s.live is not None:
                s.detach_live()

    def snapshot(self) -> dict:
        """Router fan-out counters, every replica scheduler's snapshot
        (grouped per logical shard under ``replica_sets``), and the
        merged fleet pipeline view."""
        return {
            "requests": self.requests,
            "scattered": self.scattered,
            "fanout_mean": self.scattered / self.requests
            if self.requests else 0.0,
            "num_shards": len(self.replica_sets),
            "shards": [r.scheduler.snapshot()
                       for rset in self.replica_sets
                       for r in rset.replicas],
            "replica_sets": [rset.snapshot()
                             for rset in self.replica_sets],
            "pipeline": self.pipeline_snapshot(),
        }

    def close(self) -> None:
        for rset in self.replica_sets:
            rset.close(close_indexes=self._close_shards)

    def __enter__(self) -> "IndexRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
