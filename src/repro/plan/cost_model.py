"""Device/host/SSD cost model calibrated from pipeline telemetry.

Every planner decision reduces to comparing a handful of linear cost
terms: seconds per bucket read, seconds per byte across the host↔device
link, and seconds per candidate *cell* (one d² evaluation + threshold
test) on each verify path. This module owns those coefficients and where
they came from.

Calibration sources, in priority order (recorded per-coefficient in
``provenance`` so ``JoinPlan.explain()`` can say *why* a number was
believed):

1. **measured** — a live ``PipelineStats`` snapshot from the same index
   session: ``read_s / loads`` is the observed per-bucket read latency,
   ``h2d_bytes`` over transfer counts sanity-checks the link model.
2. **config** — the emulation knobs (``emulate_read_latency_s``,
   ``emulate_xfer_gb_s``) when set: the workload *will* pay these, so
   they beat any static default.
3. **static** — built-in fallbacks for a cold session with no telemetry.
   On this CPU-only container host==device memory, so the static link
   bandwidth is 0 ("free"): transfers cost nothing unless emulated.

The host/device per-cell rates are static by design: the host path
evaluates d² and extracts pairs with NumPy at roughly kernel speed but
pays a full cap×cap mask + d² readback per edge, while the device path
fuses verify+compact (paying a small per-cell compaction overhead and a
larger fixed dispatch cost) and reads back only ``pairs × 12 B``. With a
free link the host path's simplicity wins; once the link is slow (real
PCIe, or ``emulate_xfer_gb_s``), shipping cap²·5 B of mask+d² per edge
loses badly to the device path's compacted readback — which is exactly
the flip the planner's host/device routing decision captures.
"""
from __future__ import annotations

import dataclasses

_STATIC_READ_S = 2e-4          # per-bucket read on a warm NVMe
_STATIC_HOST_CELL_NS = 1.0     # host verify+extract, per candidate cell
_STATIC_DEVICE_CELL_NS = 1.3   # fused verify+compact, per candidate cell
_STATIC_HOST_DISPATCH_S = 2e-5   # per host flush (python + BLAS entry)
_STATIC_DEVICE_DISPATCH_S = 3e-4  # per device dispatch (jit call + sync)
_MASK_D2_BYTES = 5             # host readback per cell: bool mask + f32 d2
_PAIR_BYTES = 12               # device readback per pair: 2×i32 ids + f32 d


@dataclasses.dataclass
class CostModel:
    """Linear cost coefficients + the provenance of each."""

    read_s_per_bucket: float = _STATIC_READ_S
    h2d_gb_s: float = 0.0          # 0 ⇒ free link (unified memory)
    d2h_gb_s: float = 0.0
    host_cell_ns: float = _STATIC_HOST_CELL_NS
    device_cell_ns: float = _STATIC_DEVICE_CELL_NS
    host_dispatch_s: float = _STATIC_HOST_DISPATCH_S
    device_dispatch_s: float = _STATIC_DEVICE_DISPATCH_S
    provenance: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_telemetry(cls, config=None, pipeline: dict | None = None,
                       live: dict | None = None) -> "CostModel":
        """Calibrate from a ``PipelineStats.snapshot()`` dict, the
        rolling span-derived constants of a ``repro.obs.live``
        ``LiveCalibrator`` (its ``constants()`` dict, or the calibrator
        itself), and/or the session config's emulation knobs; static
        fallbacks otherwise.

        Per-coefficient priority is **measured > live > config >
        static**: a batch pipeline's own cumulative counters stay
        authoritative where they exist (per-bucket read), the live tier's
        windowed medians cover everything the counters can't see or that
        drifted since plan time (the link, a mid-run latency shift on a
        session passing windowed rather than cumulative telemetry), the
        emulation knobs predict what the workload *will* pay, and the
        static defaults catch a cold session."""
        m = cls()
        prov = {"read_s_per_bucket": "static", "link": "static(free)",
                "host_cell_ns": "static", "device_cell_ns": "static"}
        if live is not None and hasattr(live, "constants"):
            live = live.constants()
        live = live or {}

        def live_tag(entry: dict) -> str:
            return (f"live({entry.get('samples', '?')} spans/"
                    f"{entry.get('windows', '?')} windows)")

        emu_read = float(getattr(config, "emulate_read_latency_s", 0.0)
                         or 0.0) if config is not None else 0.0
        live_read = live.get("read_s_per_bucket")
        if pipeline and pipeline.get("loads", 0) > 0 \
                and pipeline.get("read_s", 0.0) > 0.0:
            m.read_s_per_bucket = (pipeline["read_s"]
                                   / pipeline["loads"])
            prov["read_s_per_bucket"] = (
                f"measured({pipeline['loads']} loads)")
        elif live_read and live_read.get("value", 0.0) > 0.0:
            m.read_s_per_bucket = float(live_read["value"])
            prov["read_s_per_bucket"] = live_tag(live_read)
        elif emu_read > 0.0:
            m.read_s_per_bucket = emu_read
            prov["read_s_per_bucket"] = "config(emulate_read_latency_s)"
        emu_xfer = float(getattr(config, "emulate_xfer_gb_s", 0.0)
                         or 0.0) if config is not None else 0.0
        live_link = live.get("h2d_gb_s")
        if live_link and live_link.get("value", 0.0) > 0.0:
            # no counter measures the link, so live IS its top tier
            m.h2d_gb_s = m.d2h_gb_s = float(live_link["value"])
            prov["link"] = live_tag(live_link)
        elif emu_xfer > 0.0:
            m.h2d_gb_s = m.d2h_gb_s = emu_xfer
            prov["link"] = "config(emulate_xfer_gb_s)"
        m.provenance = prov
        return m

    # -- primitive terms --------------------------------------------------------
    def xfer_s(self, nbytes: float, gb_s: float) -> float:
        return nbytes / (gb_s * 1e9) if gb_s > 0.0 else 0.0

    def read_s(self, n_buckets: int) -> float:
        return n_buckets * self.read_s_per_bucket

    # -- per-edge verify costs ---------------------------------------------------
    def host_edge_s(self, cells: float, cap: int, dim: int,
                    batch: int = 1) -> float:
        """One (u, v) edge on the host path: stage both slabs across the
        link, evaluate ``cells`` candidates, read back the full cap×cap
        mask + d² block, amortizing one dispatch over ``batch`` edges."""
        stage = self.xfer_s(2 * cap * dim * 4, self.h2d_gb_s)
        fetch = self.xfer_s(cap * cap * _MASK_D2_BYTES, self.d2h_gb_s)
        return (stage + cells * self.host_cell_ns * 1e-9 + fetch
                + self.host_dispatch_s / max(1, batch))

    def device_edge_s(self, cells: float, pairs_hi: float, cap: int,
                      dim: int, fresh_slabs: float = 0.0,
                      batch: int = 1) -> float:
        """One (u, v) edge on the device path: H2D only for slabs not yet
        device-resident (``fresh_slabs``, fractional when amortized),
        fused verify+compact over ``cells``, compacted ``pairs_hi × 12 B``
        readback, one dispatch amortized over ``batch`` edges."""
        h2d = self.xfer_s(fresh_slabs * cap * dim * 4, self.h2d_gb_s)
        d2h = self.xfer_s(pairs_hi * _PAIR_BYTES + 4, self.d2h_gb_s)
        return (h2d + cells * self.device_cell_ns * 1e-9 + d2h
                + self.device_dispatch_s / max(1, batch))

    # -- query-wave costs ---------------------------------------------------------
    def host_query_s(self, cells: float) -> float:
        return (cells * self.host_cell_ns * 1e-9
                + self.host_dispatch_s)

    def device_query_s(self, cells: float, pairs_hi: float, nq: int,
                       cap: int, dim: int, fresh_slabs: int) -> float:
        h2d = self.xfer_s((fresh_slabs * cap + nq) * dim * 4,
                          self.h2d_gb_s)
        d2h = self.xfer_s(pairs_hi * _PAIR_BYTES + 4, self.d2h_gb_s)
        return (h2d + cells * self.device_cell_ns * 1e-9 + d2h
                + self.device_dispatch_s)

    def describe(self) -> str:
        link = (f"{self.h2d_gb_s:g} GB/s"
                if self.h2d_gb_s > 0 else "free")
        return (f"read={self.read_s_per_bucket * 1e3:.3f} ms/bucket "
                f"[{self.provenance.get('read_s_per_bucket', '?')}], "
                f"link={link} [{self.provenance.get('link', '?')}], "
                f"host={self.host_cell_ns:g} ns/cell, "
                f"device={self.device_cell_ns:g} ns/cell")
