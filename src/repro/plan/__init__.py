"""repro.plan — cost-based adaptive planning (see README.md).

``CardinalityEstimator`` answers *how many pairs will this work emit*
from per-bucket sample sketches; ``CostModel`` prices reads, transfers
and verify paths from telemetry; ``Planner`` turns both into typed,
explainable ``JoinPlan``/``WavePlan``/``PoolPlan`` objects the
core/io/compute/serve layers consume instead of hand-tuned knobs.
"""
from repro.plan.cost_model import CostModel
from repro.plan.estimator import (SKETCH_FILE, CardinalityEstimator,
                                  PairEstimate)
from repro.plan.planner import (Decision, JoinPlan, Planner, PoolPlan,
                                WavePlan, predict_replica_service_s)

__all__ = [
    "CardinalityEstimator", "PairEstimate", "SKETCH_FILE",
    "CostModel", "Planner", "JoinPlan", "WavePlan", "PoolPlan",
    "Decision", "predict_replica_service_s",
]
