"""Join-size estimation from per-bucket sample sketches.

*Similarity Join Size Estimation using LSH* (Lee/Ng/Shim, PAPERS.md)
shows that a cheap sketch pre-pass predicts per-bucket-pair output
cardinality well enough to drive planning decisions. This module is that
pre-pass for DiskJoin: each bucket carries a small uniform sample of its
member vectors (plus their squared norms — the "norm sketch" half that
turns every distance evaluation into one dot product), and the estimator
answers *how many result pairs will edge (u, v) emit at threshold ε* by
exhaustively verifying the s×s sampled cross pairs and scaling the hit
fraction to the full n_u×n_v pair population.

The estimate is a binomial proportion, so its error bars are calibrated
by construction: ``est_edges`` returns Wilson-score intervals at the
estimator's ``z`` (default 2 ≈ 95%), and the *upper* bound is what the
planner sizes hard capacities from (``compact_pairs`` lane capacity,
``query_verify_compact`` k_cap) — a bound that is allowed to be loose
but must rarely be exceeded, because exceeding it costs an overflow
re-dispatch (a recompile), while looseness only costs output-buffer
bytes.

Sketches are built once (during ``DiskJoinIndex.build``, from the flat
store — no bucketed-store reads) and persisted next to the manifest
(``plan_sketch.npz``); ``open()`` of an index built before sketches
existed rebuilds them lazily from the bucketed store with a one-time
warning. The sketch is ε-independent: one build serves every threshold.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

SKETCH_VERSION = 1
SKETCH_FILE = "plan_sketch.npz"
DEFAULT_SAMPLE_ROWS = 16
DEFAULT_Z = 2.0
_EDGE_CHUNK = 512  # edges estimated per vectorized block (memory bound)
_SCAN_BLOCK_ROWS = 8192  # sequential gather granularity (sample_flat)


@dataclasses.dataclass(frozen=True)
class PairEstimate:
    """Estimated result-pair count for one bucket pair at one ε."""

    est: float       # point estimate (sample hit fraction × population)
    lo: float        # Wilson lower bound at the estimator's z
    hi: float        # Wilson upper bound — what capacities are sized from
    sampled: int     # sample pairs examined
    hits: int        # sample pairs within ε
    population: int  # full pair population the fraction scales to


def _wilson_bounds(k: np.ndarray, m: np.ndarray, z: float
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized Wilson score interval for k successes in m trials.

    Degenerate m == 0 (a 1-row bucket's intra edge) reports [0, 1]: the
    sketch carries no pair evidence, so the bound stays maximally loose.
    """
    m = m.astype(np.float64)
    k = k.astype(np.float64)
    safe = np.maximum(m, 1.0)
    z2 = z * z
    center = (k + z2 / 2.0) / (safe + z2)
    half = (z / (safe + z2)) * np.sqrt(k * (safe - k) / safe + z2 / 4.0)
    lo = np.clip(center - half, 0.0, 1.0)
    hi = np.clip(center + half, 0.0, 1.0)
    empty = m <= 0
    lo[empty] = 0.0
    hi[empty] = 1.0
    return lo, hi


class CardinalityEstimator:
    """Per-bucket sample sketches → per-edge join-size estimates.

    ``samples``: (B, s, d) float32 — up to ``s`` uniformly sampled member
    vectors per bucket, zero-padded past ``rows[b]``; ``rows``: (B,) live
    sample counts; ``sizes``: (B,) true bucket populations.
    """

    def __init__(self, samples: np.ndarray, rows: np.ndarray,
                 sizes: np.ndarray, *, seed: int = 0, z: float = DEFAULT_Z):
        self.samples = np.ascontiguousarray(samples, np.float32)
        self.rows = np.asarray(rows, np.int64)
        self.sizes = np.asarray(sizes, np.int64)
        self.seed = int(seed)
        self.z = float(z)
        if self.samples.ndim != 3:
            raise ValueError(f"samples must be (B, s, d), "
                             f"got {self.samples.shape}")
        if not (len(self.rows) == len(self.sizes)
                == self.samples.shape[0]):
            raise ValueError("samples/rows/sizes bucket counts disagree")
        # norm sketch: ‖x‖² per sample row, so a distance evaluation is
        # one dot product (d² = ‖a‖² − 2a·b + ‖b‖²)
        self._norms = np.einsum("bsd,bsd->bs", self.samples,
                                self.samples).astype(np.float32)

    # -- construction ---------------------------------------------------------
    @classmethod
    def sample_flat(cls, store, assignment: np.ndarray, num_buckets: int,
                    *, sample_rows: int = DEFAULT_SAMPLE_ROWS,
                    seed: int = 0, z: float = DEFAULT_Z
                    ) -> "CardinalityEstimator":
        """Build from the flat dataset + its (final) bucket assignment —
        the build-time path. The ≤ B·s sampled rows are gathered with one
        sequential block scan (a per-row gather would charge a full page
        per ~100-byte row and wreck the join's Fig. 16 read-amplification
        accounting); it rides the same block-granular discipline as
        bucketize's three scans and stops at the last sampled row."""
        assignment = np.asarray(assignment, np.int64)
        sizes = np.bincount(assignment,
                            minlength=num_buckets).astype(np.int64)
        order = np.argsort(assignment, kind="stable")
        bounds = np.concatenate([[0], np.cumsum(sizes)])
        rng = np.random.default_rng(seed)
        rows = np.minimum(sizes, sample_rows).astype(np.int64)
        picks: list[np.ndarray] = []
        for b in range(num_buckets):
            members = order[bounds[b]:bounds[b + 1]]
            if rows[b] == len(members):
                picks.append(members)
            else:
                picks.append(rng.choice(members, size=int(rows[b]),
                                        replace=False))
        flat_ids = np.concatenate(picks) if picks else np.zeros(0, np.int64)
        sorted_ids = np.sort(flat_ids)
        vecs = np.zeros((len(sorted_ids), store.dim), np.float32)
        ptr = 0
        if sorted_ids.size:
            for start, block in store.iter_blocks(_SCAN_BLOCK_ROWS):
                end = start + block.shape[0]
                hi = int(np.searchsorted(sorted_ids, end))
                if hi > ptr:
                    vecs[ptr:hi] = block[sorted_ids[ptr:hi] - start]
                    ptr = hi
                if ptr >= sorted_ids.size:
                    break
        by_id = dict(zip(sorted_ids.tolist(), range(len(sorted_ids))))
        samples = np.zeros((num_buckets, sample_rows, store.dim),
                           np.float32)
        for b in range(num_buckets):
            for i, vid in enumerate(picks[b]):
                samples[b, i] = vecs[by_id[int(vid)]]
        return cls(samples, rows, sizes, seed=seed, z=z)

    @classmethod
    def sample_bucketed(cls, store, sizes: np.ndarray, *,
                        sample_rows: int = DEFAULT_SAMPLE_ROWS,
                        seed: int = 0, z: float = DEFAULT_Z
                        ) -> "CardinalityEstimator":
        """Rebuild from an already-bucketed store (lazy back-compat path
        for indexes built before sketches existed): one read per bucket.
        Emulated SSD latency is suspended for the pass — sketch rebuild is
        index maintenance, not part of any modeled workload."""
        sizes = np.asarray(sizes, np.int64)
        num_buckets = len(sizes)
        rng = np.random.default_rng(seed)
        rows = np.minimum(sizes, sample_rows).astype(np.int64)
        samples = np.zeros((num_buckets, sample_rows, store.dim),
                           np.float32)
        old_latency = getattr(store, "read_latency_s", None)
        if old_latency is not None:
            store.read_latency_s = 0.0
        try:
            for b in range(num_buckets):
                vecs, _ = store.read_bucket(b)
                if rows[b] == vecs.shape[0]:
                    sel = np.arange(int(rows[b]))
                else:
                    sel = rng.choice(vecs.shape[0], size=int(rows[b]),
                                     replace=False)
                samples[b, :rows[b]] = vecs[sel]
        finally:
            if old_latency is not None:
                store.read_latency_s = old_latency
        return cls(samples, rows, sizes, seed=seed, z=z)

    # -- persistence -----------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez(path, version=SKETCH_VERSION, samples=self.samples,
                 rows=self.rows, sizes=self.sizes, seed=self.seed)

    @classmethod
    def load(cls, path: str, *, z: float = DEFAULT_Z
             ) -> "CardinalityEstimator":
        with np.load(path) as f:
            if int(f["version"]) != SKETCH_VERSION:
                raise ValueError(f"{path}: sketch version {int(f['version'])}"
                                 f" != {SKETCH_VERSION}")
            return cls(f["samples"], f["rows"], f["sizes"],
                       seed=int(f["seed"]), z=z)

    @property
    def num_buckets(self) -> int:
        return self.samples.shape[0]

    @property
    def sample_rows(self) -> int:
        return self.samples.shape[1]

    # -- estimation -------------------------------------------------------------
    def est_pairs(self, edge: tuple[int, int], epsilon: float
                  ) -> PairEstimate:
        """Result-pair estimate for one bucket pair (u == v ⇒ the bucket's
        intra self-join, counting unordered pairs)."""
        u, v = int(edge[0]), int(edge[1])
        edges = np.array([[u, v]], np.int64)
        intra = np.array([u == v])
        est, lo, hi, k, m, pop = self._est_edges_full(edges, epsilon, intra)
        return PairEstimate(est=float(est[0]), lo=float(lo[0]),
                            hi=float(hi[0]), sampled=int(m[0]),
                            hits=int(k[0]), population=int(pop[0]))

    def est_edges(self, edges: np.ndarray, epsilon: float,
                  intra: np.ndarray | None = None
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized ``est_pairs`` over (E, 2) edges → (est, lo, hi).

        ``intra`` marks edges whose two endpoints are the same bucket
        (inferred from u == v when omitted): those count unordered member
        pairs, matching the executor's strictly-upper intra verify.
        """
        est, lo, hi, _, _, _ = self._est_edges_full(edges, epsilon, intra)
        return est, lo, hi

    def _est_edges_full(self, edges: np.ndarray, epsilon: float,
                        intra: np.ndarray | None):
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        E = edges.shape[0]
        if intra is None:
            intra = edges[:, 0] == edges[:, 1]
        intra = np.asarray(intra, bool)
        eps2 = np.float32(float(epsilon) * float(epsilon))
        s = self.sample_rows
        k = np.zeros(E, np.int64)
        for lo_i in range(0, E, _EDGE_CHUNK):
            sl = slice(lo_i, min(lo_i + _EDGE_CHUNK, E))
            u, v = edges[sl, 0], edges[sl, 1]
            su, sv = self.samples[u], self.samples[v]
            d2 = (self._norms[u][:, :, None]
                  - 2.0 * np.einsum("esd,etd->est", su, sv)
                  + self._norms[v][:, None, :])
            m = d2 <= eps2
            r = np.arange(s)
            live = ((r[None, :, None] < self.rows[u][:, None, None])
                    & (r[None, None, :] < self.rows[v][:, None, None]))
            tri = (~intra[sl, None, None]
                   | (r[None, :, None] < r[None, None, :]))
            k[sl] = (m & live & tri).sum((1, 2))
        ru, rv = self.rows[edges[:, 0]], self.rows[edges[:, 1]]
        nu, nv = self.sizes[edges[:, 0]], self.sizes[edges[:, 1]]
        m_pairs = np.where(intra, ru * (ru - 1) // 2, ru * rv)
        pop = np.where(intra, nu * (nu - 1) // 2, nu * nv)
        frac = k / np.maximum(m_pairs, 1)
        est = frac * pop
        lo_p, hi_p = _wilson_bounds(k, m_pairs, self.z)
        return est, lo_p * pop, hi_p * pop, k, m_pairs, pop

    def est_queries(self, Q: np.ndarray, per_q: list[np.ndarray],
                    epsilon: float
                    ) -> tuple[np.ndarray, np.ndarray, dict[int, float]]:
        """ε-range result-size estimates for a query wave.

        ``per_q``: per-query candidate-bucket id lists (the output of
        ``DiskJoinIndex.plan_probes``). Returns (per-query est, per-query
        hi, per-bucket hi) where the per-bucket figure is the upper bound
        on the *total* pairs one bucket's verify emits across every member
        query that probes it — exactly the quantity the device query
        path's ``k_cap`` must bound.
        """
        Q = np.asarray(Q, np.float32)
        eps2 = np.float32(float(epsilon) * float(epsilon))
        n = Q.shape[0]
        est_q = np.zeros(n)
        hi_q = np.zeros(n)
        probe: dict[int, list[int]] = {}
        for qi, ids in enumerate(per_q):
            for b in ids:
                probe.setdefault(int(b), []).append(qi)
        bucket_hi: dict[int, float] = {}
        for b, qis in probe.items():
            sb = self.samples[b][:self.rows[b]]         # (r, d)
            if sb.shape[0] == 0:
                bucket_hi[b] = float(self.sizes[b]) * len(qis)
                continue
            qs = Q[qis]                                  # (k, d)
            d2 = ((qs * qs).sum(1)[:, None]
                  - 2.0 * (qs @ sb.T)
                  + self._norms[b][None, :self.rows[b]])
            hits = (d2 <= eps2).sum(1)
            m = np.full(len(qis), int(self.rows[b]))
            lo_p, hi_p = _wilson_bounds(hits, m, self.z)
            scale = float(self.sizes[b])
            est_q[qis] += hits / m * scale
            hi_q[qis] += hi_p * scale
            bucket_hi[b] = float(hi_p.sum() * scale)
        return est_q, hi_q, bucket_hi
