"""Cost-based planner: estimates + cost model → typed, explainable plans.

The planner turns the pile of hand-tuned knobs the execution layers used
to hard-code into derived decisions:

``JoinPlan`` (one per batch join)
    * **pair_cap** — the device engine's per-edge compaction capacity,
      sized from the estimator's calibrated *upper bound* over every
      verify unit (instead of the fixed ``PAIR_CAP_INIT``). Overflow
      re-dispatch survives as a counted fallback for the estimate's
      tail, not the steady state.
    * **verify_batch per schedule region** — dense regions (many
      predicted pairs per edge) flush in small batches to bound the
      result working set; sparse regions batch wide to amortize dispatch.
    * **host/device route per verify unit** — modeled cost of staging +
      cells + readback on each path, using the cache schedule's hit/miss
      outcomes for per-edge transfer freshness.

``WavePlan`` (one per serving wave)
    k_cap for the device query path, host/device choice, and the
    predicted wave seconds the scheduler's estimate-based admission
    compares against request deadlines.

``PoolPlan`` (one per session pool sizing)
    The split of the ``BufferPool`` slab budget between the join working
    set and the serving warm cache, from observed probe reuse.

Every decision is recorded three ways: a ``Decision`` row rendered by
``explain()`` (inputs → choice → reason), a tracer instant
(``plan.join`` / ``plan.wave`` / ``plan.pool``), and counters/gauges on
the session ``PipelineStats``/``MetricsRegistry``. The planner only
sizes and places work — the result pair set is invariant under every
choice it makes (asserted by the planner-on/off byte-parity tests).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.plan.cost_model import CostModel
from repro.plan.estimator import CardinalityEstimator

PAIR_CAP_FLOOR = 64          # never plan below this compaction capacity
PAIR_CAP_MARGIN = 1.5        # headroom multiplier on the estimate hi bound
REGION_UNITS = 32            # verify units per batching region
FLUSH_PAIRS_BUDGET = 1 << 16  # target result pairs in flight per flush
K_CAP_FLOOR = 256            # query-path compaction floor (matches legacy)


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class Decision:
    """One explainable planner choice: inputs → choice, with the reason."""

    name: str
    choice: object
    reason: str
    inputs: dict = dataclasses.field(default_factory=dict)

    def render(self) -> str:
        ins = ", ".join(f"{k}={v}" for k, v in self.inputs.items())
        return f"{self.name:<14} = {self.choice!s:<18} <- {self.reason}" \
               + (f"  [{ins}]" if ins else "")


def _render(title: str, decisions: list[Decision]) -> str:
    return "\n".join([title] + ["  " + d.render() for d in decisions])


@dataclasses.dataclass
class JoinPlan:
    """Planner output for one batch join, consumed by the executor.

    ``unit_params`` holds one (route, verify_batch) per verify unit in
    the exact order the executor enqueues them (touch-intra units
    included for self-joins), so consumption is a single cursor walk.
    """

    epsilon: float
    num_units: int
    est_total: float
    hi_total: float
    pair_cap: int
    compute_mode: str                 # "host" | "device" | "mixed"
    unit_params: list                 # [(route, batch)] in enqueue order
    decisions: list = dataclasses.field(default_factory=list)

    @property
    def mixed(self) -> bool:
        return self.compute_mode == "mixed"

    def explain(self) -> str:
        routes = {}
        for r, _ in self.unit_params:
            routes[r] = routes.get(r, 0) + 1
        head = (f"JoinPlan(eps={self.epsilon:g}, units={self.num_units}, "
                f"est_pairs={self.est_total:.3g} "
                f"[hi {self.hi_total:.3g}], routes={routes})")
        return _render(head, self.decisions)


@dataclasses.dataclass
class WavePlan:
    """Planner output for one serving wave / admission probe."""

    epsilon: float
    num_queries: int
    num_buckets: int
    cold_reads: int
    est_pairs: float
    hi_pairs: float
    k_cap: int
    compute_mode: str                 # resolved: "host" | "device"
    predicted_s: float
    decisions: list = dataclasses.field(default_factory=list)

    def explain(self) -> str:
        head = (f"WavePlan(eps={self.epsilon:g}, "
                f"queries={self.num_queries}, "
                f"buckets={self.num_buckets}, "
                f"cold_reads={self.cold_reads}, "
                f"est_pairs={self.est_pairs:.3g} [hi {self.hi_pairs:.3g}], "
                f"predicted={self.predicted_s * 1e3:.2f} ms)")
        return _render(head, self.decisions)


@dataclasses.dataclass
class PoolPlan:
    """Slab-budget split between join working set and serving warm cache."""

    num_slabs: int
    warm_quota: int
    decisions: list = dataclasses.field(default_factory=list)

    def explain(self) -> str:
        head = (f"PoolPlan(slabs={self.num_slabs}, "
                f"warm_quota={self.warm_quota})")
        return _render(head, self.decisions)


class Planner:
    """Binds a ``CardinalityEstimator`` + ``CostModel`` to one session."""

    def __init__(self, estimator: CardinalityEstimator,
                 cost_model: CostModel, *, tracer=None, metrics=None,
                 pstats=None, pair_cap_margin: float = PAIR_CAP_MARGIN,
                 region_units: int = REGION_UNITS,
                 flush_pairs_budget: int = FLUSH_PAIRS_BUDGET):
        self.estimator = estimator
        self.cost = cost_model
        self.tracer = tracer
        self.metrics = metrics
        self.pstats = pstats
        self.pair_cap_margin = float(pair_cap_margin)
        self.region_units = int(region_units)
        self.flush_pairs_budget = int(flush_pairs_budget)

    # -- shared helpers ----------------------------------------------------------
    def _instant(self, name: str, **args) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, **args)

    def _count(self, stat: str, metric: str) -> None:
        if self.pstats is not None:
            self.pstats.add(stat, 1)
        if self.metrics is not None:
            self.metrics.counter(metric).inc()

    # -- batch-join planning --------------------------------------------------------
    def plan_join(self, tasks, actions, meta, config, bucket_capacity: int,
                  *, intra_join: bool = True) -> JoinPlan:
        """Plan one batch join from the executor's task walk.

        ``tasks``/``actions`` are ``JoinExecutor.plan()``'s edge schedule
        and cache-schedule actions — walking them together replays the
        executor's exact access pattern, so the plan knows both the
        verify-unit order (for cursor-based consumption) and each
        access's hit/miss outcome (for per-edge transfer freshness).
        """
        cap = int(bucket_capacity)
        dim = self.estimator.samples.shape[2]
        sizes = meta.sizes
        units: list[tuple[int, int, bool]] = []   # (u, v, intra)
        fresh: list[int] = []                     # cold accesses per unit
        ai = 0
        for task in tasks:
            if task[0] == "touch":
                b = int(task[1])
                miss = 0 if actions[ai][1] else 1
                ai += 1
                if intra_join and sizes[b] >= 2:
                    units.append((b, b, True))
                    fresh.append(miss)
            else:
                _, u, v = task
                miss = ((0 if actions[ai][1] else 1)
                        + (0 if actions[ai + 1][1] else 1))
                ai += 2
                units.append((int(u), int(v), False))
                fresh.append(miss)
        decisions: list[Decision] = []
        if not units:
            plan = JoinPlan(epsilon=float(config.epsilon), num_units=0,
                            est_total=0.0, hi_total=0.0,
                            pair_cap=PAIR_CAP_FLOOR,
                            compute_mode=(config.compute_mode
                                          if config.compute_mode != "auto"
                                          else "host"),
                            unit_params=[], decisions=decisions)
            self._record_join(plan)
            return plan

        edges = np.array([(u, v) for u, v, _ in units], np.int64)
        intra = np.array([i for _, _, i in units], bool)
        est, _, hi = self.estimator.est_edges(edges, config.epsilon,
                                              intra)
        est_total, hi_total = float(est.sum()), float(hi.sum())

        # --- pair_cap: bound the densest verify unit, with headroom ---
        cap2 = cap * cap
        densest = float(hi.max())
        pair_cap = _next_pow2(int(math.ceil(
            max(PAIR_CAP_FLOOR, densest * self.pair_cap_margin))))
        pair_cap = min(pair_cap, cap2)
        decisions.append(Decision(
            "pair_cap", pair_cap,
            f"densest unit hi {densest:.3g} x margin "
            f"{self.pair_cap_margin:g}, pow2, clamp "
            f"[{PAIR_CAP_FLOOR}, cap^2={cap2}]",
            {"units": len(units), "hi_total": f"{hi_total:.3g}"}))

        # --- verify_batch per schedule region ---
        batches = np.empty(len(units), np.int64)
        region_sizes: list[int] = []
        for lo in range(0, len(units), self.region_units):
            sl = slice(lo, min(lo + self.region_units, len(units)))
            density = float(hi[sl].mean())
            b = int(np.clip(self.flush_pairs_budget
                            / max(1.0, density), 1,
                            config.verify_batch))
            batches[sl] = b
            region_sizes.append(b)
        decisions.append(Decision(
            "verify_batch",
            f"{min(region_sizes)}..{max(region_sizes)}",
            f"flush budget {self.flush_pairs_budget} pairs / region "
            f"density, clamp [1, {config.verify_batch}]",
            {"regions": len(region_sizes)}))

        # --- host/device route per unit ---
        cells = np.where(intra,
                         sizes[edges[:, 0]] * (sizes[edges[:, 0]] - 1) / 2,
                         sizes[edges[:, 0]] * sizes[edges[:, 1]])
        if config.compute_mode in ("host", "device"):
            routes = [config.compute_mode] * len(units)
            decisions.append(Decision(
                "compute", config.compute_mode,
                "pinned by config.compute_mode"))
        else:  # "auto": per-unit modeled cost
            host_s = np.array([
                self.cost.host_edge_s(c, cap, dim, batch=int(b))
                for c, b in zip(cells, batches)])
            dev_s = np.array([
                self.cost.device_edge_s(c, h, cap, dim, fresh_slabs=f,
                                        batch=int(b))
                for c, h, f, b in zip(cells, hi, fresh, batches)])
            routes = ["device" if d < h else "host"
                      for d, h in zip(dev_s, host_s)]
            n_dev = routes.count("device")
            decisions.append(Decision(
                "compute",
                ("device" if n_dev == len(units) else
                 "host" if n_dev == 0 else "mixed"),
                f"modeled host {host_s.sum():.3g}s vs device "
                f"{dev_s.sum():.3g}s per unit ({self.cost.describe()})",
                {"host_units": len(units) - n_dev,
                 "device_units": n_dev}))
        n_dev = routes.count("device")
        mode = ("device" if n_dev == len(units)
                else "host" if n_dev == 0 else "mixed")
        plan = JoinPlan(
            epsilon=float(config.epsilon), num_units=len(units),
            est_total=est_total, hi_total=hi_total, pair_cap=pair_cap,
            compute_mode=mode,
            unit_params=list(zip(routes, (int(b) for b in batches))),
            decisions=decisions)
        self._record_join(plan)
        return plan

    def _record_join(self, plan: JoinPlan) -> None:
        self._count("plans", "plan.joins")
        self._instant("plan.join", units=plan.num_units,
                      pair_cap=plan.pair_cap, compute=plan.compute_mode,
                      est_pairs=round(plan.est_total, 1),
                      hi_pairs=round(plan.hi_total, 1))
        if self.metrics is not None:
            self.metrics.gauge("plan.pair_cap").set(plan.pair_cap)
        if self.pstats is not None:
            with self.pstats._lock:
                self.pstats.planned_pair_cap = plan.pair_cap

    # -- serving-wave planning ---------------------------------------------------------
    def plan_wave(self, Q: np.ndarray, per_q: list, meta, config,
                  bucket_capacity: int, warm: set | None = None
                  ) -> WavePlan:
        """Plan one serving wave (also the admission cost probe).

        ``per_q``: per-query candidate-bucket lists from ``plan_probes``;
        ``warm``: bucket ids already resident in the session pool (their
        reads are free)."""
        warm = warm or set()
        cap = int(bucket_capacity)
        dim = Q.shape[1]
        est_q, hi_q, bucket_hi = self.estimator.est_queries(
            Q, per_q, config.epsilon)
        buckets = sorted(bucket_hi)
        cold = [b for b in buckets if b not in warm]
        decisions: list[Decision] = []
        sizes = meta.sizes
        cells = float(sum(int(sizes[b]) * sum(1 for ids in per_q
                                              if b in set(np.asarray(ids)))
                          for b in buckets))
        densest = max(bucket_hi.values(), default=0.0)
        k_cap = min(_next_pow2(int(math.ceil(
            max(K_CAP_FLOOR, densest * self.pair_cap_margin)))),
            cap * max(1, len(Q)))
        decisions.append(Decision(
            "k_cap", k_cap,
            f"densest bucket hi {densest:.3g} x margin "
            f"{self.pair_cap_margin:g}, pow2, floor {K_CAP_FLOOR}"))
        hi_total = float(hi_q.sum())
        if config.compute_mode in ("host", "device"):
            mode = config.compute_mode
            decisions.append(Decision(
                "compute", mode, "pinned by config.compute_mode"))
            verify_s = (self.cost.host_query_s(cells) if mode == "host"
                        else self.cost.device_query_s(
                            cells, hi_total, len(Q), cap, dim,
                            len(cold)))
        else:
            host_s = self.cost.host_query_s(cells)
            dev_s = self.cost.device_query_s(cells, hi_total, len(Q),
                                             cap, dim, len(cold))
            mode = "device" if dev_s < host_s else "host"
            verify_s = min(host_s, dev_s)
            decisions.append(Decision(
                "compute", mode,
                f"modeled host {host_s:.3g}s vs device {dev_s:.3g}s "
                f"({self.cost.describe()})"))
        read_s = self.cost.read_s(len(cold))
        predicted = read_s + verify_s
        decisions.append(Decision(
            "predicted_s", f"{predicted:.4g}",
            f"reads {len(cold)} x "
            f"{self.cost.read_s_per_bucket * 1e3:.3g} ms + verify "
            f"{verify_s:.3g}s over {cells:.3g} cells"))
        plan = WavePlan(
            epsilon=float(config.epsilon), num_queries=len(Q),
            num_buckets=len(buckets), cold_reads=len(cold),
            est_pairs=float(est_q.sum()), hi_pairs=hi_total,
            k_cap=int(k_cap), compute_mode=mode,
            predicted_s=float(predicted), decisions=decisions)
        self._count("wave_plans", "plan.waves")
        self._instant("plan.wave", queries=len(Q),
                      buckets=len(buckets), cold_reads=len(cold),
                      k_cap=int(k_cap), compute=mode,
                      predicted_ms=round(predicted * 1e3, 3))
        return plan

    # -- pool-budget planning -----------------------------------------------------------
    def plan_pool(self, config, cap_buckets: int, lookahead: int,
                  stats: dict | None, *, floor: int = 2,
                  ceiling: int | None = None) -> PoolPlan:
        """Split the session slab budget between the join working set
        (cache capacity + prefetch lookahead) and the serving warm cache.

        The warm quota is the predicted per-wave bucket reuse: the mean
        distinct buckets probed per wave (or per point query) observed so
        far — keeping that many slabs warm lets the *next* wave's probes
        hit without reads. With no query traffic yet the quota stays at
        the legacy reserve (``floor``)."""
        stats = stats or {}
        waves = stats.get("waves", 0)
        queries = stats.get("queries", 0)
        if waves > 0:
            reuse = stats.get("shared_probe_reads", 0) / waves
            basis = f"{reuse:.2f} distinct buckets/wave over {waves} waves"
        elif queries > 0:
            reuse = ((stats.get("query_reads", 0)
                      + stats.get("query_warm_hits", 0)) / queries)
            basis = f"{reuse:.2f} probes/query over {queries} queries"
        else:
            reuse = float(floor)
            basis = "no query traffic yet (legacy reserve)"
        quota = int(np.clip(math.ceil(reuse), floor,
                            ceiling if ceiling is not None
                            else max(floor, cap_buckets)))
        num_slabs = cap_buckets + lookahead + quota
        decisions = [
            Decision("warm_quota", quota, f"predicted reuse: {basis}"),
            Decision("num_slabs", num_slabs,
                     f"join working set {cap_buckets} + lookahead "
                     f"{lookahead} + warm {quota}"),
        ]
        plan = PoolPlan(num_slabs=num_slabs, warm_quota=quota,
                        decisions=decisions)
        self._instant("plan.pool", num_slabs=num_slabs, warm_quota=quota)
        if self.metrics is not None:
            self.metrics.gauge("plan.warm_quota").set(quota)
        return plan


# -- replica-aware service prediction -----------------------------------------

def predict_replica_service_s(request_s: float, queue_depth: int, *,
                              observed_s: float | None = None) -> float:
    """Predicted time for a NEW request to clear a replica: its own
    service (``request_s`` — a ``WavePlan.predicted_s`` for a
    single-request wave, or an observed per-request EWMA) plus the
    backlog already queued ahead of it, drained at the observed rate
    when one is available.

    This is the scoring function behind ``serve.replica.ReplicaSet``'s
    ``least_loaded`` policy: with equal replicas it reduces to queue
    depth; a replica whose live-calibrated costs have drifted up (a
    browned-out SSD raises its ``read_s_per_bucket``, so its
    ``predicted_s`` rises) is avoided even at equal depth.
    """
    per_request = observed_s if observed_s and observed_s > 0 \
        else float(request_s)
    return float(request_s) + max(0, int(queue_depth)) * per_request
