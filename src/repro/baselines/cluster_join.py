"""ClusterJoin baseline (Das Sarma et al., VLDB'14) — single-node version.

Pivot-based partitioning with the bisector replication filter: each vector
goes to its nearest pivot's *home* partition, and is additionally replicated
to any partition whose bisector it is within ε/2 of — guaranteeing every
ε-pair co-locates in ≥1 partition (exact join). Verification is all-pairs
within each partition. The paper implements it in-memory for fairness; so do
we. Distance-computation counts grow near-quadratically with N (Fig. 7's
separation vs DiskJoin).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import canonicalize_pairs


def cluster_join(x: np.ndarray, epsilon: float, num_pivots: int | None = None,
                 seed: int = 0, verify_block: int = 4096):
    """Exact SSJ → (pairs (P,2) int64, #distance computations)."""
    n, d = x.shape
    num_pivots = num_pivots or max(2, int(np.sqrt(n) / 2))
    rng = np.random.default_rng(seed)
    pivots = x[rng.choice(n, size=num_pivots, replace=False)].astype(np.float64)
    xf = x.astype(np.float64)

    # distances to pivots (blocked)
    dc = n * num_pivots
    home = np.empty(n, dtype=np.int64)
    members: list[list[int]] = [[] for _ in range(num_pivots)]
    psq = np.sum(pivots ** 2, axis=1)
    for i0 in range(0, n, verify_block):
        i1 = min(n, i0 + verify_block)
        dp = (np.sum(xf[i0:i1] ** 2, axis=1)[:, None]
              - 2.0 * xf[i0:i1] @ pivots.T + psq[None, :])
        dp = np.sqrt(np.maximum(dp, 0))
        h = np.argmin(dp, axis=1)
        home[i0:i1] = h
        # bisector filter: replicate x to partition p if
        # d(x, p) − d(x, home) ≤ 2ε  (⇒ x within ε of the bisector)
        dmin = dp[np.arange(i1 - i0), h]
        repl = dp <= (dmin[:, None] + 2.0 * epsilon)
        for r in range(i1 - i0):
            for p in np.flatnonzero(repl[r]):
                members[p].append(i0 + r)

    eps2 = epsilon * epsilon
    pairs = []
    for p in range(num_pivots):
        ids = np.asarray(members[p], dtype=np.int64)
        m = ids.size
        if m < 2:
            continue
        sub = xf[ids]
        sq = np.sum(sub ** 2, axis=1)
        d2 = sq[:, None] - 2.0 * sub @ sub.T + sq[None, :]
        dc += m * (m - 1) // 2
        rows, cols = np.nonzero(np.triu(d2 <= eps2, k=1))
        if rows.size:
            pairs.append(np.stack([ids[rows], ids[cols]], axis=1))
    out = (canonicalize_pairs(np.concatenate(pairs))
           if pairs else np.zeros((0, 2), np.int64))
    return out, dc
