"""DiskANN-style baseline: ε-join by per-vector search of a disk index.

Faithful to the paper's baseline setup (§1, §6.1):
  * proximity graph over the dataset; full-precision vectors live on disk
    and are fetched one vector at a time (≤ page granularity → read
    amplification, the Fig. 16 effect);
  * compressed vectors (int8 scalar quantization here, PQ in DiskANN) stay
    in memory and steer the beam search; disk fetches rerank exactly;
  * every vector is issued as a query; neighbors within ε are collected,
    growing the beam until the frontier exceeds ε (the paper's "increase k
    until the distances exceed ε").

The point of this module is the *cost profile* (disk traffic, repeated
accesses), not index-construction fidelity — construction uses exact
blocked kNN (fine at validation scale) plus long-range shortcuts.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import canonicalize_pairs
from repro.store.vector_store import FlatVectorStore


@dataclasses.dataclass
class DiskANNIndex:
    graph: np.ndarray          # (N, R) int64 out-neighbors
    compressed: np.ndarray     # (N, d) int8 in-memory approximations
    scale: np.ndarray          # (d,) dequant scales
    medoid: int

    @property
    def degree(self) -> int:
        return self.graph.shape[1]


def build_index(x: np.ndarray, degree: int = 16, shortcut_frac: float = 0.25,
                seed: int = 0, block: int = 2048) -> DiskANNIndex:
    """Exact-kNN graph + random shortcuts (Vamana-flavoured, small-scale)."""
    n, d = x.shape
    rng = np.random.default_rng(seed)
    k_near = max(1, int(degree * (1 - shortcut_frac)))
    nbrs = np.empty((n, degree), dtype=np.int64)
    sq = np.sum(x.astype(np.float64) ** 2, axis=1)
    for i0 in range(0, n, block):
        i1 = min(n, i0 + block)
        d2 = sq[i0:i1, None] - 2.0 * x[i0:i1] @ x.T + sq[None, :]
        idx = np.argpartition(d2, k_near + 1, axis=1)[:, :k_near + 1]
        for r, i in enumerate(range(i0, i1)):
            cand = [j for j in idx[r] if j != i][:k_near]
            short = rng.choice(n, size=degree - len(cand), replace=False)
            nbrs[i] = np.concatenate([cand, short])[:degree]
    # int8 scalar quantization (in-memory footprint = N·d bytes = 25% of f32)
    scale = np.maximum(np.abs(x).max(axis=0), 1e-12) / 127.0
    compressed = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    medoid = int(np.argmin(np.sum((x - x.mean(0)) ** 2, axis=1)))
    return DiskANNIndex(nbrs, compressed, scale.astype(np.float32), medoid)


def _approx_dist2(index: DiskANNIndex, q: np.ndarray,
                  ids: np.ndarray) -> np.ndarray:
    approx = index.compressed[ids].astype(np.float32) * index.scale
    diff = approx - q[None, :]
    return np.sum(diff * diff, axis=1)


def search_eps(index: DiskANNIndex, store: FlatVectorStore, q: np.ndarray,
               epsilon: float, beam: int = 32, max_hops: int = 512,
               start: int | None = None) -> tuple[np.ndarray, int]:
    """Greedy beam search; full-precision rerank via per-vector disk reads.

    Returns (ids within ε of q, #distance computations). ``start`` seeds the
    search (for a join the query is a dataset node — start there; ad-hoc
    queries start at the medoid).
    """
    eps2 = epsilon * epsilon
    visited: set[int] = set()
    frontier = [index.medoid if start is None else int(start)]
    results: list[int] = []
    dc = 0
    best: list[tuple[float, int]] = []
    hops = 0
    while frontier and hops < max_hops:
        hops += 1
        cand = np.asarray([c for c in frontier if c not in visited])
        if cand.size == 0:
            break
        visited.update(int(c) for c in cand)
        # full-precision rerank — one random disk read per candidate
        full = store.read_rows(cand)
        d2 = np.sum((full - q[None, :]) ** 2, axis=1)
        dc += len(cand)
        for c, dd in zip(cand, d2):
            if dd <= eps2:
                results.append(int(c))
            best.append((float(dd), int(c)))
        best.sort()
        best = best[:beam]
        # expand: neighbors of the beam, steered by compressed distances
        expand = np.unique(index.graph[[b for _, b in best]].ravel())
        expand = np.asarray([e for e in expand if e not in visited])
        if expand.size == 0:
            break
        ad2 = _approx_dist2(index, q, expand)
        dc += len(expand)
        order = np.argsort(ad2)
        keep = expand[order][:beam]
        # beam termination: stop when the whole frontier is beyond ε and
        # the best beam entry is also beyond ε (paper's growing-k stop)
        if best and best[0][0] > eps2 and ad2[order[0]] > 4 * eps2:
            break
        frontier = [int(kk) for kk in keep]
    return np.asarray(sorted(set(results)), dtype=np.int64), dc


def diskann_join(store: FlatVectorStore, x: np.ndarray, epsilon: float,
                 beam: int = 32, sample_queries: np.ndarray | None = None):
    """Join by searching every vector (or a sample, as the paper does for
    time estimation). Returns (pairs, #distance computations)."""
    index = build_index(x)
    queries = (np.arange(x.shape[0]) if sample_queries is None
               else sample_queries)
    pairs = []
    dc = 0
    for qid in queries:
        ids, c = search_eps(index, store, x[qid], epsilon, beam=beam,
                            start=int(qid))
        dc += c
        for j in ids:
            if j != qid:
                pairs.append((min(qid, j), max(qid, j)))
    out = (canonicalize_pairs(np.asarray(pairs, dtype=np.int64))
           if pairs else np.zeros((0, 2), np.int64))
    return out, dc
