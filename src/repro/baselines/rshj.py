"""RSHJ-style baseline (Yu et al., TKDE'16) — LSH similarity join.

E2LSH-style hash family h(x) = ⌊(a·x + b)/w⌋ composed into K-wide signatures
across T tables; candidate pairs are vectors sharing a signature in any
table; verification is exact. Approximate — recall depends on (K, T, w).

Memory behaviour mirrors the paper's observation: candidate sets blow up
roughly quadratically in dense regions (RSHJ "fails to run at 1M/10M" in
Fig. 7); ``max_candidates`` raises MemoryError beyond the budget to emulate
that failure mode honestly rather than thrash.
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from repro.core.types import canonicalize_pairs


def rshj_join(x: np.ndarray, epsilon: float, tables: int = 8, k: int = 4,
              width_mult: float = 2.0, seed: int = 0,
              max_candidates: int | None = 50_000_000):
    """→ (pairs, #distance computations). Raises MemoryError on blow-up."""
    n, d = x.shape
    rng = np.random.default_rng(seed)
    w = width_mult * epsilon
    eps2 = epsilon * epsilon
    xf = x.astype(np.float64)

    cand: set[tuple[int, int]] = set()
    dc = 0
    for t in range(tables):
        a = rng.normal(size=(d, k))
        b = rng.uniform(0, w, size=k)
        sig = np.floor((xf @ a + b) / w).astype(np.int64)
        buckets: defaultdict[bytes, list[int]] = defaultdict(list)
        for i in range(n):
            buckets[sig[i].tobytes()].append(i)
        for ids in buckets.values():
            m = len(ids)
            if m < 2:
                continue
            for ii in range(m):
                for jj in range(ii + 1, m):
                    cand.add((ids[ii], ids[jj]))
            if max_candidates and len(cand) > max_candidates:
                raise MemoryError(
                    f"RSHJ candidate set exceeded {max_candidates} pairs "
                    f"(table {t}/{tables}) — emulating the paper's OOM")
    pairs = []
    for i, j in cand:
        dd = xf[i] - xf[j]
        dc += 1
        if float(dd @ dd) <= eps2:
            pairs.append((i, j))
    out = (canonicalize_pairs(np.asarray(pairs, dtype=np.int64))
           if pairs else np.zeros((0, 2), np.int64))
    return out, dc
