"""Baselines the paper compares against (§6.1).

- ``diskann_join``  — search-per-vector over a disk-resident proximity
                      graph (DiskANN-style): the paper's Fig. 1 baseline.
- ``cluster_join``  — single-node ClusterJoin (pivot partitioning +
                      bisector replication filter), exact.
- ``rshj``          — LSH-based in-memory join (RSHJ-style), approximate.
"""
from repro.baselines.cluster_join import cluster_join
from repro.baselines.diskann_join import DiskANNIndex, diskann_join
from repro.baselines.rshj import rshj_join

__all__ = ["DiskANNIndex", "cluster_join", "diskann_join", "rshj_join"]
