"""DiskJoin one-shot API (paper §3 workflow) — DEPRECATED wrappers.

    similarity_self_join(store, config)  →  JoinResult
    similarity_cross_join(store_x, store_y, config) → JoinResult

Both are now thin shims over the build-once / query-many session API
(``repro.core.index.DiskJoinIndex``): they build a throwaway index in the
workdir, run exactly one join against it, fold the build time back into
the result's timings (legacy "bucketing included" schema) and close the
session. Every ε-sweep or repeated call through these functions
re-bucketizes from scratch — build a ``DiskJoinIndex`` once instead:

    index = DiskJoinIndex.build(store, config, workdir)
    index.self_join(epsilon=...)          # bucketization amortized
    index.cross_join(other_index, ...)
    index.query(q, epsilon=...)           # online point lookups

Each wrapper emits a ``DeprecationWarning`` once per process.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import warnings

from repro.core.bipartite import (CombinedBipartiteStore,
                                  CrossJoinExecutor)
from repro.core.index import DiskJoinIndex
from repro.core.types import JoinConfig, JoinResult
from repro.store.vector_store import FlatVectorStore

# kept importable under their pre-refactor private names
_CombinedBipartiteStore = CombinedBipartiteStore
_CrossJoinExecutor = CrossJoinExecutor

_deprecation_warned: set[str] = set()


def _warn_deprecated(name: str) -> None:
    if name in _deprecation_warned:
        return
    _deprecation_warned.add(name)
    warnings.warn(
        f"{name}() is deprecated: it rebuilds the bucketed layout on every "
        f"call. Build a DiskJoinIndex once and use index.self_join / "
        f"index.cross_join / index.query instead.",
        DeprecationWarning, stacklevel=3)


def similarity_self_join(store: FlatVectorStore, config: JoinConfig,
                         workdir: str | None = None,
                         attribute_mask=None,
                         io_mode: str | None = None) -> JoinResult:
    """SSJ over a flat on-disk dataset under a memory budget.

    Deprecated: equivalent to ``DiskJoinIndex.build(store, config,
    workdir).self_join(attribute_mask=...)`` with the build cost folded
    into ``timings`` — identical pair set, no reuse across calls.

    ``attribute_mask`` (paper §3 extension): (N,) bool predicate results;
    only pairs where both sides pass are verified/returned.

    ``io_mode`` overrides ``config.io_mode`` ("sync" | "prefetch") without
    rebuilding the config; the result pair set is identical either way.
    """
    _warn_deprecated("similarity_self_join")
    if io_mode is not None:
        config = dataclasses.replace(config, io_mode=io_mode)
    index = DiskJoinIndex.build(store, config, workdir)
    try:
        result = index.self_join(attribute_mask=attribute_mask)
        result.timings = index.merge_build_timings(result.timings)
        return result
    finally:
        index.close()


def similarity_cross_join(store_x: FlatVectorStore, store_y: FlatVectorStore,
                          config: JoinConfig, workdir: str | None = None,
                          reorder_larger: bool = True,
                          io_mode: str | None = None,
                          attribute_mask=None) -> JoinResult:
    """Cross-join (§3 extension): bipartite graph over two bucketings.

    Deprecated: equivalent to building one ``DiskJoinIndex`` per side and
    calling ``index_x.cross_join(index_y, ...)``.

    ``reorder_larger=True`` is the paper's DiskJoin1 (stream the larger
    dataset in schedule order, cache the smaller); False is DiskJoin2.
    ``io_mode`` overrides ``config.io_mode`` as in ``similarity_self_join``.
    ``attribute_mask``: (N_x + N_y,) bool over the combined id space (X
    ids first, Y ids offset by ``store_x.num_vectors``) — pairs survive
    only if both endpoints pass, exactly as in the self-join.

    Result ids: X in [0, n_x), Y offset by n_x. The two sides get a
    spatial-tour disk layout when coalescing/striping is on (the bipartite
    schedule is unknowable before both sides are bucketized).
    """
    _warn_deprecated("similarity_cross_join")
    if io_mode is not None:
        config = dataclasses.replace(config, io_mode=io_mode)
    workdir = workdir or tempfile.mkdtemp(prefix="diskjoin_x_")
    os.makedirs(workdir, exist_ok=True)
    index_x = DiskJoinIndex.build(store_x, config,
                                  os.path.join(workdir, "x"),
                                  layout="spatial")
    index_y = DiskJoinIndex.build(store_y, config,
                                  os.path.join(workdir, "y"),
                                  layout="spatial")
    try:
        result = index_x.cross_join(index_y, reorder_larger=reorder_larger,
                                    attribute_mask=attribute_mask)
        result.timings = index_x.merge_build_timings(
            index_y.merge_build_timings(result.timings))
        return result
    finally:
        index_x.close()
        index_y.close()
