"""DiskJoin top-level API (paper §3 workflow).

    similarity_self_join(store, config)  →  JoinResult
    similarity_cross_join(store_x, store_y, config) → JoinResult

Pipeline: bucketize → bucket graph (+ pruning) → orchestrate (Gorder +
Belady) → execute (kernel verify). Cross-join follows §3's recipe: bucketize
each dataset, bipartite bucket graph, reorder the *larger* side (streamed
once) and cache the smaller.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import numpy as np

from repro.core import ordering
from repro.core.bucket_graph import build_bucket_graph
from repro.core.bucketize import bucketize
from repro.core.center_index import make_center_index
from repro.core.executor import JoinExecutor
from repro.core.pruning import prune_candidates
from repro.core.types import (BucketGraph, BucketMeta, JoinConfig,
                              JoinResult, resolve_bucket_capacity,
                              resolve_cache_buckets)
from repro.store.vector_store import FlatVectorStore


def similarity_self_join(store: FlatVectorStore, config: JoinConfig,
                         workdir: str | None = None,
                         attribute_mask=None,
                         io_mode: str | None = None) -> JoinResult:
    """SSJ over a flat on-disk dataset under a memory budget.

    ``attribute_mask`` (paper §3 extension): (N,) bool predicate results;
    only pairs where both sides pass are verified/returned.

    ``io_mode`` overrides ``config.io_mode`` ("sync" | "prefetch") without
    rebuilding the config; the result pair set is identical either way.
    """
    if io_mode is not None:
        config = dataclasses.replace(config, io_mode=io_mode)
    workdir = workdir or tempfile.mkdtemp(prefix="diskjoin_")
    os.makedirs(workdir, exist_ok=True)
    timings: dict[str, float] = {}

    # disk-layout planning: when coalescing or striping is on, the write
    # scan needs the join's node order *before* it lays out extents — the
    # planner runs on the final bucket metadata, and its graph/order are
    # reused below so the schedule matches the layout by construction
    plan_cache: dict = {}

    def layout_fn(meta: BucketMeta):
        graph = build_bucket_graph(meta, config)
        cap = resolve_bucket_capacity(config, meta.sizes)
        cache_buckets = resolve_cache_buckets(config, cap, store.dim)
        order = ordering.compute_node_order(graph, meta, config,
                                            cache_buckets)
        plan_cache["graph"], plan_cache["order"] = graph, order
        return order

    wants_layout = config.io_coalesce or config.io_devices > 1
    t0 = time.perf_counter()
    bstore, meta, bt = bucketize(store, os.path.join(workdir, "buckets"),
                                 config,
                                 layout_order_fn=(layout_fn if wants_layout
                                                  else None))
    timings["bucketing"] = time.perf_counter() - t0
    timings.update({f"bucketing/{k}": v for k, v in bt.items()})

    t0 = time.perf_counter()
    graph = plan_cache.get("graph")
    if graph is None:
        graph = build_bucket_graph(meta, config)
    timings["graph"] = time.perf_counter() - t0

    executor = JoinExecutor(bstore, meta, config,
                            attribute_mask=attribute_mask)
    result = executor.run(graph, node_order=plan_cache.get("order"))
    result.timings.update(timings)
    # the layout pass did the graph build + ordering the executor reuses;
    # attribute it to orchestration (total and sub-key both) so phase
    # breakdowns stay comparable with non-layout configs
    layout_s = result.timings.pop("bucketing/layout_plan", 0.0)
    if layout_s:
        result.timings["orchestration/layout_plan"] = layout_s
    result.timings["bucketing"] -= layout_s
    result.timings["orchestration"] = (result.timings.pop("plan")
                                       + timings["graph"] + layout_s)
    return result


def similarity_cross_join(store_x: FlatVectorStore, store_y: FlatVectorStore,
                          config: JoinConfig, workdir: str | None = None,
                          reorder_larger: bool = True,
                          io_mode: str | None = None) -> JoinResult:
    """Cross-join (§3 extension): bipartite graph over two bucketings.

    ``reorder_larger=True`` is the paper's DiskJoin1 (stream the larger
    dataset in schedule order, cache the smaller); False is DiskJoin2.
    ``io_mode`` overrides ``config.io_mode`` as in ``similarity_self_join``.
    """
    if io_mode is not None:
        config = dataclasses.replace(config, io_mode=io_mode)
    workdir = workdir or tempfile.mkdtemp(prefix="diskjoin_x_")
    os.makedirs(workdir, exist_ok=True)

    big_first = store_x.num_vectors >= store_y.num_vectors
    if not reorder_larger:
        big_first = not big_first
    s_drive, s_cache = ((store_x, store_y) if big_first
                        else (store_y, store_x))
    drive_is_x = s_drive is store_x

    cfg_drive = config
    cfg_cache = config
    # the bipartite schedule isn't known until both sides are bucketized,
    # so exact schedule-order layout is impossible here; a per-side
    # spatial tour of centers approximates it (the executor's Gorder over
    # the bipartite graph follows metric locality), keeping coalescing
    # and phase striping useful on cross-joins too
    layout = ((lambda m: ordering.spatial_order(m.centers))
              if (config.io_coalesce or config.io_devices > 1) else None)
    t0 = time.perf_counter()
    bs_d, meta_d, _ = bucketize(s_drive, os.path.join(workdir, "drive"),
                                cfg_drive, layout_order_fn=layout)
    bs_c, meta_c, _ = bucketize(s_cache, os.path.join(workdir, "cache"),
                                cfg_cache, layout_order_fn=layout)
    bucketing_s = time.perf_counter() - t0

    # bipartite candidate graph: for each drive bucket, candidate cache
    # buckets by center search + Eq.1 + probabilistic pruning
    t0 = time.perf_counter()
    index = make_center_index(meta_c.centers)
    L = min(config.max_candidates, meta_c.num_buckets)
    d2, cand = index.search(meta_d.centers, L)
    dists = np.sqrt(np.maximum(d2, 0.0))
    eps = float(config.epsilon)
    dim = meta_d.centers.shape[1]
    pairs_bg: list[tuple[int, int]] = []
    for b in range(meta_d.num_buckets):
        ids, dd = cand[b], dists[b]
        ok = np.isfinite(dd)
        ids, dd = ids[ok], dd[ok]
        tri = dd - meta_d.radii[b] - meta_c.radii[ids] <= eps
        ids, dd = ids[tri], dd[tri]
        if config.prune and ids.size:
            keep = prune_candidates(dd, float(meta_d.radii[b]) + eps, dim,
                                    config.recall_target,
                                    cand_radii=meta_c.radii[ids])
            ids = ids[keep]
        for j in ids:
            pairs_bg.append((b, int(j)))
    graph_s = time.perf_counter() - t0

    # execute: drive buckets streamed in Gorder order; cache side managed by
    # Belady. We reuse the self-join executor over a *combined* store view by
    # offsetting cache-bucket ids. Result ids: X in [0, n_x), Y offset by n_x.
    n_x = store_x.num_vectors
    combined = _CombinedBipartiteStore(
        bs_d, bs_c,
        drive_id_offset=0 if drive_is_x else n_x,
        cache_id_offset=n_x if drive_is_x else 0)
    meta = BucketMeta(
        centers=np.concatenate([meta_d.centers, meta_c.centers]),
        radii=np.concatenate([meta_d.radii, meta_c.radii]),
        sizes=np.concatenate([meta_d.sizes, meta_c.sizes]),
    )
    off = meta_d.num_buckets
    edges = np.asarray([(i, off + j) for i, j in pairs_bg], dtype=np.int64)
    if edges.size == 0:
        edges = np.zeros((0, 2), dtype=np.int64)
    graph = BucketGraph(num_nodes=meta.num_buckets, edges=edges)

    executor = _CrossJoinExecutor(combined, meta, config)
    result = executor.run(graph)
    result.timings["bucketing"] = bucketing_s
    result.timings["orchestration"] = result.timings.pop("plan") + graph_s
    return result


class _CombinedBipartiteStore:
    """Unified bucket-id space over (drive ++ cache) bucketed stores.

    Vector ids are tagged per side (X ids stay < n_x; Y ids offset by n_x)
    so result pairs are unambiguous.
    """

    def __init__(self, drive, cache, drive_id_offset: int,
                 cache_id_offset: int):
        self.drive = drive
        self.cache = cache
        self.dim = drive.dim
        self.off = drive.num_buckets
        self._offs = (drive_id_offset, cache_id_offset)
        self.stats = drive.stats  # JoinExecutor snapshots this; we override
        self._live = (drive.stats, cache.stats)
        # device surface: the two sides are distinct backing stores, so
        # their device ids are disjoint; the prefetcher gets one queue per
        # underlying device across both
        self.num_devices = drive.num_devices + cache.num_devices

    def device_of(self, b: int) -> int:
        if b < self.off:
            return self.drive.device_of(b)
        return self.drive.num_devices + self.cache.device_of(b - self.off)

    def contiguous_after(self, a: int, b: int) -> bool:
        if a < self.off and b < self.off:
            return self.drive.contiguous_after(a, b)
        if a >= self.off and b >= self.off:
            return self.cache.contiguous_after(a - self.off, b - self.off)
        return False

    def read_run_into(self, buckets, out_vecs, out_ids,
                      pad_value: float = 0.0) -> list[int]:
        if buckets[0] < self.off:
            side, locs, off = (self.drive, list(buckets), self._offs[0])
        else:
            side = self.cache
            locs = [b - self.off for b in buckets]
            off = self._offs[1]
        ns = side.read_run_into(locs, out_vecs, out_ids,
                                pad_value=pad_value)
        for oi, n in zip(out_ids, ns):
            oi[:n] += off
        return ns

    def read_bucket(self, b: int):
        if b < self.off:
            vecs, ids = self.drive.read_bucket(b)
            return vecs, ids + self._offs[0]
        vecs, ids = self.cache.read_bucket(b - self.off)
        return vecs, ids + self._offs[1]

    def read_bucket_into(self, b: int, out_vecs, out_ids,
                         pad_value: float = 0.0) -> int:
        """Prefetcher hot path: delegate to the owning side, offset ids."""
        if b < self.off:
            side, local, off = self.drive, b, self._offs[0]
        else:
            side, local, off = self.cache, b - self.off, self._offs[1]
        n = side.read_bucket_into(local, out_vecs, out_ids,
                                  pad_value=pad_value)
        out_ids[:n] += off
        return n

    def snapshot_stats(self) -> dict:
        return self._live[0].merge(self._live[1]).snapshot()


class _CrossJoinExecutor(JoinExecutor):
    """Bipartite execution: intra-bucket self-joins disabled."""

    intra_join = False

    def run(self, graph) -> JoinResult:
        res = super().run(graph)
        pipeline = res.io_stats.get("pipeline")
        res.io_stats = self.store.snapshot_stats()
        if pipeline is not None:
            res.io_stats["pipeline"] = pipeline
        return res
