"""Cache management (paper §4.2, Alg. 1) — Belady + baseline policies.

Given the full bucket access sequence S (known offline — this is what makes
Belady legal here), we simulate cache behaviour and emit a *schedule*: for
every access, hit/miss and the victim to evict on miss. The executor replays
the schedule against real storage; the simulator is also used standalone for
the Fig. 17 ablation.

One deviation from the textbook statement of Alg. 1: the executor needs both
endpoints of the in-flight edge resident simultaneously, so eviction skips
*pinned* buckets (the current access's partner). Belady's optimality
argument is unaffected — the pinned bucket is the next access, i.e. the one
with the *smallest* next-access index, which Belady would never pick anyway;
for the baseline policies it is a correctness guard.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict, defaultdict

import numpy as np

INF = np.iinfo(np.int64).max


@dataclasses.dataclass
class CacheSchedule:
    """Replayable cache decisions for an access sequence."""

    hits: int
    misses: int
    loads: int
    actions: list  # per access: (bucket, is_hit, victim_or_None)

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


def _next_access_table(seq: np.ndarray, num_buckets: int):
    """P[b] = list of access indices of bucket b (Alg. 1 lines 4–5)."""
    P: list[list[int]] = [[] for _ in range(num_buckets)]
    for i, b in enumerate(seq):
        P[int(b)].append(i)
    return P


def simulate_belady(seq: np.ndarray, num_buckets: int, capacity: int,
                    pinned_partner: np.ndarray | None = None) -> CacheSchedule:
    """Alg. 1: max-heap over next-access indices, O(|S| log C)."""
    capacity = max(2, int(capacity))
    P = _next_access_table(seq, num_buckets)
    cnt = np.zeros(num_buckets, dtype=np.int64)  # accesses consumed per bucket
    cache: set[int] = set()
    heap: list[tuple[int, int]] = []  # (-next_access, bucket); lazy deletion
    next_key = np.full(num_buckets, -1, dtype=np.int64)

    def push(b: int) -> None:
        k = cnt[b]
        nxt = P[b][k] if k < len(P[b]) else INF
        next_key[b] = nxt
        heapq.heappush(heap, (-nxt, b))

    hits = misses = 0
    actions = []
    for i, b in enumerate(seq):
        b = int(b)
        cnt[b] += 1
        pin = int(pinned_partner[i]) if pinned_partner is not None else -1
        if b in cache:
            hits += 1
            push(b)  # refresh key to the new next access
            actions.append((b, True, None))
            continue
        misses += 1
        victim = None
        if len(cache) >= capacity:
            while True:
                negk, v = heapq.heappop(heap)
                if v in cache and -negk == next_key[v]:
                    if v == pin or v == b:
                        # pinned: re-push and take the next-furthest
                        spill = [(negk, v)]
                        while True:
                            negk2, v2 = heapq.heappop(heap)
                            if v2 in cache and -negk2 == next_key[v2] \
                                    and v2 != pin and v2 != b:
                                victim = v2
                                break
                            elif v2 in cache and -negk2 == next_key[v2]:
                                spill.append((negk2, v2))
                        for item in spill:
                            heapq.heappush(heap, item)
                        break
                    victim = v
                    break
            cache.discard(victim)
        cache.add(b)
        push(b)
        actions.append((b, False, victim))
    return CacheSchedule(hits=hits, misses=misses, loads=misses,
                         actions=actions)


def simulate_policy(seq: np.ndarray, num_buckets: int, capacity: int,
                    policy: str,
                    pinned_partner: np.ndarray | None = None
                    ) -> CacheSchedule:
    """Online policies for the ablation: lru / fifo / lfu."""
    capacity = max(2, int(capacity))
    if policy == "belady":
        return simulate_belady(seq, num_buckets, capacity, pinned_partner)
    lru: OrderedDict[int, None] = OrderedDict()
    load_time: dict[int, int] = {}
    freq: defaultdict[int, int] = defaultdict(int)
    cache: set[int] = set()
    hits = misses = 0
    actions = []
    for i, b in enumerate(seq):
        b = int(b)
        freq[b] += 1
        pin = int(pinned_partner[i]) if pinned_partner is not None else -1
        if b in cache:
            hits += 1
            if policy == "lru":
                lru.move_to_end(b)
            actions.append((b, True, None))
            continue
        misses += 1
        victim = None
        if len(cache) >= capacity:
            candidates = [v for v in cache if v != pin]
            if policy == "lru":
                for v in lru:
                    if v != pin:
                        victim = v
                        break
            elif policy == "fifo":
                victim = min(candidates, key=lambda v: load_time[v])
            elif policy == "lfu":
                victim = min(candidates, key=lambda v: (freq[v], load_time[v]))
            else:
                raise ValueError(f"unknown policy {policy!r}")
            cache.discard(victim)
            lru.pop(victim, None)
        cache.add(b)
        lru[b] = None
        load_time[b] = i
        actions.append((b, False, victim))
    return CacheSchedule(hits=hits, misses=misses, loads=misses,
                         actions=actions)


