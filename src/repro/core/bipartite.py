"""Bipartite (cross-join) execution core (paper §3 extension).

Shared by the deprecated one-shot ``similarity_cross_join`` wrapper and
``DiskJoinIndex.cross_join``: builds the bipartite candidate graph over two
bucketings (center search + Eq. 1 + probabilistic pruning), presents the
two bucketed stores as one combined bucket-id space, and reuses the
self-join executor with intra-bucket pairs disabled — including its verify
engines (``JoinConfig.compute_mode``): in device mode each side's slabs
cross H2D once per cache residency of the *combined* id space.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.center_index import make_center_index
from repro.core.executor import JoinExecutor
from repro.core.pruning import prune_candidates
from repro.core.types import BucketGraph, BucketMeta, JoinConfig, JoinResult


def bipartite_graph(meta_d: BucketMeta, meta_c: BucketMeta,
                    config: JoinConfig) -> BucketGraph:
    """Candidate graph over (drive ++ cache) bucket ids: for each drive
    bucket, candidate cache buckets by center search + Eq. 1 + pruning.
    Edges are (drive_bucket, num_drive_buckets + cache_bucket)."""
    index = make_center_index(meta_c.centers)
    L = min(config.max_candidates, meta_c.num_buckets)
    d2, cand = index.search(meta_d.centers, L)
    dists = np.sqrt(np.maximum(d2, 0.0))
    eps = float(config.epsilon)
    dim = meta_d.centers.shape[1]
    off = meta_d.num_buckets
    edges: list[tuple[int, int]] = []
    for b in range(meta_d.num_buckets):
        ids, dd = cand[b], dists[b]
        ok = np.isfinite(dd)
        ids, dd = ids[ok], dd[ok]
        tri = dd - meta_d.radii[b] - meta_c.radii[ids] <= eps
        ids, dd = ids[tri], dd[tri]
        if config.prune and ids.size:
            keep = prune_candidates(dd, float(meta_d.radii[b]) + eps, dim,
                                    config.recall_target,
                                    cand_radii=meta_c.radii[ids])
            ids = ids[keep]
        for j in ids:
            edges.append((b, off + int(j)))
    e = (np.asarray(edges, dtype=np.int64) if edges
         else np.zeros((0, 2), dtype=np.int64))
    return BucketGraph(num_nodes=meta_d.num_buckets + meta_c.num_buckets,
                       edges=e)


def bipartite_join(bs_d, meta_d: BucketMeta, bs_c, meta_c: BucketMeta,
                   config: JoinConfig, *, drive_id_offset: int,
                   cache_id_offset: int,
                   attribute_mask: np.ndarray | None = None,
                   shared_pool=None, shared_stats=None
                   ) -> tuple[JoinResult, float]:
    """Execute the bipartite join → (result, graph_build_seconds).

    Drive buckets are streamed in schedule order, cache-side buckets
    managed by the eviction policy; result vector ids are shifted by the
    given per-side offsets (the caller fixes the global id space).
    ``attribute_mask`` is indexed by those *global* ids.
    """
    t0 = time.perf_counter()
    graph = bipartite_graph(meta_d, meta_c, config)
    graph_s = time.perf_counter() - t0

    combined = CombinedBipartiteStore(bs_d, bs_c,
                                      drive_id_offset=drive_id_offset,
                                      cache_id_offset=cache_id_offset)
    meta = BucketMeta(
        centers=np.concatenate([meta_d.centers, meta_c.centers]),
        radii=np.concatenate([meta_d.radii, meta_c.radii]),
        sizes=np.concatenate([meta_d.sizes, meta_c.sizes]),
    )
    executor = CrossJoinExecutor(combined, meta, config,
                                 attribute_mask=attribute_mask,
                                 shared_pool=shared_pool,
                                 shared_stats=shared_stats)
    return executor.run(graph), graph_s


class CombinedBipartiteStore:
    """Unified bucket-id space over (drive ++ cache) bucketed stores.

    Vector ids are tagged per side (via the id offsets) so result pairs
    are unambiguous.
    """

    def __init__(self, drive, cache, drive_id_offset: int,
                 cache_id_offset: int):
        self.drive = drive
        self.cache = cache
        self.dim = drive.dim
        self.off = drive.num_buckets
        self._offs = (drive_id_offset, cache_id_offset)
        self.stats = drive.stats  # JoinExecutor snapshots this; we override
        self._live = (drive.stats, cache.stats)
        # device surface: the two sides are distinct backing stores, so
        # their device ids are disjoint; the prefetcher gets one queue per
        # underlying device across both
        self.num_devices = drive.num_devices + cache.num_devices

    def device_of(self, b: int) -> int:
        if b < self.off:
            return self.drive.device_of(b)
        return self.drive.num_devices + self.cache.device_of(b - self.off)

    def contiguous_after(self, a: int, b: int) -> bool:
        if a < self.off and b < self.off:
            return self.drive.contiguous_after(a, b)
        if a >= self.off and b >= self.off:
            return self.cache.contiguous_after(a - self.off, b - self.off)
        return False

    def read_run_into(self, buckets, out_vecs, out_ids,
                      pad_value: float = 0.0) -> list[int]:
        if buckets[0] < self.off:
            side, locs, off = (self.drive, list(buckets), self._offs[0])
        else:
            side = self.cache
            locs = [b - self.off for b in buckets]
            off = self._offs[1]
        ns = side.read_run_into(locs, out_vecs, out_ids,
                                pad_value=pad_value)
        for oi, n in zip(out_ids, ns):
            oi[:n] += off
        return ns

    def read_bucket(self, b: int):
        if b < self.off:
            vecs, ids = self.drive.read_bucket(b)
            return vecs, ids + self._offs[0]
        vecs, ids = self.cache.read_bucket(b - self.off)
        return vecs, ids + self._offs[1]

    def read_bucket_into(self, b: int, out_vecs, out_ids,
                         pad_value: float = 0.0) -> int:
        """Prefetcher hot path: delegate to the owning side, offset ids."""
        if b < self.off:
            side, local, off = self.drive, b, self._offs[0]
        else:
            side, local, off = self.cache, b - self.off, self._offs[1]
        n = side.read_bucket_into(local, out_vecs, out_ids,
                                  pad_value=pad_value)
        out_ids[:n] += off
        return n

    def snapshot_stats(self) -> dict:
        return self._live[0].merge(self._live[1]).snapshot()


class CrossJoinExecutor(JoinExecutor):
    """Bipartite execution: intra-bucket self-joins disabled."""

    intra_join = False

    def run(self, graph) -> JoinResult:
        res = super().run(graph)
        pipeline = res.io_stats.get("pipeline")
        res.io_stats = self.store.snapshot_stats()
        if pipeline is not None:
            res.io_stats["pipeline"] = pipeline
        return res
