"""Center index — TPU-native replacement for the paper's HNSW (§5.1).

The paper builds an HNSW over the sampled centers and answers
nearest-center queries by graph traversal. Graph traversal is pointer
chasing: data-dependent gathers and branches, which starve the MXU/VPU.
On TPU the idiomatic equivalent is a *dense blocked distance matmul*:

    d²(q, c) = ‖q‖² − 2 q·cᵀ + ‖c‖²

computed tile-by-tile at matmul speed, followed by a top-L reduce. For very
large center sets a two-level IVF structure bounds work: centers are grouped
under √B coarse centroids; a query scans the nprobe nearest coarse cells
only. Both paths are exact within the probed set and run as a handful of
einsums — no host round-trips inside the scan loop.

This file is pure JAX (jit'd); the Pallas `bucket_assign` kernel in
repro.kernels fuses the distance+argmin for the assignment hot loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("k",))
def _topk_neg_dist(queries: jax.Array, centers: jax.Array,
                   center_sq: jax.Array, k: int):
    """Top-k nearest (squared L2) centers per query via one matmul."""
    qsq = jnp.sum(queries * queries, axis=1, keepdims=True)
    d2 = qsq - 2.0 * queries @ centers.T + center_sq[None, :]
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


@jax.jit
def _nearest(queries: jax.Array, centers: jax.Array, center_sq: jax.Array):
    qsq = jnp.sum(queries * queries, axis=1, keepdims=True)
    d2 = qsq - 2.0 * queries @ centers.T + center_sq[None, :]
    idx = jnp.argmin(d2, axis=1)
    return jnp.take_along_axis(d2, idx[:, None], axis=1)[:, 0], idx


@dataclasses.dataclass
class BruteForceCenterIndex:
    """Exact blocked matmul index — right answer for ≲64k centers."""

    centers: np.ndarray  # (B, d) float32

    def __post_init__(self):
        self._centers_dev = jnp.asarray(self.centers, jnp.float32)
        self._center_sq = jnp.sum(self._centers_dev ** 2, axis=1)

    @property
    def num_centers(self) -> int:
        return self.centers.shape[0]

    def assign(self, queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Nearest center per query → (sq_dists, center_ids)."""
        d2, idx = _nearest(jnp.asarray(queries, jnp.float32),
                           self._centers_dev, self._center_sq)
        return np.asarray(d2), np.asarray(idx)

    def search(self, queries: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
        """k nearest centers per query → (sq_dists (Q,k), ids (Q,k))."""
        k = min(k, self.num_centers)
        d2, idx = _topk_neg_dist(jnp.asarray(queries, jnp.float32),
                                 self._centers_dev, self._center_sq, k)
        return np.asarray(d2), np.asarray(idx)


@dataclasses.dataclass
class IVFCenterIndex:
    """Two-level index: coarse k-means-lite over centers, probe-limited scan.

    Build: sample √B coarse centroids from the centers, one Lloyd refinement
    pass (all matmuls), group centers by coarse cell. Query: find nprobe
    nearest coarse cells, scan their member centers exactly.

    Memory: centers + int32 cell assignment ≈ the paper's "2‰ of dataset"
    HNSW footprint claim; compute: O(Q·(√B + B·nprobe/√B)·d) vs O(Q·B·d)
    brute force.
    """

    centers: np.ndarray
    nprobe: int = 8
    seed: int = 0

    def __post_init__(self):
        B, d = self.centers.shape
        ncoarse = max(1, int(np.sqrt(B)))
        rng = np.random.default_rng(self.seed)
        coarse = self.centers[rng.choice(B, size=ncoarse, replace=False)]
        # one Lloyd step (matmul-only refinement)
        cj = jnp.asarray(coarse, jnp.float32)
        xs = jnp.asarray(self.centers, jnp.float32)
        _, assign = _nearest(xs, cj, jnp.sum(cj ** 2, axis=1))
        assign = np.asarray(assign)
        for c in range(ncoarse):
            m = assign == c
            if m.any():
                coarse[c] = self.centers[m].mean(axis=0)
        cj = jnp.asarray(coarse, jnp.float32)
        _, assign = _nearest(xs, cj, jnp.sum(cj ** 2, axis=1))
        assign = np.asarray(assign)

        self.coarse = coarse
        self._coarse_dev = cj
        self._coarse_sq = jnp.sum(cj ** 2, axis=1)
        # bucket-list layout: members sorted by cell, offsets per cell
        order = np.argsort(assign, kind="stable")
        self._member_ids = order.astype(np.int32)
        self._cell_of = assign
        counts = np.bincount(assign, minlength=ncoarse)
        self._cell_offsets = np.concatenate([[0], np.cumsum(counts)])
        self._centers_sorted = self.centers[order]
        self._centers_sorted_dev = jnp.asarray(self._centers_sorted, jnp.float32)
        self._centers_sorted_sq = jnp.sum(self._centers_sorted_dev ** 2, axis=1)
        self.ncoarse = ncoarse

    @property
    def num_centers(self) -> int:
        return self.centers.shape[0]

    def _probe_members(self, cells: np.ndarray) -> np.ndarray:
        segs = [np.arange(self._cell_offsets[c], self._cell_offsets[c + 1])
                for c in cells]
        return np.concatenate(segs) if segs else np.zeros(0, np.int64)

    def search(self, queries: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, np.float32)
        nprobe = min(self.nprobe, self.ncoarse)
        _, cell_idx = _topk_neg_dist(jnp.asarray(queries), self._coarse_dev,
                                     self._coarse_sq, nprobe)
        cell_idx = np.asarray(cell_idx)
        out_d = np.full((len(queries), k), np.inf, np.float32)
        out_i = np.zeros((len(queries), k), np.int64)
        # batch queries that probe identical cell sets to amortize gathers
        for qi in range(len(queries)):
            members = self._probe_members(cell_idx[qi])
            if members.size == 0:
                continue
            sub = self._centers_sorted_dev[members]
            d2 = np.asarray(
                jnp.sum((sub - jnp.asarray(queries[qi])[None, :]) ** 2, axis=1))
            kk = min(k, members.size)
            part = np.argpartition(d2, kk - 1)[:kk]
            part = part[np.argsort(d2[part])]
            out_d[qi, :kk] = d2[part]
            out_i[qi, :kk] = self._member_ids[members[part]]
        return out_d, out_i

    def assign(self, queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        d, i = self.search(queries, 1)
        return d[:, 0], i[:, 0]


def make_center_index(centers: np.ndarray, *, exact_threshold: int = 65536,
                      nprobe: int = 8, seed: int = 0):
    """Pick brute-force vs IVF by center count (DESIGN §2 crossover)."""
    if centers.shape[0] <= exact_threshold:
        return BruteForceCenterIndex(centers)
    return IVFCenterIndex(centers, nprobe=nprobe, seed=seed)
