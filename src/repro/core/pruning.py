"""Probabilistic candidate-bucket pruning (paper §5.2, Eq. 3 / Alg. 3).

For bucket b with ε-neighborhood ball B(c_b, r) (r = r_b + ε), pruning
candidate bucket b_i can only miss neighbors lying in the spherical cap cut
off by the Voronoi bisector between c_b and c_{b_i}. Under a uniform-density
assumption the missed fraction of the j furthest candidates is bounded by

    β(j) ≤ μ · Σ_{i=l−j}^{l} arccos(min(x_i, 1)),
    μ   = π^{−1/2} · Γ((d−1)/2) / Γ(d/2),
    x_i = db_i / r,   db_i = ‖c_b − c_{b_i}‖ / 2.

Buckets are pruned furthest-first while the running bound stays ≤ 1 − λ.
x_i ≥ 1 ⇒ the bisector does not cut the ball ⇒ zero contribution (such
buckets are also prunable outright by geometry — but they were admitted by
the triangle-inequality prefilter because radii overestimate extents, so the
probabilistic rule subsumes them for free).
"""
from __future__ import annotations

import math

import numpy as np


def cap_constant(dim: int) -> float:
    """μ = π^{-1/2} Γ((d−1)/2)/Γ(d/2) — via lgamma for numerical stability."""
    if dim < 2:
        raise ValueError("dimension must be ≥ 2")
    return math.exp(
        math.lgamma((dim - 1) / 2.0) - math.lgamma(dim / 2.0)
    ) / math.sqrt(math.pi)


def miss_bound_terms(center_dists: np.ndarray, radius: float,
                     dim: int,
                     cand_radii: np.ndarray | None = None) -> np.ndarray:
    """Per-candidate miss-probability terms μ·arccos(clip(x_i)).

    Self-join (``cand_radii=None``): the paper's Voronoi-bisector cut,
    x_i = (‖c_b − c_{b_i}‖/2)/r — sound because nearest-center assignment
    confines bucket b_i to its Voronoi cell.

    Cross-join (``cand_radii`` given): the bisector argument fails (the
    other dataset is assigned among *its own* centers), so we use the ball
    cap that contains B(c_{b_i}, r_i) ∩ B(c_b, r): any point within r_i of
    c_{b_i} projects ≥ ‖c_b − c_{b_i}‖ − r_i along the center axis, giving
    the cut x_i = (‖c_b − c_{b_i}‖ − r_i)/r. Exact geometry, no Voronoi
    assumption.

    Args:
      center_dists: (L,) distances ‖c_b − c_{b_i}‖ to candidate centers.
      radius: r = r_b + ε, the ε-neighborhood ball radius of bucket b.
      dim: vector dimension d.
      cand_radii: (L,) candidate-bucket radii (cross-join mode).
    """
    if radius <= 0:
        return np.zeros_like(center_dists, dtype=np.float64)
    d = np.asarray(center_dists, np.float64)
    if cand_radii is None:
        cut = d / 2.0
    else:
        cut = d - np.asarray(cand_radii, np.float64)
    x = np.clip(cut / float(radius), -1.0, 1.0)
    return cap_constant(dim) * np.arccos(x)


def prune_candidates(center_dists: np.ndarray, radius: float, dim: int,
                     recall_target: float,
                     cand_radii: np.ndarray | None = None) -> np.ndarray:
    """Alg. 3: keep-mask over candidates, pruning furthest-first.

    Sorts candidates by distance descending, accumulates the bound terms, and
    prunes while the partial sum stays within the error budget 1 − λ.

    Returns a boolean keep mask aligned with ``center_dists``.
    """
    l = len(center_dists)
    keep = np.ones(l, dtype=bool)
    if l == 0:
        return keep
    budget = max(0.0, 1.0 - float(recall_target))
    terms = miss_bound_terms(center_dists, radius, dim, cand_radii)
    order = np.argsort(-np.asarray(center_dists))  # furthest first
    acc = 0.0
    for idx in order:
        t = float(terms[idx])
        if acc + t <= budget:
            acc += t
            keep[idx] = False
        else:
            break  # Alg. 3 stops at the first candidate exceeding the budget
    return keep


def split_error_budget(recall_target: float, num_buckets: int,
                       per_bucket: bool = True) -> float:
    """DiskJoin applies the budget per bucket (Alg. 3 operates bucket-wise);
    expected recall is then ≥ λ by linearity over the per-bucket misses."""
    del num_buckets, per_bucket
    return recall_target
