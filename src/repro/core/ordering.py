"""Task ordering via Gorder-style graph reordering (paper §4.3, Alg. 2).

Greedy: start from the node with the largest out-degree; at each step append
the remaining node whose out-neighborhood overlaps most with the
out-neighborhoods of the nodes in the trailing window of size w = C/d_avg.

Naive scoring is O(w·d_max·n²); we keep the paper's incremental scheme —
scores k_v live in an array, updated only for nodes affected by the node
entering / leaving the window (each update touches N(x) for x ∈ N(u)), plus
a lazy max-heap, giving O(Σ_u d⁺(u)²) overall.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.types import BucketGraph


def _out_neighbors(graph: BucketGraph) -> list[np.ndarray]:
    nbrs: list[list[int]] = [[] for _ in range(graph.num_nodes)]
    for i, j in graph.edges:
        nbrs[int(i)].append(int(j))
        nbrs[int(j)].append(int(i))  # undirected view: shared-partner locality
    return [np.asarray(sorted(set(x)), dtype=np.int64) for x in nbrs]


def gorder(graph: BucketGraph, window: int) -> np.ndarray:
    """Return node order (new position → node id)."""
    n = graph.num_nodes
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    window = max(1, int(window))
    nbrs = _out_neighbors(graph)
    # reverse adjacency for "who shares a neighbor with u": v shares x with u
    # iff v ∈ N(x) for some x ∈ N(u); N here is symmetric so reuse nbrs.
    placed = np.zeros(n, dtype=bool)
    score = np.zeros(n, dtype=np.int64)
    heap: list[tuple[int, int, int]] = []   # (-score, tiebreak, node)
    stamp = np.zeros(n, dtype=np.int64)     # lazy-heap staleness stamps

    def push(v: int) -> None:
        stamp[v] += 1
        heapq.heappush(heap, (-int(score[v]), int(stamp[v]), v))

    def bump(u: int, delta: int) -> None:
        """Node u entered(+1)/left(-1) the window: update sharers' scores."""
        for x in nbrs[u]:
            for v in nbrs[x]:
                if not placed[v]:
                    score[v] += delta
                    if delta > 0:
                        push(v)

    degrees = np.asarray([len(x) for x in nbrs])
    start = int(np.argmax(degrees))
    order = [start]
    placed[start] = True
    bump(start, +1)

    for v in range(n):
        if v != start:
            push(v)

    while len(order) < n:
        # slide the window: drop the node that falls out
        if len(order) > window:
            bump(order[len(order) - window - 1], -1)
        # pop the best non-stale, unplaced node
        while True:
            if not heap:
                # isolated leftovers — append in id order
                rest = np.flatnonzero(~placed)
                order.extend(int(r) for r in rest)
                placed[rest] = True
                break
            negs, st, v = heapq.heappop(heap)
            if placed[v] or st != stamp[v] or -negs != score[v]:
                continue
            order.append(v)
            placed[v] = True
            bump(v, +1)
            break

    return np.asarray(order, dtype=np.int64)


def edge_schedule(graph: BucketGraph, node_order: np.ndarray):
    """Induce the edge processing order from a node order.

    Each edge is anchored at whichever endpoint appears *earlier* in the
    order; a node's anchored edges are processed in one run (paper §4.3:
    "process all of v's outgoing edges in succession"), partners sorted by
    their own position for window locality.

    Returns:
      tasks:      list of ("touch", b) | ("edge", u, v) in processing order.
                  Every node gets exactly one "touch" (intra-bucket
                  self-join; isolated buckets still self-join).
      access_seq: (S,) int64 bucket access sequence (Alg. 1 input).
      pins:       (S,) int64 partner-to-pin per access (−1 = none) — the
                  executor needs both endpoints of the in-flight edge
                  resident, so eviction must skip the partner.
    """
    pos = np.empty(graph.num_nodes, dtype=np.int64)
    pos[node_order] = np.arange(graph.num_nodes)

    anchored: list[list[tuple[int, int]]] = [[] for _ in range(graph.num_nodes)]
    for i, j in graph.edges:
        i, j = int(i), int(j)
        a, b = (i, j) if pos[i] <= pos[j] else (j, i)
        anchored[a].append((int(pos[b]), b))

    tasks: list[tuple] = []
    access: list[int] = []
    pins: list[int] = []
    for v in node_order:
        v = int(v)
        tasks.append(("touch", v))
        access.append(v)
        pins.append(-1)
        for _, b in sorted(anchored[v]):
            tasks.append(("edge", v, b))
            access.extend((v, b))
            pins.extend((b, v))

    return tasks, np.asarray(access, dtype=np.int64), \
        np.asarray(pins, dtype=np.int64)


def compute_node_order(graph: BucketGraph, meta, config,
                       cache_buckets: int) -> np.ndarray:
    """One node-order policy for every consumer of the schedule.

    The executor's cache schedule, the distributed superstep planner and
    the bucketed writer's *disk layout* (schedule-adjacent ⇒ disk-adjacent
    for read coalescing) all derive their order here, so they agree by
    construction.
    """
    if not config.reorder:
        return np.arange(graph.num_nodes, dtype=np.int64)
    if config.order_strategy == "spatial":
        return spatial_order(meta.centers)
    return gorder(graph, window_size(cache_buckets, graph))


def window_size(cache_buckets: int, graph: BucketGraph) -> int:
    """w = C / d_avg (paper §4.3)."""
    if graph.num_edges == 0 or graph.num_nodes == 0:
        return max(1, cache_buckets)
    d_avg = max(1.0, 2.0 * graph.num_edges / graph.num_nodes)
    return max(1, int(cache_buckets / d_avg))


def spatial_order(centers: np.ndarray, block: int = 4096) -> np.ndarray:
    """Beyond-paper ordering: greedy nearest-neighbor tour of bucket centers.

    The bucket graph is induced by metric proximity, so spatially adjacent
    buckets share most of their candidate sets — a property generic graph
    reordering (Gorder) only recovers indirectly through neighborhood
    overlap counts. The tour makes consecutive anchors metric neighbors
    directly; measured on clustered data it cuts bucket loads ~16% below
    Gorder at small cache sizes (EXPERIMENTS §Perf/join).

    O(B²) distance table (fine to ~16k buckets; beyond that, seed with a
    PCA-1D sort and run the tour per segment).
    """
    n = centers.shape[0]
    if n <= 2:
        return np.arange(n, dtype=np.int64)
    if n > 16384:  # coarse fallback: 1-D spectral sort
        c = centers - centers.mean(0)
        _, _, vt = np.linalg.svd(c, full_matrices=False)
        return np.argsort(c @ vt[0]).astype(np.int64)
    cf = centers.astype(np.float32)
    sq = np.sum(cf * cf, axis=1)
    d2 = sq[:, None] - 2.0 * cf @ cf.T + sq[None, :]
    np.fill_diagonal(d2, np.inf)
    visited = np.zeros(n, dtype=bool)
    tour = np.empty(n, dtype=np.int64)
    cur = 0
    tour[0] = 0
    visited[0] = True
    for i in range(1, n):
        row = np.where(visited, np.inf, d2[cur])
        cur = int(np.argmin(row))
        tour[i] = cur
        visited[cur] = True
    return tour
