"""Build-once / query-many DiskJoin session API.

The paper's workflow (bucketize → graph → orchestrate → execute, §3) was
exposed as one-shot free functions, so every ε-sweep, ablation and
benchmark re-bucketized and re-laid-out the dataset from scratch.
``DiskJoinIndex`` makes the expensive build a persisted artifact and every
threshold query a cheap pass over it — the same split work-sharing vector
join systems (Kim et al.) and I/O-efficient LSH joins (Pagh et al.) use to
amortize partitioning across many queries:

    index = DiskJoinIndex.build(store, config, workdir)   # bucketize ONCE
    r1 = index.self_join(epsilon=0.2)     # graph/schedule only
    r2 = index.self_join(epsilon=0.3)     # reuses bucketing + warm cache
    ids, dists = index.query(q, epsilon=0.25)   # online point lookup
    ...
    index = DiskJoinIndex.open(workdir)   # reattach later, no rescan

``build`` writes a manifest (build config, layout order, store kind) next
to the bucketed store, so ``open`` reattaches without touching the flat
dataset. The instance owns, for its lifetime, the bucketed/striped store,
ONE ``BufferPool`` and ONE ``PipelineStats``: batch joins and online point
queries share a single slab memory budget and a single telemetry surface
(the ROADMAP "serving integration" item — ``repro.serve`` wraps ``query``
in a thin ``VectorQueryService``).

The online path is split into a plan phase (``plan_probes`` — candidate
buckets from in-memory metadata, no I/O) and an execute phase
(``execute_probes`` — one read per distinct bucket, fanned out to every
member query's verify), so a wave scheduler
(``repro.serve.QueryScheduler``) can union many concurrent requests'
probe sets and pay each hot bucket's read once.

Configuration is split at the build/query boundary (``repro.core.types``):
build-time parameters are frozen in the manifest and rejected as per-call
overrides, so a query can never silently invalidate the on-disk layout.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import time
import warnings
from collections import OrderedDict

import numpy as np

from repro.core import ordering
from repro.core.bipartite import bipartite_join
from repro.core.bucket_graph import build_bucket_graph
from repro.core.bucketize import bucketize
from repro.core.center_index import make_center_index
from repro.core.executor import PAD_COORD, JoinExecutor
from repro.core.pruning import prune_candidates
from repro.core.types import (BUILD_TIME_FIELDS, QUERY_TIME_FIELDS,
                              BucketMeta, BuildConfig, JoinConfig,
                              JoinResult, QueryConfig, finalize_timings,
                              merge_config, resolve_bucket_capacity,
                              resolve_cache_buckets, split_config)
from repro.ft.atomic import AsyncCommitter, atomic_write_json
from repro.io import BufferPool, PipelineStats
from repro.io.retry import read_with_retry
from repro.obs import MetricsRegistry, enable_tracing, get_tracer
from repro.obs.live import LiveObserver, default_serving_slos
from repro.plan import (SKETCH_FILE, CardinalityEstimator, CostModel,
                        Planner)
from repro.store.striped_store import StripedBucketedVectorStore
from repro.store.vector_store import BucketedVectorStore, FlatVectorStore

MANIFEST_NAME = "diskjoin_index.json"
MANIFEST_FORMAT = "diskjoin-index/v1"
# serving fast-restart snapshot: which buckets were warm at close()
RESIDENCY_NAME = "residency.json"
# pool slabs the query warm cache always leaves free (liveness headroom
# for concurrent batch joins and for the queries' own transient reads)
_WARM_RESERVE = 2


class DiskJoinIndex:
    """Persistent session over one bucketized dataset. Use ``build``/``open``."""

    def __init__(self, workdir: str, store, meta: BucketMeta,
                 build_config: BuildConfig,
                 query_defaults: QueryConfig | None,
                 build_timings: dict | None = None,
                 build_seconds: float = 0.0):
        self.workdir = workdir
        self.store = store                  # bucketed (possibly striped)
        self.meta = meta
        self.build_config = build_config
        self.query_defaults = query_defaults
        self.build_timings = dict(build_timings or {})
        self.build_seconds = float(build_seconds)
        self.stats = PipelineStats()        # ONE lifetime telemetry surface
        # session tracer: None → resolve the current (module-level) tracer
        # at call time, so `with trace_session():` around any join/query
        # records without re-plumbing; set to a Tracer to pin one
        self.tracer = None
        self.metrics = MetricsRegistry()
        self.metrics.register_provider("pipeline", self.stats.snapshot)
        self.metrics.register_provider("io",
                                       lambda: self.store.stats.snapshot())
        # span drops must be visible without holding the tracer object
        self.metrics.register_provider("tracer", self._tracer_section)
        self.bucket_capacity = resolve_bucket_capacity(build_config,
                                                       meta.sizes)
        self._pool: BufferPool | None = None
        self._pool_lock = threading.Lock()
        self._center_index = None
        self._center_lock = threading.Lock()
        self._graph_cache: dict = {}
        self._order_cache: dict = {}
        # warm point-query cache: bucket -> (pool slot, rows); each entry
        # holds one pool reference (dropped while batch joins run)
        self._warm: OrderedDict[int, tuple[int, int]] = OrderedDict()
        self._warm_lock = threading.RLock()
        self._joins_active = 0
        # cost-based planning (repro.plan): the sketch-backed estimator is
        # session-lazy; _warm_quota is the PoolPlan's serving share of the
        # slab budget (None = legacy all-but-reserve behavior)
        self._estimator: CardinalityEstimator | None = None
        self._estimator_lock = threading.Lock()
        self._sketch_path = os.path.join(workdir, SKETCH_FILE)
        self._warm_quota: int | None = None
        # live observability (repro.obs.live): rollups + SLO monitors +
        # cost recalibration, attached on demand via attach_live()
        self._live: LiveObserver | None = None
        self._live_key: str | None = None
        # periodic residency snapshots (ft follow-on): an async writer
        # thread persists residency.json on an interval so a crash
        # mid-serve still restarts warm; never blocks the serve path
        self._residency_committer: AsyncCommitter | None = None
        self._residency_interval = 0.0
        self._residency_next = float("inf")
        self._closed = False

    # -- construction ---------------------------------------------------------
    @classmethod
    def build(cls, store: FlatVectorStore,
              config: JoinConfig | BuildConfig,
              workdir: str | None = None, *,
              layout: str = "auto",
              resumable: bool = True) -> "DiskJoinIndex":
        """Bucketize + lay out ``store`` once under ``workdir`` and return
        the attached session. ``config`` may be a flat ``JoinConfig`` (its
        query-time half becomes the session's per-call defaults) or a bare
        ``BuildConfig`` (then every query call must pass ``epsilon``).

        ``layout`` chooses the disk extent order used when coalescing or
        striping is on: ``"auto"`` plans the join schedule order for the
        config's default parameters (schedule-adjacent ⇒ disk-adjacent);
        ``"spatial"`` uses the ε-free nearest-neighbor center tour (the
        right choice when the index mostly serves cross-joins or wide
        ε-sweeps). Without coalescing/striping no reordering is needed.

        ``resumable`` (default on) commits per-phase markers under
        ``<workdir>/build_phases`` (``repro.ft.PhaseLog``): a build killed
        mid-way restarts at the last finished phase — sample, assign,
        sketch and layout outputs are loaded instead of rescanning the
        flat store (only the final write scan re-runs). A config change
        invalidates the markers (fingerprinted); the log is removed once
        the manifest commits.
        """
        if isinstance(config, BuildConfig):
            build_cfg, query_defaults = config, None
        else:
            build_cfg, query_defaults = split_config(config)
        if layout not in ("auto", "spatial"):
            raise ValueError(f"layout must be 'auto' or 'spatial', "
                             f"got {layout!r}")
        workdir = workdir or tempfile.mkdtemp(prefix="diskjoin_index_")
        os.makedirs(workdir, exist_ok=True)

        flog = None
        if resumable:
            from repro.ft.phases import PhaseLog, build_fingerprint
            flog = PhaseLog(
                os.path.join(workdir, "build_phases"),
                build_fingerprint(dataclasses.asdict(build_cfg),
                                  (store.num_vectors, store.dim), layout))

        # disk-layout planning (only when coalescing/striping can use it):
        # the write scan needs the extent order *before* it lays them out
        plan_cache: dict = {}
        wants_layout = build_cfg.io_coalesce or build_cfg.io_devices > 1
        layout_fn = None
        if wants_layout:
            if layout == "auto" and query_defaults is not None:
                flat = merge_config(build_cfg, query_defaults)

                def layout_fn(meta):
                    if flog is not None and flog.has("layout"):
                        order = flog.load_arrays("layout")["order"]
                        plan_cache.update(
                            order=order,
                            kind=flog.load_meta("layout").get("kind"))
                        return order
                    graph = build_bucket_graph(meta, flat)
                    cap = resolve_bucket_capacity(flat, meta.sizes)
                    cache_buckets = resolve_cache_buckets(flat, cap,
                                                          store.dim)
                    order = ordering.compute_node_order(graph, meta, flat,
                                                        cache_buckets)
                    plan_cache.update(graph=graph, order=order,
                                      cache_buckets=cache_buckets,
                                      kind="schedule")
                    if flog is not None:
                        flog.commit_arrays("layout",
                                           extra={"kind": "schedule"},
                                           order=order)
                    return order
            else:
                def layout_fn(meta):
                    if flog is not None and flog.has("layout"):
                        order = flog.load_arrays("layout")["order"]
                        plan_cache.update(order=order, kind="spatial")
                        return order
                    order = ordering.spatial_order(meta.centers)
                    plan_cache.update(order=order, kind="spatial")
                    if flog is not None:
                        flog.commit_arrays("layout",
                                           extra={"kind": "spatial"},
                                           order=order)
                    return order

        # planner cardinality sketch: sampled from the FLAT store during
        # bucketization (one gather, no bucketed-store reads), persisted
        # next to the manifest so reattached sessions load it for free
        sketch_box: dict = {}

        def sketch_sink(assignment, num_buckets):
            if flog is not None and flog.has("sketch"):
                sketch_box["est"] = CardinalityEstimator.load(
                    os.path.join(flog.path("sketch"), "sketch.npz"))
                return
            est = CardinalityEstimator.sample_flat(
                store, assignment, num_buckets, seed=build_cfg.seed)
            sketch_box["est"] = est
            if flog is not None:
                flog.commit("sketch", lambda tmp: est.save(
                    os.path.join(tmp, "sketch.npz")))

        t0 = time.perf_counter()
        bstore, meta, bt = bucketize(store, os.path.join(workdir, "buckets"),
                                     config, layout_order_fn=layout_fn,
                                     sketch_sink=sketch_sink,
                                     phase_log=flog)
        build_seconds = time.perf_counter() - t0

        index = cls(workdir, bstore, meta, build_cfg, query_defaults,
                    build_timings=bt, build_seconds=build_seconds)
        est = sketch_box.get("est")
        if est is not None:
            est.save(index._sketch_path)
            index._estimator = est
        layout_kind = plan_cache.get("kind")
        if "graph" in plan_cache and query_defaults is not None:
            # the layout pass already planned the default-config join;
            # seed the session caches so the first self_join reuses it
            # (a resumed layout phase loads only the order — the caches
            # then repopulate lazily)
            flat = merge_config(build_cfg, query_defaults)
            gkey = index._graph_key(flat)
            index._graph_cache[gkey] = plan_cache["graph"]
            index._order_cache[(gkey, flat.order_strategy, flat.reorder,
                                plan_cache["cache_buckets"])] = \
                plan_cache["order"]
        index._write_manifest(plan_cache.get("order"), layout_kind)
        if flog is not None:
            flog.clear()  # manifest committed: the build is done
        return index

    @classmethod
    def open(cls, workdir: str,
             config: JoinConfig | QueryConfig | None = None, *,
             warm_start: bool = False) -> "DiskJoinIndex":
        """Reattach to an index built earlier in ``workdir`` — no dataset
        rescan; the bucketed store and manifest are read as-is.

        ``config`` optionally replaces the session's query-time defaults.
        Passing a flat ``JoinConfig`` validates its build-time half against
        the manifest (mismatch raises — the on-disk layout cannot be
        changed by opening it differently).

        ``warm_start=True`` replays the residency snapshot the previous
        session persisted on ``close()``: the buckets that were warm then
        are pre-faulted into pool slabs now (bounded by the warm quota),
        so the first post-restart query wave hits instead of paying cold
        reads. A missing/stale snapshot degrades to a cold open."""
        path = os.path.join(workdir, MANIFEST_NAME)
        with open(path) as f:
            m = json.load(f)
        if m.get("format") != MANIFEST_FORMAT:
            raise ValueError(f"{path}: not a {MANIFEST_FORMAT} manifest")
        build_cfg = BuildConfig(**m["build"])
        manifest_defaults = (QueryConfig(**m["query_defaults"])
                             if m.get("query_defaults") else None)
        query_defaults = manifest_defaults
        if isinstance(config, JoinConfig):
            got_build, query_defaults = split_config(config)
            if got_build != build_cfg:
                diff = [f.name for f in dataclasses.fields(BuildConfig)
                        if getattr(got_build, f.name)
                        != getattr(build_cfg, f.name)]
                raise ValueError(
                    f"build-time parameters {diff} differ from the on-disk "
                    f"index at {workdir}; rebuild with DiskJoinIndex.build "
                    f"to change them")
        elif isinstance(config, QueryConfig):
            query_defaults = config
        elif config is not None:
            raise TypeError("config must be JoinConfig, QueryConfig or None")
        store_path = os.path.join(workdir, m["store"])
        store = (StripedBucketedVectorStore(store_path) if m["striped"]
                 else BucketedVectorStore(store_path))
        if query_defaults is not None:
            store.read_latency_s = query_defaults.emulate_read_latency_s
        meta = BucketMeta(centers=store.centers, radii=store.radii,
                          sizes=np.asarray(store.bucket_sizes))
        index = cls(workdir, store, meta, build_cfg, query_defaults,
                    build_timings=m.get("build_timings"),
                    build_seconds=m.get("build_seconds", 0.0))
        if (m.get("layout_kind") == "schedule"
                and m.get("layout_order") is not None
                and manifest_defaults is not None):
            # the persisted layout IS the schedule order planned for the
            # MANIFEST's defaults — seed the order cache under that key
            # so a reattached session's first matching self_join skips
            # the gorder recompute (same key derivation as build)
            flat = merge_config(build_cfg, manifest_defaults)
            gkey = index._graph_key(flat)
            cache_buckets = resolve_cache_buckets(flat,
                                                  index.bucket_capacity,
                                                  store.dim)
            index._order_cache[(gkey, flat.order_strategy, flat.reorder,
                                cache_buckets)] = \
                np.asarray(m["layout_order"], dtype=np.int64)
        if warm_start:
            index._warm_start()
        return index

    def reopen(self, *, warm_start: bool = True) -> "DiskJoinIndex":
        """A fresh session over the same on-disk index — the supervised
        restart path (``serve.replica.ReplicaSupervisor``): re-``open``
        this session's ``workdir`` with its query-time defaults,
        pre-faulting the residency snapshot by default. The dead session
        is untouched (close it separately; it may be wedged)."""
        return DiskJoinIndex.open(self.workdir, self.query_defaults,
                                  warm_start=warm_start)

    def _write_manifest(self, layout_order, layout_kind) -> None:
        manifest = {
            "format": MANIFEST_FORMAT,
            "store": "buckets",
            "striped": self.store.__class__ is StripedBucketedVectorStore,
            "dim": int(self.store.dim),
            "num_buckets": int(self.meta.num_buckets),
            "num_vectors": int(self.meta.sizes.sum()),
            "build": dataclasses.asdict(self.build_config),
            "query_defaults": (dataclasses.asdict(self.query_defaults)
                               if self.query_defaults is not None else None),
            "layout_kind": layout_kind,
            "layout_order": (np.asarray(layout_order).tolist()
                             if layout_order is not None else None),
            "build_seconds": self.build_seconds,
            "build_timings": self.build_timings,
            # additive (format stays v1): pre-sketch manifests simply
            # lack the key and get a lazy rebuild on first planner use
            "sketch": (self._sketch_manifest_entry()
                       if self._estimator is not None else None),
        }
        # atomic: a build killed mid-manifest-write must not leave a
        # torn JSON that a later open() would half-parse
        atomic_write_json(os.path.join(self.workdir, MANIFEST_NAME),
                          manifest)

    def _sketch_manifest_entry(self) -> dict:
        return {"file": SKETCH_FILE,
                "sample_rows": int(self._estimator.sample_rows),
                "seed": int(self._estimator.seed)}

    def _note_sketch_in_manifest(self) -> None:
        """Record a lazily-rebuilt sketch in the manifest (read-modify-
        write of the JSON only — nothing else changes)."""
        path = os.path.join(self.workdir, MANIFEST_NAME)
        try:
            with open(path) as f:
                m = json.load(f)
            m["sketch"] = self._sketch_manifest_entry()
            with open(path, "w") as f:
                json.dump(m, f)
        except OSError:
            pass  # read-only workdir: the in-memory sketch still serves

    # -- shape ---------------------------------------------------------------
    @property
    def num_vectors(self) -> int:
        return int(self.meta.sizes.sum())

    @property
    def num_buckets(self) -> int:
        return self.meta.num_buckets

    @property
    def dim(self) -> int:
        return self.store.dim

    def _tracer(self):
        return self.tracer if self.tracer is not None else get_tracer()

    def _tracer_section(self) -> dict:
        """``metrics_snapshot()["tracer"]``: whether tracing is on, how
        many events each thread's ring holds, and — crucially — how many
        were silently dropped to ring wrap-around."""
        tr = self._tracer()
        if not tr.enabled:
            return {"enabled": False}
        out = {"enabled": True}
        out.update(tr.ring_stats())
        return out

    # -- live observability (repro.obs.live) -----------------------------------
    @property
    def live(self) -> "LiveObserver | None":
        """The attached live observer, or None (``repro.obs.dash`` reads
        this)."""
        return self._live

    def attach_live(self, *, window_s: float = 1.0, windows: int = 60,
                    slos=None, calibrate: bool = True, on_alert=None,
                    tracer=None, residency_interval_s: float | None = None,
                    **observer_kw) -> "LiveObserver":
        """Attach continuous observability to this session: streaming
        rollups of every span/instant/counter the session records, SLO
        burn-rate monitors over them, and live cost-model recalibration
        feeding ``_planner_for``.

        Uses the session tracer; when no tracer is recording, the
        module-level tracer is enabled (and disabled again on
        ``detach_live``/``close`` if still ours). ``slos=None`` watches
        ``default_serving_slos()``; pass ``slos=()`` for rollups only.
        ``residency_interval_s`` additionally turns on periodic in-run
        residency snapshots (``enable_residency_snapshots``)."""
        if self._live is not None:
            raise RuntimeError("live observability is already attached; "
                               "detach_live() first")
        tr = tracer if tracer is not None else self._tracer()
        owns = False
        if not tr.enabled:
            tr = enable_tracing()
            owns = True
        self.tracer = tr  # pin: session spans keep landing in this tracer
        obs = LiveObserver(
            tr, window_s=window_s, windows=windows,
            slos=default_serving_slos() if slos is None else slos,
            pipeline_source=self.stats.snapshot, metrics=self.metrics,
            on_alert=on_alert, calibrate=calibrate, owns_tracing=owns,
            **observer_kw)
        self._live = obs
        self._live_key = self.metrics.register_provider("live",
                                                        obs.section)
        if residency_interval_s is not None:
            self.enable_residency_snapshots(residency_interval_s)
        return obs

    def detach_live(self) -> None:
        """Remove the live observer (sink, provider, owned tracing)."""
        obs, self._live = self._live, None
        if obs is None:
            return
        if self._live_key is not None:
            self.metrics.unregister_provider(self._live_key)
            self._live_key = None
        if self.tracer is obs.tracer and obs.owns_tracing:
            self.tracer = None
        obs.close()

    # -- cost-based planning ---------------------------------------------------
    @property
    def estimator(self) -> CardinalityEstimator:
        """The session's cardinality estimator (``repro.plan``), backed by
        the persisted per-bucket sketch. Indexes built before sketches
        existed get a one-time lazy rebuild from the bucketed store (with
        a warning), and the rebuilt sketch is re-persisted so the cost is
        paid once per index, not once per session."""
        with self._estimator_lock:
            if self._estimator is None:
                if os.path.exists(self._sketch_path):
                    self._estimator = CardinalityEstimator.load(
                        self._sketch_path)
                else:
                    warnings.warn(
                        f"index at {self.workdir} predates planner "
                        f"sketches; rebuilding the cardinality sketch "
                        f"from the bucketed store (one-time, "
                        f"{self.meta.num_buckets} bucket reads)",
                        stacklevel=2)
                    self._estimator = CardinalityEstimator.sample_bucketed(
                        self.store, self.meta.sizes,
                        seed=self.build_config.seed)
                    try:
                        self._estimator.save(self._sketch_path)
                    except OSError:
                        pass  # read-only workdir
                    else:
                        self._note_sketch_in_manifest()
            return self._estimator

    def _planner_for(self, cfg: JoinConfig) -> Planner:
        """A planner bound to this session's estimator and a cost model
        calibrated from the session's telemetry + this call's emulation
        knobs. Cheap to construct per call — the emulated link/latency
        may differ between calls, so the cost model cannot be cached.

        With ``attach_live()`` active, the observer's rolling
        span-derived constants join the calibration as the ``live``
        provenance tier (measured > live > config > static): long runs'
        wave plans re-price from what the hardware is doing *now* — the
        link especially, which no cumulative counter measures."""
        live = self._live.live_constants() if self._live is not None \
            else None
        cost = CostModel.from_telemetry(cfg, self.stats.snapshot(),
                                        live=live)
        return Planner(self.estimator, cost, tracer=self._tracer(),
                       metrics=self.metrics, pstats=self.stats)

    # -- config resolution ---------------------------------------------------
    def _resolve(self, overrides: dict) -> JoinConfig:
        """Merge per-call query-time overrides over the session defaults.

        Build-time keys are rejected outright: the on-disk layout cannot
        be changed by a query, only by a rebuild."""
        bad = sorted(set(overrides) & BUILD_TIME_FIELDS)
        if bad:
            raise ValueError(
                f"build-time parameter(s) {bad} are fixed by the on-disk "
                f"index; rebuild with DiskJoinIndex.build to change them")
        unknown = sorted(set(overrides) - QUERY_TIME_FIELDS)
        if unknown:
            raise TypeError(f"unknown query-time parameter(s) {unknown}")
        if self.query_defaults is None:
            if "epsilon" not in overrides:
                raise ValueError(
                    "epsilon is required: the index was built from a bare "
                    "BuildConfig and has no query-time defaults")
            query = QueryConfig(**overrides)
        else:
            query = dataclasses.replace(self.query_defaults, **overrides)
        cfg = merge_config(self.build_config, query)
        self.store.read_latency_s = cfg.emulate_read_latency_s
        return cfg

    # -- per-ε planning caches ------------------------------------------------
    @staticmethod
    def _graph_key(cfg: JoinConfig):
        return (float(cfg.epsilon), float(cfg.recall_target),
                int(cfg.max_candidates), bool(cfg.prune))

    def _graph_for(self, cfg: JoinConfig):
        """Bucket graph for these query params → (graph, seconds, key).
        Repeat calls at the same (ε, λ, L, prune) reuse the cached graph."""
        key = self._graph_key(cfg)
        graph = self._graph_cache.get(key)
        if graph is not None:
            return graph, 0.0, key
        t0 = time.perf_counter()
        graph = build_bucket_graph(self.meta, cfg)
        graph_s = time.perf_counter() - t0
        self._graph_cache[key] = graph
        return graph, graph_s, key

    def _order_for(self, graph, cfg: JoinConfig, cache_buckets: int, gkey):
        key = (gkey, cfg.order_strategy, cfg.reorder, cache_buckets)
        order = self._order_cache.get(key)
        if order is None:
            order = ordering.compute_node_order(graph, self.meta, cfg,
                                                cache_buckets)
            self._order_cache[key] = order
        return order

    # -- session buffer pool --------------------------------------------------
    def _ensure_pool(self, cfg: JoinConfig) -> BufferPool:
        """The session's one BufferPool: sized for a batch join at these
        query params plus warm-cache headroom; created on first use.

        With ``plan_mode="on"`` (and no explicit ``io_pool_slabs``) the
        split between the join working set and the serving warm cache
        comes from the planner's ``PoolPlan`` — the warm share tracks the
        observed per-wave bucket reuse instead of the fixed reserve."""
        with self._pool_lock:
            if self._pool is None:
                cap_buckets = min(
                    resolve_cache_buckets(cfg, self.bucket_capacity,
                                          self.store.dim),
                    self.meta.num_buckets or 1)
                if cfg.plan_mode == "on" and cfg.io_pool_slabs is None:
                    pp = self._planner_for(cfg).plan_pool(
                        cfg, cap_buckets, cfg.io_lookahead,
                        self.stats.snapshot(), floor=_WARM_RESERVE)
                    slabs = max(pp.num_slabs,
                                cap_buckets + 1 + pp.warm_quota)
                    self._warm_quota = pp.warm_quota
                else:
                    slabs = cfg.io_pool_slabs
                    if slabs is None:
                        slabs = cap_buckets + cfg.io_lookahead
                    slabs = max(slabs, cap_buckets + 1) + _WARM_RESERVE
                self._pool = BufferPool(slabs, self.bucket_capacity,
                                        self.store.dim)
            return self._pool

    # -- batch joins ----------------------------------------------------------
    def self_join(self, *, attribute_mask: np.ndarray | None = None,
                  **overrides) -> JoinResult:
        """ε-self-join over the built index. Query-time parameters
        (``epsilon=…``, ``io_mode=…``, ``memory_budget_bytes=…``, …) are
        per-call overrides; bucketization is never repeated — repeated
        calls re-derive only the graph/schedule (cached per ε)."""
        cfg = self._resolve(overrides)
        graph, graph_s, gkey = self._graph_for(cfg)
        pool = (self._ensure_pool(cfg) if cfg.io_mode == "prefetch"
                else None)
        planner = (self._planner_for(cfg) if cfg.plan_mode == "on"
                   else None)
        executor = JoinExecutor(self.store, self.meta, cfg,
                                attribute_mask=attribute_mask,
                                shared_pool=pool, shared_stats=self.stats,
                                tracer=self._tracer(), planner=planner)
        node_order = self._order_for(graph, cfg, executor.cache_buckets,
                                     gkey)
        self._begin_join()
        try:
            result = executor.run(graph, node_order=node_order)
        finally:
            self._end_join()
        result.timings = finalize_timings(result.timings, graph_s)
        return result

    def cross_join(self, other: "DiskJoinIndex", *,
                   reorder_larger: bool = True,
                   attribute_mask: np.ndarray | None = None,
                   **overrides) -> JoinResult:
        """Bipartite ε-join against another index (paper §3 extension).

        Result ids: this index's vectors keep their ids in
        ``[0, self.num_vectors)``; ``other``'s are offset by
        ``self.num_vectors``. ``attribute_mask`` is a
        ``(self.num_vectors + other.num_vectors,)`` bool array over that
        combined id space — pairs survive only if both endpoints pass.
        ``reorder_larger=True`` streams the larger side in schedule order
        and caches the smaller (the paper's DiskJoin1); False flips it.
        """
        cfg = self._resolve(overrides)
        n_x, n_y = self.num_vectors, other.num_vectors
        if attribute_mask is not None:
            attribute_mask = np.asarray(attribute_mask, dtype=bool)
            if attribute_mask.shape != (n_x + n_y,):
                raise ValueError(
                    f"attribute_mask must cover the combined id space "
                    f"({n_x + n_y},), got {attribute_mask.shape}")
        big_first = n_x >= n_y
        if not reorder_larger:
            big_first = not big_first
        drive, cached = (self, other) if big_first else (other, self)
        drive_is_x = drive is self
        # session pool as for self_join; the executor falls back to a
        # private pool when the combined bucket capacity doesn't fit it
        pool = (self._ensure_pool(cfg) if cfg.io_mode == "prefetch"
                else self._pool)
        self._begin_join()
        try:
            result, graph_s = bipartite_join(
                drive.store, drive.meta, cached.store, cached.meta, cfg,
                drive_id_offset=0 if drive_is_x else n_x,
                cache_id_offset=n_x if drive_is_x else 0,
                attribute_mask=attribute_mask,
                shared_pool=pool, shared_stats=self.stats)
        finally:
            self._end_join()
        result.timings = finalize_timings(result.timings, graph_s)
        return result

    def _begin_join(self) -> None:
        # batch joins take the executor's liveness floor on the shared
        # pool; warm query slabs are dropped so they can never starve it
        with self._warm_lock:
            self._joins_active += 1
            self._drop_warm_locked()

    def _end_join(self) -> None:
        with self._warm_lock:
            self._joins_active -= 1

    # -- online point queries -------------------------------------------------
    def _validate_queries(self, Q: np.ndarray) -> np.ndarray:
        """Normalize query input to a contiguous (Q, dim) float32 array,
        rejecting wrong dimensionality and non-finite values up front —
        NaN/Inf would otherwise flow through the verify kernel as garbage
        distances instead of an error."""
        Q = np.ascontiguousarray(np.atleast_2d(np.asarray(Q, np.float32)))
        if Q.ndim != 2 or Q.shape[1] != self.dim:
            raise ValueError(
                f"query shape {Q.shape} incompatible with index "
                f"({self.dim}-dimensional vectors expected)")
        if not np.isfinite(Q).all():
            raise ValueError("query contains NaN/Inf values")
        return Q

    def query(self, q: np.ndarray, epsilon: float | None = None,
              **overrides) -> tuple[np.ndarray, np.ndarray]:
        """ε-range lookup for one query vector → (ids, distances)."""
        out = self.query_batch(np.asarray(q, np.float32)[None, :],
                               epsilon, **overrides)
        return out[0]

    def plan_probes(self, Q: np.ndarray, epsilon: float | None = None,
                    **overrides) -> list[np.ndarray]:
        """Plan phase of ``query_batch``: per-query candidate-bucket ids.

        Pure in-memory metadata work (center index + point triangle
        inequality + Eq. 3 pruning) — no disk reads. A wave scheduler
        (``repro.serve.QueryScheduler``) plans a whole wave first, unions
        the returned sets, and pays ONE read per distinct bucket in
        ``execute_probes`` instead of one per (query, bucket) reference.
        """
        if epsilon is not None:
            overrides["epsilon"] = epsilon
        cfg = self._resolve(overrides)
        Q = self._validate_queries(Q)
        with self._tracer().span("query.plan", queries=Q.shape[0]):
            return self._candidate_buckets(Q, cfg)

    def execute_probes(self, Q: np.ndarray, per_q: list[np.ndarray],
                       epsilon: float | None = None, cancel=None,
                       **overrides
                       ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Execute phase of ``query_batch``: read + verify planned probes.

        ``per_q`` is ``plan_probes``' output for the same ``Q`` (and the
        same query-time parameters). Each *distinct* bucket in the union
        of ``per_q`` is read once — through the session pool, warm cache
        and (``io_mode="prefetch"``) the batching/coalescing prefetcher —
        and its resident slab is fanned out to every member query's
        verify. Returns one (ids, distances) pair per query, unsorted.

        ``cancel(qi) -> bool``: optional mid-execution cancellation
        probe, consulted as buckets are served — a cancelled query's
        verify fan-out is skipped from then on (its result row comes
        back possibly partial), and a bucket whose probing queries are
        ALL cancelled is not even read (``midwave_skipped_reads``). The
        wave scheduler uses this to stop working for requests whose
        deadline expired mid-wave.
        """
        if epsilon is not None:
            overrides["epsilon"] = epsilon
        cfg = self._resolve(overrides)
        Q = self._validate_queries(Q)
        if len(per_q) != Q.shape[0]:
            raise ValueError(f"probe plan covers {len(per_q)} queries, "
                             f"got {Q.shape[0]} query vectors")
        return self._execute_probes(Q, per_q, cfg, cancel=cancel)

    def query_batch(self, Q: np.ndarray, epsilon: float | None = None,
                    **overrides) -> list[tuple[np.ndarray, np.ndarray]]:
        """ε-range lookups for a batch of query vectors.

        Routing (the ROADMAP serving item): candidate buckets come from
        the center index + point triangle inequality + Eq. 3 pruning
        (``plan_probes``); their reads go through the session's shared
        ``BufferPool`` (and, in ``io_mode="prefetch"``, a schedule
        prefetcher), land in the same ``PipelineStats`` as batch joins,
        and recently-read buckets stay warm in pool slabs for subsequent
        queries (``execute_probes``). Returns one (ids, distances) pair
        per query, unsorted, with exact distances (perfect precision;
        recall governed by ``recall_target``). Both compute modes apply
        the ε-threshold in float32 (d² and ε² each rounded to f32) and
        return float32 distances, so host and device agree on
        membership; residual divergence on distance *values* is bounded
        by the device kernel's f32 accumulation on near-zero pairs.
        """
        if epsilon is not None:
            overrides["epsilon"] = epsilon
        cfg = self._resolve(overrides)
        Q = self._validate_queries(Q)
        per_q = self._candidate_buckets(Q, cfg)
        return self._execute_probes(Q, per_q, cfg)

    def _execute_probes(self, Q: np.ndarray, per_q: list[np.ndarray],
                        cfg: JoinConfig, cancel=None
                        ) -> list[tuple[np.ndarray, np.ndarray]]:
        with self._tracer().span(
                "query.execute", queries=Q.shape[0],
                buckets=len({int(b) for ids in per_q for b in ids})):
            return self._execute_probes_inner(Q, per_q, cfg,
                                              cancel=cancel)

    def _execute_probes_inner(self, Q: np.ndarray, per_q: list[np.ndarray],
                              cfg: JoinConfig, cancel=None
                              ) -> list[tuple[np.ndarray, np.ndarray]]:
        eps = float(cfg.epsilon)
        # bucket -> probing query rows; each distinct bucket is read once
        probe: dict[int, list[int]] = {}
        for qi, ids in enumerate(per_q):
            for b in ids:
                probe.setdefault(int(b), []).append(qi)

        # wave plan (plan_mode="on"): k_cap for the device query path
        # from the estimate's upper bound, host/device resolution for
        # compute_mode="auto", and the predicted seconds admission uses
        wplan = None
        compute = cfg.compute_mode
        if cfg.plan_mode == "on":
            wplan = self._planner_for(cfg).plan_wave(
                Q, per_q, self.meta, cfg, self.bucket_capacity,
                warm=set(self.warm_buckets()))
            if compute == "auto":
                compute = wplan.compute_mode
        elif compute == "auto":  # unreachable via config validation
            compute = "host"

        # mid-execution cancellation: a query found cancelled stays
        # cancelled (deadlines only ever recede into the past)
        dead: set[int] = set()

        def live_rows(b: int) -> list[int]:
            qis = probe[b]
            if cancel is None:
                return qis
            out = []
            for qi in qis:
                if qi in dead:
                    continue
                if cancel(qi):
                    dead.add(qi)
                    continue
                out.append(qi)
            return out

        acc_ids: list[list[np.ndarray]] = [[] for _ in range(Q.shape[0])]
        acc_d: list[list[np.ndarray]] = [[] for _ in range(Q.shape[0])]
        # dtype parity with the device path: both query verify paths
        # round d² to float32 and compare against ε² rounded exactly as
        # the device program rounds it (the f64 python product cast ONCE
        # to f32 — not np.float32(eps)**2, which can differ by 1 ulp).
        # The host accumulates the a² − 2ab + b² expansion in f64 first:
        # in f32 that expansion cancels catastrophically for near-zero
        # distances, and the host path — which exists as the accuracy
        # reference — must not inherit the kernel's cancellation error.
        # Residual host/device divergence is therefore bounded by the
        # device kernel's own f32 accumulation (≲1e-3 on distances),
        # while threshold semantics (f32 d² vs f32 ε²) are identical.
        eps2 = np.float32(float(eps) * float(eps))

        def verify(b: int, vecs: np.ndarray, ids_: np.ndarray,
                   n: int) -> None:
            qidx = live_rows(b)
            if not qidx:
                return
            live, lids = vecs[:n], ids_[:n]
            qs = Q[qidx].astype(np.float64)
            lv = live.astype(np.float64)
            d2 = ((qs * qs).sum(1)[:, None] - 2.0 * (qs @ lv.T)
                  + (lv * lv).sum(1)[None, :])
            np.maximum(d2, 0.0, out=d2)
            d2 = d2.astype(np.float32)
            mask = d2 <= eps2
            for row, qi in enumerate(qidx):
                m = mask[row]
                if m.any():
                    acc_ids[qi].append(lids[m].astype(np.int64))
                    acc_d[qi].append(np.sqrt(d2[row][m])
                                     .astype(np.float32))

        if compute == "device":
            verify = self._make_device_verify(
                Q, probe, eps, acc_ids, acc_d, live_rows=live_rows,
                k_cap_init=(wplan.k_cap if wplan is not None else None))
        skip = None
        if cancel is not None:
            def skip(b: int) -> bool:
                return not live_rows(b)
        self._read_and_verify(self._sorted_by_layout(list(probe)), cfg,
                              verify, skip=skip)
        self.stats.add("queries", Q.shape[0])
        self._maybe_snapshot_residency()

        out = []
        for qi in range(Q.shape[0]):
            if acc_ids[qi]:
                out.append((np.concatenate(acc_ids[qi]),
                            np.concatenate(acc_d[qi])))
            else:
                out.append((np.zeros(0, np.int64), np.zeros(0, np.float32)))
        return out

    def _make_device_verify(self, Q: np.ndarray, probe: dict, eps: float,
                            acc_ids: list, acc_d: list, live_rows=None,
                            k_cap_init: int | None = None):
        """Device verify for a probe wave (``compute_mode="device"``):
        the wave's query block crosses H2D ONCE, each probed bucket's
        padded slab once, and the kernel hands back compacted
        (query row, bucket row, distance) triples — no per-bucket host
        distance matrix. Both query paths compute d² in float32 (same
        formulation, see ``_execute_probes_inner``), so host/device
        results agree up to f32 matmul accumulation order — a few ulps
        on d², which only borderline pairs within that tolerance of ε
        can notice (the batch-join engines are byte-identical — both
        take d² from the same jitted program).

        ``k_cap_init`` seeds the compaction capacity from the wave
        plan's estimate upper bound (``plan_mode="on"``) instead of the
        fixed 256; overflow re-dispatch remains as the counted fallback.
        """
        import jax
        import jax.numpy as jnp

        from repro.compute import next_pow2, query_verify_compact

        eps2 = float(eps) * float(eps)
        cap = self.bucket_capacity
        q_dev = jax.device_put(np.array(Q, np.float32))  # staged ONCE
        self.stats.add("h2d_transfers", 1)
        self.stats.add("h2d_bytes", int(Q.nbytes))
        state = {"first": True, "k_cap": int(k_cap_init or 256)}

        def verify(b: int, vecs: np.ndarray, ids_: np.ndarray,
                   n: int) -> None:
            rows_alive = (probe[b] if live_rows is None
                          else live_rows(b))
            if not rows_alive:
                return
            if state["first"]:
                state["first"] = False
            else:
                # every verify after the first reuses the staged block
                # a per-bucket staging baseline would re-transfer
                self.stats.add("device_slab_hits", 1)
                self.stats.add("h2d_transfers_saved", 1)
            slab = vecs
            if slab.shape[0] != cap:  # fallback reads come unpadded
                slab = np.concatenate(
                    [slab, np.full((cap - slab.shape[0], slab.shape[1]),
                                   PAD_COORD, np.float32)])
            slab_dev = jax.device_put(np.array(slab, np.float32))
            self.stats.add("h2d_transfers", 1)
            self.stats.add("h2d_bytes", int(slab.nbytes))
            qidx = np.asarray(rows_alive, np.int32)
            nq = qidx.size
            idx = np.zeros(next_pow2(nq), np.int32)
            idx[:nq] = qidx
            idx_dev = jnp.asarray(idx)
            while True:
                counts, r, c, d = query_verify_compact(
                    q_dev, idx_dev, nq, slab_dev, eps2, state["k_cap"])
                k = int(np.asarray(counts)[0])
                if k <= state["k_cap"]:
                    break
                state["k_cap"] = next_pow2(k)
            if k == 0:
                return
            qrows = np.asarray(r)[0, :k]
            cols = np.asarray(c)[0, :k]
            dists = np.asarray(d)[0, :k]
            lids = ids_[:n]
            for row in np.unique(qrows):
                sel = qrows == row
                qi = int(qidx[row])
                acc_ids[qi].append(lids[cols[sel]].astype(np.int64))
                acc_d[qi].append(dists[sel].astype(np.float32))

        return verify

    def _sorted_by_layout(self, buckets: list[int]) -> list[int]:
        """Order an ad-hoc bucket set by disk placement, so a wave's
        unioned miss set presents disk-adjacent buckets adjacently to the
        prefetcher — the same batched/coalesced submission path the join
        schedule gets, now for serving reads."""
        if len(buckets) < 2 or not hasattr(self.store, "layout_keys"):
            return buckets
        keys = self.store.layout_keys(buckets)
        return [buckets[i] for i in np.argsort(keys, kind="stable")]

    def _candidate_buckets(self, Q: np.ndarray,
                           cfg: JoinConfig) -> list[np.ndarray]:
        """Per-query candidate bucket ids: center search, point triangle
        inequality (‖q − c_b‖ − r_b ≤ ε), then Eq. 3 pruning with the
        query ball radius ε."""
        with self._center_lock:
            if self._center_index is None:
                self._center_index = make_center_index(self.meta.centers)
        eps = float(cfg.epsilon)
        L = min(cfg.max_candidates, self.meta.num_buckets)
        d2, cand = self._center_index.search(Q, L)
        dists = np.sqrt(np.maximum(d2, 0.0))
        out = []
        for qi in range(Q.shape[0]):
            ids, dd = cand[qi], dists[qi]
            ok = np.isfinite(dd)
            ids, dd = ids[ok], dd[ok]
            near = dd - self.meta.radii[ids] <= eps
            ids, dd = ids[near], dd[near]
            if cfg.prune and ids.size:
                keep = prune_candidates(dd, eps, self.dim,
                                        cfg.recall_target,
                                        cand_radii=self.meta.radii[ids])
                ids = ids[keep]
            out.append(ids.astype(np.int64))
        return out

    def _read_and_verify(self, buckets: list[int], cfg: JoinConfig,
                         verify, skip=None) -> None:
        """Serve ``verify(b, vecs, ids, rows)`` for every bucket, routing
        reads through the session pool.

        Liveness under concurrency (a batch join may be running against
        the same pool): warm hits only *pin* already-resident slabs; fresh
        reads hold at most one transient slab each and release it right
        after verification; when the pool is fully contended the read
        falls back to a plain store read (counted) instead of blocking —
        queries therefore never hold-and-wait against the executor.

        ``skip(b) -> bool``: consulted immediately before each bucket is
        served (mid-wave cancellation) — a skipped warm bucket is simply
        not verified; a skipped miss saves its read outright
        (``midwave_skipped_reads``)."""
        pool = self._ensure_pool(cfg)
        warm_hits = 0
        misses: list[int] = []
        for b in buckets:
            if skip is not None and skip(b):
                continue
            with self._warm_lock:
                ent = self._warm.get(b)
                if ent is not None:
                    slot, rows = ent
                    pool.pin(slot)
                    self._warm.move_to_end(b)
                else:
                    slot = None
            if slot is None:
                misses.append(b)
            else:
                try:
                    verify(b, pool.vecs(slot), pool.ids(slot), rows)
                finally:
                    pool.unpin(slot)
                warm_hits += 1
        if warm_hits:
            self.stats.add("query_warm_hits", warm_hits)
        if not misses:
            return

        if cfg.io_mode == "prefetch" and len(misses) > 1:
            self._read_misses_prefetch(misses, cfg, pool, verify,
                                       skip=skip)
        else:
            self._read_misses_sync(misses, cfg, pool, verify, skip=skip)

    def _read_misses_sync(self, misses: list[int], cfg: JoinConfig,
                          pool: BufferPool, verify, skip=None) -> None:
        tr = self._tracer()
        for b in misses:
            if skip is not None and skip(b):
                # every prober's deadline passed since the wave started:
                # the read itself is saved, not just the verify
                self.stats.add("midwave_skipped_reads", 1)
                continue
            self._make_room(pool)
            slot = pool.try_acquire()
            if slot is None:
                # pool fully contended (e.g. a concurrent batch join):
                # bounded-latency fallback instead of hold-and-wait
                size = int(self.meta.sizes[b])
                vecs = np.empty((size, self.dim), np.float32)
                ids = np.empty(size, np.int64)
                t0 = time.perf_counter() if tr.enabled else 0.0
                n = read_with_retry(
                    lambda: self.store.read_bucket_into(
                        b, vecs, ids, pad_value=PAD_COORD),
                    retries=cfg.io_retries,
                    backoff_s=cfg.io_retry_backoff_s, stats=self.stats)
                if tr.enabled:
                    tr.complete("io.read", t0, time.perf_counter() - t0,
                                buckets=1, src="query")
                self.stats.add("query_fallback_reads", 1)
                verify(b, vecs, ids, n)
                continue
            t0 = time.perf_counter() if tr.enabled else 0.0
            n = read_with_retry(
                lambda: self.store.read_bucket_into(
                    b, pool.vecs(slot), pool.ids(slot),
                    pad_value=PAD_COORD),
                retries=cfg.io_retries,
                backoff_s=cfg.io_retry_backoff_s, stats=self.stats)
            if tr.enabled:
                tr.complete("io.read", t0, time.perf_counter() - t0,
                            buckets=1, src="query")
            self.stats.add("query_reads", 1)
            try:
                verify(b, pool.vecs(slot), pool.ids(slot), n)
            finally:
                self._retain_or_release(b, slot, n, pool)

    def _read_misses_prefetch(self, misses: list[int], cfg: JoinConfig,
                              pool: BufferPool, verify, skip=None) -> None:
        """Batch-friendly path: a schedule prefetcher overlaps the misses'
        reads (per-device queues, batching/coalescing as configured).
        The prefetcher was already told the full miss list, so mid-wave
        cancellation here skips only the verify fan-out — the slab still
        lands (and stays warm for later waves), it just isn't scanned."""
        from repro.io import SchedulePrefetcher
        pf = SchedulePrefetcher(
            self.store, misses, pool, lookahead=cfg.io_lookahead,
            num_threads=cfg.io_threads, stats=self.stats,
            pad_value=PAD_COORD, batch_reads=cfg.io_batch_reads,
            coalesce=cfg.io_coalesce, close_pool=False,
            tracer=self._tracer(), retries=cfg.io_retries,
            retry_backoff_s=cfg.io_retry_backoff_s)
        try:
            for _ in misses:
                b, slot, n = pf.pop_next()
                self.stats.add("query_reads", 1)
                try:
                    if skip is None or not skip(b):
                        verify(b, pool.vecs(slot), pool.ids(slot), n)
                finally:
                    self._retain_or_release(b, slot, n, pool)
        finally:
            pf.close()

    # -- warm query cache -----------------------------------------------------
    def _retain_or_release(self, b: int, slot: int, rows: int,
                           pool: BufferPool) -> None:
        """Keep a freshly-read slab warm for later queries when no batch
        join needs the pool and headroom remains; else release it. The
        warm capacity is the planner's ``PoolPlan`` share when one sized
        this pool, else the legacy all-but-reserve bound."""
        with self._warm_lock:
            cap = (self._warm_quota if self._warm_quota is not None
                   else pool.num_slabs - _WARM_RESERVE)
            if (self._joins_active == 0 and b not in self._warm
                    and len(self._warm) < cap):
                self._warm[b] = (slot, rows)
                return
        pool.unpin(slot)

    def _make_room(self, pool: BufferPool) -> None:
        """Evict warm LRU entries until at least one pool slab is free
        (the warm cache must never block the queries that feed it)."""
        with self._warm_lock:
            while self._warm and pool.in_use >= pool.num_slabs - 1:
                _, (slot, _) = self._warm.popitem(last=False)
                pool.unpin(slot)

    def _drop_warm_locked(self) -> None:
        while self._warm:
            _, (slot, _) = self._warm.popitem(last=False)
            self._pool.unpin(slot)

    def drop_warm_cache(self) -> None:
        """Release every warm query slab (benchmark cold-start helper)."""
        with self._warm_lock:
            self._drop_warm_locked()

    def warm_buckets(self) -> list[int]:
        with self._warm_lock:
            return list(self._warm)

    # -- serving fast restart (repro.ft) --------------------------------------
    def _residency_ids(self) -> list[int]:
        """Warm bucket ids eligible for the residency snapshot (LRU
        order, oldest first). Slabs a concurrent query still has pinned
        are excluded — their residency is transient, not cache state."""
        with self._warm_lock:
            pool = self._pool
            if pool is None:
                return []
            # warm entries hold exactly one pool reference; a higher
            # refcount means some in-flight verify has it pinned
            return [int(b) for b, (slot, _) in self._warm.items()
                    if pool.refcount(slot) == 1]

    def save_residency_snapshot(self) -> int:
        """Persist the warm cache's bucket ids to ``residency.json`` so
        the next ``open(warm_start=True)`` can pre-fault them. Returns
        the number of bucket ids written (0 on a read-only workdir)."""
        ids = self._residency_ids()
        try:
            atomic_write_json(os.path.join(self.workdir, RESIDENCY_NAME),
                              {"format": "diskjoin-residency/v1",
                               "buckets": ids})
        except OSError:
            return 0  # read-only workdir: restart just comes up cold
        return len(ids)

    def enable_residency_snapshots(self, interval_s: float = 30.0) -> None:
        """Persist ``residency.json`` periodically *during* serving, not
        only at ``close()`` — a crash mid-serve then still restarts warm.
        The snapshot is captured at query-execution boundaries (a cheap
        id-list copy under the warm lock) and written by an
        ``AsyncCommitter`` daemon via ``try_submit``: the serve path
        never blocks on the disk, and a slow write simply defers the
        snapshot to the next boundary."""
        self._residency_interval = float(interval_s)
        if self._residency_committer is None:
            self._residency_committer = AsyncCommitter(
                name="residency-snapshot")
        self._residency_next = time.perf_counter() + \
            self._residency_interval

    def disable_residency_snapshots(self) -> None:
        committer, self._residency_committer = \
            self._residency_committer, None
        self._residency_next = float("inf")
        if committer is not None:
            committer.close()

    def _maybe_snapshot_residency(self) -> bool:
        """Called at query-execution boundaries: submit an async
        residency write when the interval elapsed and the writer is
        idle. Returns whether a snapshot was submitted."""
        if self._residency_committer is None:
            return False
        now = time.perf_counter()
        if now < self._residency_next:
            return False
        self._residency_next = now + self._residency_interval
        ids = self._residency_ids()
        path = os.path.join(self.workdir, RESIDENCY_NAME)

        def write():
            try:
                atomic_write_json(path,
                                  {"format": "diskjoin-residency/v1",
                                   "buckets": ids})
            except OSError:
                pass  # read-only workdir: keep serving

        if not self._residency_committer.try_submit(write):
            return False  # previous write still in flight
        self.stats.add("residency_snapshots", 1)
        return True

    def _warm_start(self) -> None:
        """Replay a persisted residency snapshot: pre-fault its buckets
        into pool slabs (newest-first priority, bounded by the warm
        quota and pool headroom). Counted as ``warm_prefaults``."""
        path = os.path.join(self.workdir, RESIDENCY_NAME)
        if self.query_defaults is None or not os.path.exists(path):
            return
        try:
            with open(path) as f:
                snap = json.load(f)
            buckets = snap["buckets"]
        except (OSError, ValueError, KeyError):
            return  # torn/stale snapshot: cold start, not an error
        cfg = merge_config(self.build_config, self.query_defaults)
        pool = self._ensure_pool(cfg)
        with self._warm_lock:
            cap = (self._warm_quota if self._warm_quota is not None
                   else pool.num_slabs - _WARM_RESERVE)
            # snapshot is LRU order (oldest first): fault the most
            # recently used end first so it survives any truncation
            faulted = 0
            for b in reversed(buckets):
                b = int(b)
                if faulted >= cap:
                    break
                if not (0 <= b < self.meta.num_buckets):
                    continue  # snapshot predates a rebuild
                if b in self._warm:
                    continue
                slot = pool.try_acquire()
                if slot is None:
                    break
                n = read_with_retry(
                    lambda: self.store.read_bucket_into(
                        b, pool.vecs(slot), pool.ids(slot),
                        pad_value=PAD_COORD),
                    retries=cfg.io_retries,
                    backoff_s=cfg.io_retry_backoff_s, stats=self.stats)
                self._warm[b] = (slot, n)
                self._warm.move_to_end(b, last=False)
                faulted += 1
            if faulted:
                self.stats.add("warm_prefaults", faulted)

    # -- telemetry / lifecycle ------------------------------------------------
    def pipeline_snapshot(self) -> dict:
        """The session's single PipelineStats snapshot: batch-join loads
        and online query reads appear in one surface."""
        return self.stats.snapshot()

    def io_snapshot(self) -> dict:
        return self.store.stats.snapshot()

    def metrics_snapshot(self) -> dict:
        """The session's full metrics surface (``repro.obs``): registered
        instruments plus the pipeline/io provider sections — and whatever
        services (scheduler, query service) registered on top."""
        return self.metrics.snapshot()

    def merge_build_timings(self, timings: dict) -> dict:
        """Fold this index's (amortized) build cost into a result's
        timings — the deprecated one-shot wrappers use this to keep the
        legacy "bucketing included" schema."""
        sub = dict(self.build_timings)
        layout_s = sub.pop("layout_plan", 0.0)
        t = dict(timings)
        t["bucketing"] = t.get("bucketing", 0.0) + self.build_seconds \
            - layout_s
        for k, v in sub.items():
            t[f"bucketing/{k}"] = t.get(f"bucketing/{k}", 0.0) + v
        if layout_s:
            t["orchestration"] = t.get("orchestration", 0.0) + layout_s
            t["orchestration/layout_plan"] = \
                t.get("orchestration/layout_plan", 0.0) + layout_s
        return t

    def close(self) -> None:
        """Release the session: warm slabs, pool, store handles. The
        on-disk index remains and can be re-``open``ed."""
        if self._closed:
            return
        self._closed = True
        if self._live is not None:
            try:
                self.detach_live()
            except Exception:
                pass  # observability teardown must not block release
        if self._residency_committer is not None:
            try:
                self.disable_residency_snapshots()
            except Exception:
                pass  # a failed last snapshot is re-raised there; the
                #       close() below still writes a fresh one inline
        with self._warm_lock:
            if self._pool is not None:
                # snapshot BEFORE dropping: the warm set is the restart's
                # pre-fault list (ft "serving fast restart")
                self.save_residency_snapshot()
                self._drop_warm_locked()
        if self._pool is not None:
            self._pool.close()
        self.store.close()

    def __enter__(self) -> "DiskJoinIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
