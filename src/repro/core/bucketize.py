"""Streaming, memory-bounded vector bucketization (paper §5.1).

Three sequential dataset scans, all block-granular (no read amplification):

  1. *Sample*   — stream X, collect the pre-drawn sample ids as centers.
  2. *Assign*   — stream X in blocks; nearest-center search per block via the
                  center index (matmul / Pallas kernel); record assignment,
                  per-bucket counts and radii (only counters stay in memory).
  3. *Write*    — stream X again, appending each vector to its bucket's
                  buffered extent in the reorganized store (per-bucket
                  write buffers avoid write amplification).

Memory high-water mark: centers (≈1‰–1% of data) + index + block buffer +
per-bucket write buffers — matches the paper's "minimum ≈2% of dataset".
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.center_index import make_center_index
from repro.core.types import BucketMeta, JoinConfig
from repro.kernels import ops as kops
from repro.store.striped_store import (COALESCE_STRIPE_CHUNK,
                                       StripedBucketedVectorStore)
from repro.store.vector_store import BucketedVectorStore, FlatVectorStore


def sample_centers(store: FlatVectorStore, num_centers: int,
                   seed: int, block_rows: int) -> np.ndarray:
    """Scan 1: random center sample via pre-drawn ids, sequential stream."""
    rng = np.random.default_rng(seed)
    ids = np.sort(rng.choice(store.num_vectors, size=num_centers,
                             replace=False))
    centers = np.empty((num_centers, store.dim), dtype=np.float32)
    filled = 0
    ptr = 0
    for start, block in store.iter_blocks(block_rows):
        end = start + block.shape[0]
        while ptr < num_centers and ids[ptr] < end:
            centers[filled] = block[ids[ptr] - start]
            filled += 1
            ptr += 1
        if ptr >= num_centers:
            break
    assert filled == num_centers
    return centers


def assign_blocks(store: FlatVectorStore, centers: np.ndarray,
                  block_rows: int, use_pallas: bool = False
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Scan 2: nearest-center assignment → (assignment, per-vector d²)."""
    assignment = np.empty(store.num_vectors, dtype=np.int64)
    dist_sq = np.empty(store.num_vectors, dtype=np.float32)
    index = make_center_index(centers)
    for start, block in store.iter_blocks(block_rows):
        if use_pallas and hasattr(index, "_centers_dev"):
            d2, idx = kops.bucket_assign(block.astype(np.float32), centers)
            d2, idx = np.asarray(d2), np.asarray(idx)
        else:
            d2, idx = index.assign(block.astype(np.float32))
        assignment[start:start + block.shape[0]] = idx
        dist_sq[start:start + block.shape[0]] = d2
    return assignment, dist_sq


def split_oversized(assignment: np.ndarray, centers: np.ndarray,
                    max_rows: int) -> tuple[np.ndarray, np.ndarray]:
    """Split buckets above ``max_rows`` into sub-buckets sharing the center.

    Bounds the fixed-shape kernel's padding waste under cluster skew.
    Sub-buckets keep the parent's center (the bucket graph links them via
    zero-distance candidate edges, so no pairs are lost).
    """
    sizes = np.bincount(assignment, minlength=centers.shape[0])
    new_centers = []
    remap_base: dict[int, int] = {}
    for b, s in enumerate(sizes):
        remap_base[b] = len(new_centers)
        for _ in range(max(1, -(-int(s) // max_rows))):
            new_centers.append(centers[b])
    new_assignment = np.empty_like(assignment)
    counter = np.zeros(centers.shape[0], dtype=np.int64)
    for i, b in enumerate(assignment):
        sub = counter[b] // max_rows
        counter[b] += 1
        new_assignment[i] = remap_base[int(b)] + sub
    return new_assignment, np.asarray(new_centers, dtype=np.float32)


def write_buckets(store: FlatVectorStore, out_path: str,
                  assignment: np.ndarray, sizes: np.ndarray,
                  centers: np.ndarray, radii: np.ndarray,
                  block_rows: int, layout_order: np.ndarray | None = None,
                  num_devices: int = 1, stripe_by: str = "phase",
                  stripe_chunk: int = 1):
    """Scan 3: stream X, append to per-bucket buffered extents.

    ``layout_order`` places bucket extents in Gorder/schedule order so
    schedule-adjacent buckets are disk-adjacent (read coalescing);
    ``num_devices > 1`` stripes the extents over that many backing files
    (``StripedBucketedVectorStore``).
    """
    if num_devices > 1:
        writer = StripedBucketedVectorStore.create(
            out_path, store.dim, np.float32, sizes, centers, radii,
            num_devices=num_devices, stats=store.stats,
            layout_order=layout_order, stripe_by=stripe_by,
            stripe_chunk=stripe_chunk)
    else:
        writer = BucketedVectorStore.create(
            out_path, store.dim, np.float32, sizes, centers, radii,
            stats=store.stats, layout_order=layout_order)
    for start, block in store.iter_blocks(block_rows):
        blk_assign = assignment[start:start + block.shape[0]]
        # group within the block to batch appends per bucket
        order = np.argsort(blk_assign, kind="stable")
        sorted_assign = blk_assign[order]
        boundaries = np.flatnonzero(np.diff(sorted_assign)) + 1
        for seg in np.split(np.arange(len(order)), boundaries):
            if seg.size == 0:
                continue
            b = int(sorted_assign[seg[0]])
            rows = order[seg]
            writer.append_batch(b, block[rows].astype(np.float32),
                                start + rows)
    return writer.finalize()


def bucketize(store: FlatVectorStore, out_path: str, config: JoinConfig,
              layout_order_fn=None, sketch_sink=None, phase_log=None
              ) -> tuple["BucketedVectorStore | StripedBucketedVectorStore",
                         BucketMeta, dict]:
    """Full 3-scan bucketization → (bucketed store, metadata, timings).

    ``config`` may be a flat ``JoinConfig`` or a bare ``BuildConfig`` —
    bucketization consumes only build-time parameters (query-time knobs
    like ``use_pallas``/``emulate_read_latency_s`` are read leniently,
    defaulting off).

    ``layout_order_fn(meta) -> np.ndarray | None``: called once the final
    bucket metadata is known, *before* the write scan — returns the disk
    layout order (typically the join's Gorder node order, see
    ``ordering.compute_node_order``) so the writer can make
    schedule-adjacent buckets disk-adjacent. Striping (``config.io_devices
    > 1``) applies whether or not a layout order is supplied.

    ``sketch_sink(assignment, num_buckets) -> None``: called with the
    FINAL assignment (after oversize splitting and empty-bucket
    compaction) so the planner's cardinality sketch can sample the flat
    store directly — at build time the bucketed store doesn't exist yet,
    and resampling it later would pay one read per bucket.

    ``phase_log``: a ``repro.ft.PhaseLog`` making the build resumable —
    the sample and assign scans commit their outputs when they finish,
    and a restarted build (same config fingerprint) loads the committed
    arrays instead of rescanning the flat store (the skipped scans report
    0.0 in ``timings``).
    """
    timings: dict[str, float] = {}
    n_buckets = config.resolve_num_buckets(store.num_vectors)

    if phase_log is not None and phase_log.has("sample"):
        centers = phase_log.load_arrays("sample")["centers"]
        timings["sample"] = 0.0
    else:
        t0 = time.perf_counter()
        centers = sample_centers(store, n_buckets, config.seed,
                                 config.block_rows)
        timings["sample"] = time.perf_counter() - t0
        if phase_log is not None:
            phase_log.commit_arrays("sample", centers=centers)

    if phase_log is not None and phase_log.has("assign"):
        arrs = phase_log.load_arrays("assign")
        assignment, dist_sq = arrs["assignment"], arrs["dist_sq"]
        timings["assign"] = 0.0
    else:
        t0 = time.perf_counter()
        assignment, dist_sq = assign_blocks(
            store, centers, config.block_rows,
            use_pallas=getattr(config, "use_pallas", False))
        timings["assign"] = time.perf_counter() - t0
        if phase_log is not None:
            phase_log.commit_arrays("assign", assignment=assignment,
                                    dist_sq=dist_sq)

    max_rows = config.max_bucket_rows
    if max_rows is None:
        avg = max(1, store.num_vectors // n_buckets)
        max_rows = max(config.pad_align,
                       ((2 * avg + config.pad_align - 1)
                        // config.pad_align) * config.pad_align)
    assignment, centers = split_oversized(assignment, centers, max_rows)
    n_buckets = centers.shape[0]

    # per-bucket stats over final (possibly split) buckets
    sizes = np.bincount(assignment, minlength=n_buckets).astype(np.int64)
    radii_sq = np.zeros(n_buckets, dtype=np.float64)
    np.maximum.at(radii_sq, assignment, dist_sq.astype(np.float64))
    radii = np.sqrt(np.maximum(radii_sq, 0.0)).astype(np.float32)

    # drop empty buckets (random sampling can orphan a center)
    nonempty = sizes > 0
    if not nonempty.all():
        remap = -np.ones(n_buckets, dtype=np.int64)
        remap[nonempty] = np.arange(int(nonempty.sum()))
        assignment = remap[assignment]
        centers, sizes, radii = (centers[nonempty], sizes[nonempty],
                                 radii[nonempty])

    meta = BucketMeta(centers=centers, radii=radii, sizes=sizes)

    if sketch_sink is not None:
        t0 = time.perf_counter()
        sketch_sink(assignment, int(centers.shape[0]))
        timings["sketch"] = time.perf_counter() - t0

    layout_order = None
    if layout_order_fn is not None:
        t0 = time.perf_counter()
        layout_order = layout_order_fn(meta)
        timings["layout_plan"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    # under coalescing, chunked phase striping keeps schedule-adjacent
    # buckets on one device (coalescible) while chunks rotate devices;
    # without it, chunk 1 maximizes per-miss device fan-out
    stripe_chunk = (COALESCE_STRIPE_CHUNK if config.io_coalesce else 1)
    bstore = write_buckets(store, out_path, assignment, sizes, centers,
                           radii, config.block_rows,
                           layout_order=layout_order,
                           num_devices=config.io_devices,
                           stripe_by=config.io_stripe_by,
                           stripe_chunk=stripe_chunk)
    timings["write"] = time.perf_counter() - t0
    bstore.read_latency_s = getattr(config, "emulate_read_latency_s", 0.0)

    return bstore, meta, timings
