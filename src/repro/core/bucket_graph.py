"""Bucket dependency graph construction (paper §3 + §5.2).

For each bucket b: fetch its L nearest candidate buckets from the center
index, keep those passing the triangle-inequality test (Eq. 1)

    ‖c_i − c_j‖ − r_i − r_j ≤ ε,

then apply probabilistic pruning (Eq. 3) against the recall budget. Edges
are directed i→j with i<j (symmetric distance ⇒ each pair once).
"""
from __future__ import annotations

import numpy as np

from repro.core.center_index import make_center_index
from repro.core.pruning import prune_candidates
from repro.core.types import BucketGraph, BucketMeta, JoinConfig


def build_bucket_graph(meta: BucketMeta, config: JoinConfig) -> BucketGraph:
    B, d = meta.centers.shape
    index = make_center_index(meta.centers)
    L = min(config.max_candidates + 1, B)  # +1: self comes back first
    dists_sq, cand_ids = index.search(meta.centers, L)
    dists = np.sqrt(np.maximum(dists_sq, 0.0))

    edges: list[tuple[int, int]] = []
    eps = float(config.epsilon)
    for b in range(B):
        ids = cand_ids[b]
        dd = dists[b]
        mask = ids != b
        ids, dd = ids[mask], dd[mask]
        # Eq. 1 triangle-inequality prefilter
        tri = dd - meta.radii[b] - meta.radii[ids] <= eps
        ids, dd = ids[tri], dd[tri]
        if config.prune and ids.size:
            keep = prune_candidates(dd, float(meta.radii[b]) + eps, d,
                                    config.recall_target)
            ids = ids[keep]
        for j in ids:
            edges.append((min(b, int(j)), max(b, int(j))))

    if edges:
        e = np.unique(np.asarray(edges, dtype=np.int64), axis=0)
    else:
        e = np.zeros((0, 2), dtype=np.int64)
    return BucketGraph(num_nodes=B, edges=e)


def candidate_pair_count(graph: BucketGraph, meta: BucketMeta) -> int:
    """#candidate vector pairs implied by the graph (Fig. 18 statistic)."""
    s = meta.sizes.astype(np.int64)
    total = int(np.sum(s * (s - 1) // 2))  # intra-bucket (implicit self edges)
    if graph.num_edges:
        total += int(np.sum(s[graph.edges[:, 0]] * s[graph.edges[:, 1]]))
    return total
