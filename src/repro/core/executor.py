"""Task execution engine (paper §3 "task execution").

Replays the orchestration schedule: walks the edge order, keeps the HBM
bucket cache in sync with the cache schedule (load on miss, evict the
designated victim), and verifies bucket pairs with the pairwise-distance
kernel. Intra-bucket pairs are verified on each bucket's first touch.

Fixed shapes: every bucket is padded to ``bucket_capacity`` rows (MXU-
aligned) so the verify kernel compiles exactly once. Padded rows sit at +∞
distance (coordinates 1e15) and can never pass the ε threshold.

Batched dispatch: edges are accumulated into ``JoinConfig.verify_batch``-
sized batches and verified by a verify engine (``repro.compute``) with one
batched kernel call per flush (cache-evicted slabs stay alive via the
pending batch's references — Python refs in sync mode, buffer-pool pins in
prefetch mode, immutable device arrays in device compute mode — so
batching never races the eviction schedule).

I/O modes (``JoinConfig.io_mode``): ``"sync"`` reads every missed bucket
inline; ``"prefetch"`` consumes slabs from ``repro.io``'s schedule-driven
prefetcher, overlapping SSD reads with verification.

Compute modes (``JoinConfig.compute_mode``): ``"host"`` stages operands
per batch and extracts pairs from fetched masks; ``"device"`` keeps slabs
device-resident per cache residency, double-buffers dispatch and
compacts pairs on-device (``repro.compute``). All four combinations
replay the same cache schedule and produce byte-identical results.
"""
from __future__ import annotations

import time

import numpy as np

from repro.compute import make_verify_engine
from repro.core import cache as cache_mod
from repro.obs import get_tracer
from repro.core import ordering
from repro.core.types import (BucketGraph, BucketMeta, JoinConfig,
                              JoinResult, dedup_pairs,
                              resolve_bucket_capacity, resolve_cache_buckets)
from repro.store.vector_store import BucketedVectorStore

PAD_COORD = 1e15  # padded rows: astronomically far from everything


class BucketCache:
    """Padded bucket slabs (host staging), driven by the cache schedule.

    The sync I/O backend: ``load`` reads inline on the executor thread.
    Shares the ``checkout``/``release`` surface with
    ``repro.io.PrefetchedBucketCache`` (here release is a no-op — Python
    references keep evicted slabs alive for pending verify batches).
    """

    def __init__(self, store: BucketedVectorStore, capacity_rows: int,
                 retries: int = 0, retry_backoff_s: float = 0.005,
                 stats=None):
        self.store = store
        self.capacity_rows = capacity_rows
        self.retries = max(0, int(retries))
        self.retry_backoff_s = float(retry_backoff_s)
        self.stats = stats
        self._slabs: dict[int, tuple[np.ndarray, np.ndarray, int]] = {}
        self.loads = 0

    def __contains__(self, b: int) -> bool:
        return b in self._slabs

    load_issued = True  # sync loads never need a pipeline to catch up

    def load(self, b: int) -> None:
        from repro.io.retry import read_with_retry
        vecs, ids = read_with_retry(
            lambda: self.store.read_bucket(b), retries=self.retries,
            backoff_s=self.retry_backoff_s, stats=self.stats)
        n = vecs.shape[0]
        pad = self.capacity_rows - n
        if pad > 0:
            vecs = np.concatenate(
                [vecs, np.full((pad, vecs.shape[1]), PAD_COORD, vecs.dtype)])
        self._slabs[b] = (np.asarray(vecs, np.float32), ids, n)
        self.loads += 1

    def evict(self, b: int) -> None:
        self._slabs.pop(b, None)

    def get(self, b: int):
        return self._slabs[b]

    def rows(self, b: int) -> int:
        return self._slabs[b][2]

    def checkout(self, b: int):
        vecs, ids, n = self._slabs[b]
        return (vecs, ids, n, None)

    def release(self, entry) -> None:
        pass

    def close(self) -> None:
        pass

    @property
    def resident(self) -> int:
        return len(self._slabs)


class JoinExecutor:
    intra_join = True  # cross-join subclass disables intra-bucket pairs

    def __init__(self, store: BucketedVectorStore, meta: BucketMeta,
                 config: JoinConfig,
                 attribute_mask: np.ndarray | None = None,
                 shared_pool=None, shared_stats=None, tracer=None,
                 planner=None):
        """``attribute_mask``: (N,) bool — attribute filtering (paper §3
        extension): vectors failing the predicate are excluded from
        verification via a bitmap, before any distance is computed.

        ``shared_pool`` / ``shared_stats``: a ``DiskJoinIndex`` session's
        lifetime ``BufferPool`` and ``PipelineStats`` — batch joins and
        online point queries then share one memory budget and one
        telemetry surface. The pool is used only when its slab shape and
        size fit this run (otherwise a private pool is created; the stats
        are shared regardless).

        ``planner``: a ``repro.plan.Planner`` (usually the index
        session's) consulted when ``config.plan_mode == "on"``; with
        plan_mode on and no planner supplied, one is built lazily by
        sampling the bucketed store (the one-shot / cross-join path)."""
        self.store = store
        self.meta = meta
        self.config = config
        self.attribute_mask = attribute_mask
        self.shared_pool = shared_pool
        self.shared_stats = shared_stats
        self.planner = planner
        self.tracer = tracer if tracer is not None else get_tracer()
        cap = resolve_bucket_capacity(config, meta.sizes)
        self.bucket_capacity = cap
        self.padded_bucket_bytes = cap * store.dim * 4
        self.cache_buckets = resolve_cache_buckets(config, cap, store.dim)

    # -- orchestration -------------------------------------------------------
    def plan(self, graph: BucketGraph, node_order: np.ndarray | None = None):
        """Gorder (optional) → edge order → access seq → cache schedule.

        ``node_order`` short-circuits the ordering step when the caller
        already planned it (e.g. the disk-layout pass in ``bucketize``) —
        identical by construction since both go through
        ``ordering.compute_node_order``.
        """
        t0 = time.perf_counter()
        with self.tracer.span("join.plan", edges=graph.num_edges,
                              buckets=graph.num_nodes):
            if node_order is None:
                node_order = ordering.compute_node_order(
                    graph, self.meta, self.config, self.cache_buckets)
            tasks, access_seq, pins = ordering.edge_schedule(graph,
                                                            node_order)
            schedule = cache_mod.simulate_policy(
                access_seq, graph.num_nodes, self.cache_buckets,
                self.config.eviction_policy, pins)
        plan_seconds = time.perf_counter() - t0
        return tasks, access_seq, schedule, plan_seconds

    # -- execution -----------------------------------------------------------
    def _make_cache(self, schedule):
        """Cache backend per JoinConfig.io_mode (+ pipeline stats or None)."""
        if self.config.io_mode != "prefetch":
            stats = self.shared_stats
            if stats is None and self.config.compute_mode != "host":
                # device telemetry (h2d/compaction counters) needs a
                # stats surface even without the prefetch pipeline
                from repro.io import PipelineStats
                stats = PipelineStats()
            return BucketCache(self.store, self.bucket_capacity,
                               retries=self.config.io_retries,
                               retry_backoff_s=self.config.io_retry_backoff_s,
                               stats=stats), stats
        from repro.io import PipelineStats, PrefetchedBucketCache
        cap_buckets = min(self.cache_buckets, self.meta.num_buckets or 1)
        pool_slabs = self.config.io_pool_slabs
        if pool_slabs is None:
            pool_slabs = cap_buckets + self.config.io_lookahead
        pool_slabs = max(pool_slabs, cap_buckets + 1)  # liveness floor
        stats = (self.shared_stats if self.shared_stats is not None
                 else PipelineStats())
        pool = self.shared_pool
        if pool is not None and (pool.capacity_rows != self.bucket_capacity
                                 or pool.dim != self.store.dim
                                 or pool.num_slabs < pool_slabs):
            pool = None  # session pool doesn't fit this run: go private
        cache = PrefetchedBucketCache(
            self.store, self.bucket_capacity, schedule.actions,
            lookahead=self.config.io_lookahead, pool_slabs=pool_slabs,
            num_threads=self.config.io_threads, pad_value=PAD_COORD,
            batch_reads=self.config.io_batch_reads,
            coalesce=self.config.io_coalesce, stats=stats, pool=pool,
            tracer=self.tracer, retries=self.config.io_retries,
            retry_backoff_s=self.config.io_retry_backoff_s)
        return cache, stats

    def _resolve_planner(self, pstats):
        """The session planner when given, else (plan_mode on) a lazily
        built one sampling this executor's store — the one-shot and
        cross-join paths, whose stores have no persisted sketch."""
        if self.planner is not None or self.config.plan_mode != "on":
            return self.planner
        from repro.plan import CardinalityEstimator, CostModel, Planner
        est = CardinalityEstimator.sample_bucketed(
            self.store, self.meta.sizes, seed=self.config.seed)
        cost = CostModel.from_telemetry(
            self.config, pstats.snapshot() if pstats is not None else None)
        self.planner = Planner(est, cost, tracer=self.tracer,
                               pstats=pstats)
        return self.planner

    def run(self, graph: BucketGraph,
            node_order: np.ndarray | None = None) -> JoinResult:
        tasks, access_seq, schedule, plan_seconds = self.plan(graph,
                                                             node_order)
        cache, pstats = self._make_cache(schedule)
        # on a session's lifetime stats, this run's result must still
        # report per-run numbers: diff against a baseline at the end
        pstats_base = (pstats.snapshot() if pstats is not None
                       and self.shared_stats is not None else None)
        jplan = None
        if self.config.plan_mode == "on":
            planner = self._resolve_planner(pstats)
            jplan = planner.plan_join(tasks, schedule.actions, self.meta,
                                      self.config, self.bucket_capacity,
                                      intra_join=self.intra_join)
        engine = make_verify_engine(self.config, cache,
                                    self.bucket_capacity, self.store.dim,
                                    attribute_mask=self.attribute_mask,
                                    pstats=pstats, tracer=self.tracer,
                                    plan=jplan)

        tracer = self.tracer
        run_span = tracer.span("join.run", edges=graph.num_edges,
                               io_mode=self.config.io_mode,
                               compute_mode=self.config.compute_mode)
        run_span.__enter__()
        t0 = time.perf_counter()
        ai = 0  # index into access_seq / schedule.actions
        actions = schedule.actions
        io_wait = 0.0   # executor time blocked in cache.load

        def ensure(b: int) -> None:
            nonlocal io_wait
            nonlocal ai
            bb, is_hit, victim = actions[ai]
            assert bb == b, f"schedule desync at access {ai}: {bb} != {b}"
            ai += 1
            if not is_hit:
                if victim is not None:
                    cache.evict(victim)
                    engine.evict(victim)
                if not cache.load_issued:
                    # prefetcher is behind AND may be blocked on the pool:
                    # flush pending pins so a slab frees up (liveness)
                    if engine.pending and pstats is not None:
                        pstats.add("flush_on_stall", 1)
                    engine.flush()
                t0 = time.perf_counter()
                cache.load(b)
                dt = time.perf_counter() - t0
                io_wait += dt
                # same interval as the io_wait accumulator (see
                # tracer.complete): hidden_fraction("io.read", "io.wait")
                # must agree with overlap_efficiency by construction
                tracer.complete("io.wait", t0, dt, bucket=b)

        # plan cursor: unit_params is in exact enqueue order (the planner
        # replayed this same task walk), so consumption is a single index
        ui = 0
        unit_params = jplan.unit_params if jplan is not None else None

        def tune() -> None:
            nonlocal ui
            route, vb = unit_params[ui]
            ui += 1
            engine.set_route(route)
            engine.set_verify_batch(vb)

        try:
            for task in tasks:
                if task[0] == "touch":
                    b = int(task[1])
                    ensure(b)
                    if self.intra_join and cache.rows(b) >= 2:
                        if unit_params is not None:
                            tune()
                        engine.enqueue(b, b, True)
                else:
                    _, u, v = task
                    ensure(int(u))
                    ensure(int(v))
                    if unit_params is not None:
                        tune()
                    engine.enqueue(int(u), int(v), False)
            engine.finish()
        finally:
            engine.abort()
            cache.close()
            run_span.__exit__(None, None, None)
        exec_seconds = time.perf_counter() - t0
        compute_t = engine.compute_s  # engine time in stage/dispatch/extract

        pairs_list, dists_list = engine.results()
        if pairs_list:
            pairs, dists = dedup_pairs(np.concatenate(pairs_list),
                                       np.concatenate(dists_list))
        else:
            pairs = np.zeros((0, 2), np.int64)
            dists = np.zeros(0, np.float32)

        io_stats = self.store.stats.snapshot()
        timings = {"plan": plan_seconds, "execute": exec_seconds,
                   "io_wait": io_wait, "compute": compute_t}
        if pstats is not None:
            pstats.add("io_wait_s", io_wait)
            pstats.add("compute_s", compute_t)
            if self.config.io_mode != "prefetch":
                # prefetch-mode loads are counted at pop_next; count sync
                # loads here so a session's stats see both join kinds
                pstats.add("loads", cache.loads)
            io_stats["pipeline"] = (pstats.snapshot_since(pstats_base)
                                    if pstats_base is not None
                                    else pstats.snapshot())

        from repro.core.bucket_graph import candidate_pair_count
        return JoinResult(
            pairs=pairs, distances=dists,
            num_distance_computations=engine.dc,
            num_candidate_pairs=candidate_pair_count(graph, self.meta),
            cache_hits=schedule.hits, cache_misses=schedule.misses,
            bucket_loads=cache.loads,
            io_stats=io_stats,
            timings=timings,
            plan=jplan,
        )
