"""DiskJoin core — the paper's contribution as a composable JAX module.

Public API:
  DiskJoinIndex                    — build-once / query-many session:
                                     build/open, self_join, cross_join,
                                     online query/query_batch
  JoinConfig, JoinResult           — task configuration / output
  BuildConfig, QueryConfig         — build-time vs query-time split of
                                     JoinConfig (split_config/merge_config)
  similarity_self_join             — one-shot SSJ (deprecated wrapper)
  similarity_cross_join            — one-shot bipartite join (deprecated)
  bucketize / build_bucket_graph   — pipeline stages, individually usable
  gorder / simulate_policy         — orchestration primitives (Fig. 17)
"""
from repro.core.bucket_graph import build_bucket_graph, candidate_pair_count
from repro.core.bucketize import bucketize
from repro.core.cache import CacheSchedule, simulate_belady, simulate_policy
from repro.core.executor import JoinExecutor
from repro.core.index import DiskJoinIndex
from repro.core.join import similarity_cross_join, similarity_self_join
from repro.core.ordering import edge_schedule, gorder, window_size
from repro.core.pruning import cap_constant, miss_bound_terms, prune_candidates
from repro.core.types import (BUILD_TIME_FIELDS, QUERY_TIME_FIELDS,
                              TIMING_KEYS, BucketGraph, BucketMeta,
                              BuildConfig, JoinConfig, JoinResult,
                              QueryConfig, canonicalize_pairs, dedup_pairs,
                              finalize_timings, merge_config, recall,
                              split_config)

__all__ = [
    "BUILD_TIME_FIELDS", "BucketGraph", "BucketMeta", "BuildConfig",
    "CacheSchedule", "DiskJoinIndex", "JoinConfig", "JoinExecutor",
    "JoinResult", "QUERY_TIME_FIELDS", "QueryConfig", "TIMING_KEYS",
    "bucketize", "build_bucket_graph", "candidate_pair_count",
    "canonicalize_pairs", "cap_constant", "dedup_pairs", "edge_schedule",
    "finalize_timings", "gorder", "merge_config", "miss_bound_terms",
    "prune_candidates", "recall", "similarity_cross_join",
    "similarity_self_join", "simulate_belady", "simulate_policy",
    "split_config", "window_size",
]
