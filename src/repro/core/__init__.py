"""DiskJoin core — the paper's contribution as a composable JAX module.

Public API:
  JoinConfig, JoinResult           — task configuration / output
  similarity_self_join             — SSJ over an on-disk dataset
  similarity_cross_join            — bipartite join over two datasets
  bucketize / build_bucket_graph   — pipeline stages, individually usable
  gorder / simulate_policy         — orchestration primitives (Fig. 17)
"""
from repro.core.bucket_graph import build_bucket_graph, candidate_pair_count
from repro.core.bucketize import bucketize
from repro.core.cache import CacheSchedule, simulate_belady, simulate_policy
from repro.core.executor import JoinExecutor
from repro.core.join import similarity_cross_join, similarity_self_join
from repro.core.ordering import edge_schedule, gorder, window_size
from repro.core.pruning import cap_constant, miss_bound_terms, prune_candidates
from repro.core.types import (BucketGraph, BucketMeta, JoinConfig, JoinResult,
                              canonicalize_pairs, dedup_pairs, recall)

__all__ = [
    "BucketGraph", "BucketMeta", "CacheSchedule", "JoinConfig",
    "JoinExecutor", "JoinResult", "bucketize", "build_bucket_graph",
    "candidate_pair_count", "canonicalize_pairs", "cap_constant",
    "dedup_pairs", "edge_schedule", "gorder", "miss_bound_terms",
    "prune_candidates", "recall", "similarity_cross_join",
    "similarity_self_join", "simulate_belady", "simulate_policy",
    "window_size",
]
