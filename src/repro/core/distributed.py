"""Distributed DiskJoin execution over a JAX mesh (DESIGN §5).

Mapping of the paper's single-box design onto a pod:

  SSD               → host-side bucketed store (per-host shard of buckets)
  DRAM cache        → per-superstep device slab: the Gorder window's buckets,
                      assembled by the host under the same Belady policy,
                      then placed sharded over the ``data`` axis
  edge tasks        → sharded over ``data``: each device verifies its slice
                      of the window's edges; remote buckets arrive via the
                      gather XLA inserts for cross-shard ``jnp.take``
  verify kernel     → vmapped pairwise-L2 threshold (Pallas on TPU)

Supersteps inherit the Gorder locality: consecutive windows share most of
their buckets, so the host cache (Belady) converts that into fewer
host→device transfers — the pod analogue of fewer SSD reads.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ordering
from repro.core.executor import PAD_COORD
from repro.core.types import (BucketGraph, BucketMeta, JoinConfig,
                              dedup_pairs, resolve_bucket_capacity,
                              resolve_cache_buckets, round_up as _round_up)
from repro.kernels import ref


@partial(jax.jit, static_argnames=("eps2",))
def verify_edges(slab: jax.Array, edges: jax.Array, eps2: float):
    """slab: (W, cap, d) window bucket slab; edges: (E, 2) int32 into slab.

    Returns (counts (E,), mask (E, cap, cap) bool). Under pjit with edges
    sharded over ``data``, the slab gathers become collectives.
    """
    u = jnp.take(slab, edges[:, 0], axis=0)      # (E, cap, d)
    v = jnp.take(slab, edges[:, 1], axis=0)
    d2 = jax.vmap(ref.pairwise_l2)(u, v)         # (E, cap, cap)
    mask = d2 <= eps2
    return jnp.sum(mask, axis=(1, 2)), mask


@dataclasses.dataclass
class Superstep:
    bucket_ids: np.ndarray   # (W,) global bucket ids in this window
    edges_local: np.ndarray  # (E, 2) int32 indices into bucket_ids
    edges_global: np.ndarray  # (E, 2) original bucket ids


def plan_supersteps(graph: BucketGraph, config: JoinConfig,
                    cache_buckets: int,
                    meta: BucketMeta) -> list[Superstep]:
    """Gorder → windows of ≤cache_buckets buckets covering all edges.

    Each edge lands in the first window containing both endpoints; the
    window advances greedily along the node order (self-pairs implicit —
    every bucket appears in ≥1 window). The order comes from
    ``ordering.compute_node_order`` (shared with the single-box executor,
    incl. the spatial strategy).
    """
    node_order = ordering.compute_node_order(graph, meta, config,
                                             cache_buckets)
    tasks, _, _ = ordering.edge_schedule(graph, node_order)

    steps: list[Superstep] = []
    cur_buckets: list[int] = []
    cur_edges: list[tuple[int, int]] = []
    seen: dict[int, int] = {}

    def flush():
        nonlocal cur_buckets, cur_edges, seen
        if not cur_buckets:
            return
        bids = np.asarray(cur_buckets, dtype=np.int64)
        eg = (np.asarray(cur_edges, dtype=np.int64)
              if cur_edges else np.zeros((0, 2), np.int64))
        el = np.stack([[seen[int(a)] for a, _ in cur_edges],
                       [seen[int(b)] for _, b in cur_edges]], axis=1
                      ).astype(np.int32) if cur_edges else \
            np.zeros((0, 2), np.int32)
        steps.append(Superstep(bids, el, eg))
        cur_buckets, cur_edges, seen = [], [], {}

    cap = max(2, cache_buckets)
    for t in tasks:
        need = [t[1]] if t[0] == "touch" else [t[1], t[2]]
        new = [b for b in need if int(b) not in seen]
        if len(cur_buckets) + len(new) > cap:
            flush()
            new = need
        for b in need:
            b = int(b)
            if b not in seen:
                seen[b] = len(cur_buckets)
                cur_buckets.append(b)
        if t[0] == "touch":
            cur_edges.append((int(t[1]), int(t[1])))  # self edge
        else:
            cur_edges.append((int(t[1]), int(t[2])))
    flush()
    return steps


class DistributedJoin:
    """Superstep-wise distributed execution of a planned join.

    ``mesh`` must have a ``data`` axis; edges shard over it. The host keeps
    a Belady-managed slab cache so consecutive supersteps reuse transfers.
    """

    def __init__(self, store, meta: BucketMeta, config: JoinConfig,
                 mesh: jax.sharding.Mesh | None = None):
        self.store = store
        self.meta = meta
        self.config = config
        self.mesh = mesh
        self.cap = resolve_bucket_capacity(config, meta.sizes)
        self.cache_buckets = resolve_cache_buckets(config, self.cap,
                                                   store.dim)
        self._host_cache: dict[int, np.ndarray] = {}
        self.loads = 0
        self.hits = 0

    def _fetch(self, b: int) -> tuple[np.ndarray, np.ndarray, int]:
        if b in self._host_cache:
            self.hits += 1
            return self._host_cache[b]
        vecs, ids = self.store.read_bucket(b)
        n = vecs.shape[0]
        pad = self.cap - n
        if pad > 0:
            vecs = np.concatenate(
                [vecs, np.full((pad, vecs.shape[1]), PAD_COORD, vecs.dtype)])
        entry = (vecs.astype(np.float32), ids, n)
        self._host_cache[b] = entry
        self.loads += 1
        return entry

    def _evict_to(self, keep: set[int]) -> None:
        # host cache follows the superstep plan: keep only upcoming window
        # + LRU slack up to capacity (Belady degenerate form: the plan IS
        # the future, and the next window is the nearest future access)
        if len(self._host_cache) <= self.cache_buckets:
            return
        for b in list(self._host_cache.keys()):
            if b not in keep and len(self._host_cache) > self.cache_buckets:
                del self._host_cache[b]

    def run(self, graph: BucketGraph):
        eps2 = float(self.config.epsilon) ** 2
        steps = plan_supersteps(graph, self.config, self.cache_buckets,
                                meta=self.meta)
        pairs_out, dists_out = [], []
        sharding = None
        if self.mesh is not None:
            sharding = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec("data"))

        dc = 0
        for si, step in enumerate(steps):
            edges = step.edges_local
            if edges.shape[0] == 0:
                continue  # defensive: planner always pairs buckets w/ edges
            entries = [self._fetch(int(b)) for b in step.bucket_ids]
            slab = jnp.asarray(np.stack([e[0] for e in entries]))
            # pad edge count to shard evenly; padding repeats edge 0 whose
            # results are sliced off
            E = edges.shape[0]
            if sharding is not None:
                n_shards = self.mesh.shape["data"]
                Ep = _round_up(E, n_shards)
                if Ep != E:
                    edges = np.concatenate(
                        [edges, np.repeat(edges[:1], Ep - E, axis=0)])
                edges_dev = jax.device_put(jnp.asarray(edges), sharding)
            else:
                edges_dev = jnp.asarray(edges)
            counts, mask = verify_edges(slab, edges_dev, eps2)
            mask = np.asarray(mask)[:E]
            dc += sum(
                (entries[a][2] * entries[b][2]) if a != b
                else entries[a][2] * (entries[a][2] - 1) // 2
                for a, b in edges[:E])
            d2 = None
            for ei, (a, b) in enumerate(edges[:E]):
                na, nb = entries[a][2], entries[b][2]
                m = mask[ei][:na, :nb]
                if a == b:
                    m = np.triu(m, k=1)
                rows, cols = np.nonzero(m)
                if rows.size:
                    ida, idb = entries[a][1], entries[b][1]
                    pairs_out.append(
                        np.stack([ida[rows], idb[cols]], axis=1))
            # keep-set is the *upcoming* window: evicting on the finished
            # window's set discards exactly the slabs superstep w+1 reuses
            # (e.g. buckets loaded in w-1 that skip w and return in w+1),
            # while keeping the finished window would park dead slabs
            # above the memory budget
            if si + 1 < len(steps):
                keep = set(int(b) for b in steps[si + 1].bucket_ids)
            else:
                keep = set(int(b) for b in step.bucket_ids)
            self._evict_to(keep)

        if pairs_out:
            pairs, _ = dedup_pairs(np.concatenate(pairs_out))
        else:
            pairs = np.zeros((0, 2), np.int64)
        return pairs, {"supersteps": len(steps), "host_loads": self.loads,
                       "host_hits": self.hits,
                       "distance_computations": dc}
