"""Distributed DiskJoin execution over a JAX mesh (DESIGN §5).

Mapping of the paper's single-box design onto a pod:

  SSD               → host-side bucketed store (per-host shard of buckets)
  DRAM cache        → per-superstep device slab: the Gorder window's buckets,
                      assembled by the host under the same Belady policy,
                      then placed sharded over the ``data`` axis
  edge tasks        → sharded over ``data``: each device verifies its slice
                      of the window's edges; remote buckets arrive via the
                      gather XLA inserts for cross-shard ``jnp.take``
  verify kernel     → vmapped pairwise-L2 threshold (Pallas on TPU)

Supersteps inherit the Gorder locality: consecutive windows share most of
their buckets, so the host cache (Belady) converts that into fewer
host→device transfers — the pod analogue of fewer SSD reads.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ordering
from repro.core.executor import PAD_COORD
from repro.core.types import (BucketGraph, BucketMeta, JoinConfig,
                              dedup_pairs, resolve_bucket_capacity,
                              resolve_cache_buckets, round_up as _round_up)
from repro.kernels import ref
from repro.obs import get_tracer


@partial(jax.jit, static_argnames=("eps2",))
def verify_edges(slab: jax.Array, edges: jax.Array, eps2: float):
    """slab: (W, cap, d) window bucket slab; edges: (E, 2) int32 into slab.

    Returns (counts (E,), mask (E, cap, cap) bool, d2 (E, cap, cap)) —
    the squared distances ride along so the host can emit pair distances
    without recomputing them. Under pjit with edges sharded over
    ``data``, the slab gathers become collectives.
    """
    u = jnp.take(slab, edges[:, 0], axis=0)      # (E, cap, d)
    v = jnp.take(slab, edges[:, 1], axis=0)
    d2 = jax.vmap(ref.pairwise_l2)(u, v)         # (E, cap, cap)
    mask = d2 <= eps2
    return jnp.sum(mask, axis=(1, 2)), mask, d2


@partial(jax.jit, static_argnames=("eps2", "k_cap"))
def verify_edges_compact(slab: jax.Array, edges: jax.Array, na: jax.Array,
                         nb: jax.Array, intra: jax.Array, eps2: float,
                         k_cap: int):
    """Compacted variant (``compute_mode="device"``): instead of shipping
    the full (E, cap, cap) mask back to the host, pairs are compacted
    on-device (``repro.compute.compact_pairs``) — D2H shrinks from
    E·cap² bytes to E·(1 + 3·k_cap) values. ``na``/``nb`` carry the
    live-row counts (0 masks a padding lane out entirely)."""
    from repro.compute import compact_pairs
    u = jnp.take(slab, edges[:, 0], axis=0)
    v = jnp.take(slab, edges[:, 1], axis=0)
    d2 = jax.vmap(ref.pairwise_l2)(u, v)
    return compact_pairs(d2, d2 <= eps2, na, nb, intra, k_cap)


@dataclasses.dataclass
class Superstep:
    bucket_ids: np.ndarray   # (W,) global bucket ids in this window
    edges_local: np.ndarray  # (E, 2) int32 indices into bucket_ids
    edges_global: np.ndarray  # (E, 2) original bucket ids


def plan_supersteps(graph: BucketGraph, config: JoinConfig,
                    cache_buckets: int,
                    meta: BucketMeta) -> list[Superstep]:
    """Gorder → windows of ≤cache_buckets buckets covering all edges.

    Each edge lands in the first window containing both endpoints; the
    window advances greedily along the node order (self-pairs implicit —
    every bucket appears in ≥1 window). The order comes from
    ``ordering.compute_node_order`` (shared with the single-box executor,
    incl. the spatial strategy).
    """
    node_order = ordering.compute_node_order(graph, meta, config,
                                             cache_buckets)
    tasks, _, _ = ordering.edge_schedule(graph, node_order)

    steps: list[Superstep] = []
    cur_buckets: list[int] = []
    cur_edges: list[tuple[int, int]] = []
    seen: dict[int, int] = {}

    def flush():
        nonlocal cur_buckets, cur_edges, seen
        if not cur_buckets:
            return
        bids = np.asarray(cur_buckets, dtype=np.int64)
        eg = (np.asarray(cur_edges, dtype=np.int64)
              if cur_edges else np.zeros((0, 2), np.int64))
        el = np.stack([[seen[int(a)] for a, _ in cur_edges],
                       [seen[int(b)] for _, b in cur_edges]], axis=1
                      ).astype(np.int32) if cur_edges else \
            np.zeros((0, 2), np.int32)
        steps.append(Superstep(bids, el, eg))
        cur_buckets, cur_edges, seen = [], [], {}

    cap = max(2, cache_buckets)
    for t in tasks:
        need = [t[1]] if t[0] == "touch" else [t[1], t[2]]
        new = [b for b in need if int(b) not in seen]
        if len(cur_buckets) + len(new) > cap:
            flush()
            new = need
        for b in need:
            b = int(b)
            if b not in seen:
                seen[b] = len(cur_buckets)
                cur_buckets.append(b)
        if t[0] == "touch":
            cur_edges.append((int(t[1]), int(t[1])))  # self edge
        else:
            cur_edges.append((int(t[1]), int(t[2])))
    flush()
    return steps


class DistributedJoin:
    """Superstep-wise distributed execution of a planned join.

    ``mesh`` must have a ``data`` axis; edges shard over it. The host keeps
    a Belady-managed slab cache so consecutive supersteps reuse transfers.
    """

    def __init__(self, store, meta: BucketMeta, config: JoinConfig,
                 mesh: jax.sharding.Mesh | None = None):
        self.store = store
        self.meta = meta
        self.config = config
        self.mesh = mesh
        self.cap = resolve_bucket_capacity(config, meta.sizes)
        self.cache_buckets = resolve_cache_buckets(config, self.cap,
                                                   store.dim)
        self._host_cache: dict[int, np.ndarray] = {}
        self._staged: dict[int, tuple] = {}  # prefetched, not yet fetched
        self.loads = 0
        self.hits = 0
        self.prefetched = 0  # window w+1 loads issued under w's verify
        # compute_mode="device": per-bucket device slabs persist across
        # supersteps (evicted on the host keep-set), so consecutive
        # windows re-transfer only their *new* buckets instead of
        # device_put-ing the whole window slab every superstep
        from repro.compute import DeviceSlabPool, next_pow2
        self._dev_pool = (DeviceSlabPool() if config.compute_mode == "device"
                          else None)
        self._next_pow2 = next_pow2
        self._pair_cap = min(next_pow2(max(1024, 8 * self.cap)),
                             self.cap * self.cap)

    def _read_padded(self, b: int) -> tuple[np.ndarray, np.ndarray, int]:
        from repro.io.retry import read_with_retry
        vecs, ids = read_with_retry(
            lambda: self.store.read_bucket(b),
            retries=self.config.io_retries,
            backoff_s=self.config.io_retry_backoff_s)
        n = vecs.shape[0]
        pad = self.cap - n
        if pad > 0:
            vecs = np.concatenate(
                [vecs, np.full((pad, vecs.shape[1]), PAD_COORD, vecs.dtype)])
        return (vecs.astype(np.float32), ids, n)

    def _fetch(self, b: int) -> tuple[np.ndarray, np.ndarray, int]:
        if b in self._host_cache:
            self.hits += 1
            return self._host_cache[b]
        entry = self._staged.pop(b, None)
        if entry is None:            # not prefetched: load now
            entry = self._read_padded(b)
            self.loads += 1          # prefetched loads were counted at issue
        self._host_cache[b] = entry
        return entry

    def _evict_to(self, keep: set[int]) -> None:
        # host cache follows the superstep plan: keep only upcoming window
        # + LRU slack up to capacity (Belady degenerate form: the plan IS
        # the future, and the next window is the nearest future access)
        if len(self._host_cache) <= self.cache_buckets:
            return
        for b in list(self._host_cache.keys()):
            if b not in keep and len(self._host_cache) > self.cache_buckets:
                del self._host_cache[b]
                if self._dev_pool is not None:
                    self._dev_pool.evict(b)  # device mirrors host residency

    def _prefetch_window(self, step: "Superstep") -> None:
        """ROADMAP "prefetch for the distributed join": while window w's
        verify runs on-device (async dispatch), pull window w+1's missing
        buckets from disk. They land in a *staging* dict, not the host
        cache: staged entries must not add eviction pressure before
        window w's keep-set trim runs, or gap-retained buckets (kept by
        PR 2's upcoming-window keep-set) would be pushed out early and
        re-read. ``_fetch`` merges staged entries in when w+1 begins."""
        with get_tracer().span("dist.prefetch",
                               buckets=len(step.bucket_ids)):
            for b in step.bucket_ids:
                b = int(b)
                if b not in self._host_cache and b not in self._staged:
                    self._staged[b] = self._read_padded(b)
                    self.loads += 1
                    self.prefetched += 1

    def _dispatch_compact(self, slab, edges, entries, eps2, sharding):
        """Issue the compacted verify for one superstep (async). Edge
        count pads to the next pow2 (bounded recompiles) and, under a
        mesh, to a shard multiple; pad lanes carry na = nb = 0 so the
        compaction masks them out entirely."""
        E = edges.shape[0]
        Ep = self._next_pow2(E)
        if sharding is not None:
            Ep = _round_up(Ep, self.mesh.shape["data"])
        pe = edges
        if Ep != E:
            pe = np.concatenate([edges, np.zeros((Ep - E, 2), edges.dtype)])
        rowc = np.array([e[2] for e in entries], np.int32)
        na = np.zeros(Ep, np.int32)
        nb = np.zeros(Ep, np.int32)
        na[:E] = rowc[edges[:, 0]]
        nb[:E] = rowc[edges[:, 1]]
        intra = np.zeros(Ep, bool)
        intra[:E] = edges[:, 0] == edges[:, 1]
        edges_dev = jnp.asarray(pe)
        if sharding is not None:
            edges_dev = jax.device_put(edges_dev, sharding)
        out = verify_edges_compact(slab, edges_dev, jnp.asarray(na),
                                   jnp.asarray(nb), jnp.asarray(intra),
                                   eps2, self._pair_cap)
        return out, na, nb, intra, edges_dev

    def _extract_compact(self, handle, slab, edges, entries, eps2):
        """Fetch a superstep's compacted pairs (+ distances); on per-edge
        capacity overflow re-dispatch at the next pow2 (sticky for later
        steps)."""
        out, na, nb, intra, edges_dev = handle
        E = edges.shape[0]
        counts = np.asarray(out[0])
        top = int(counts[:E].max()) if E else 0
        if top > self._pair_cap:
            self._pair_cap = min(self._next_pow2(top), self.cap * self.cap)
            out = verify_edges_compact(slab, edges_dev, jnp.asarray(na),
                                       jnp.asarray(nb), jnp.asarray(intra),
                                       eps2, self._pair_cap)
            counts = np.asarray(out[0])
        rows_c = np.asarray(out[1])
        cols_c = np.asarray(out[2])
        dist_c = np.asarray(out[3])
        res, res_d = [], []
        for ei, (a, b) in enumerate(edges):
            k = int(counts[ei])
            if k:
                ida, idb = entries[a][1], entries[b][1]
                res.append(np.stack([ida[rows_c[ei, :k]],
                                     idb[cols_c[ei, :k]]], axis=1))
                res_d.append(dist_c[ei, :k].astype(np.float32))
        return res, res_d

    def fingerprint(self) -> str:
        """Session digest guarding checkpoint compatibility: config +
        bucket layout + store extent. A checkpoint written under a
        different digest must not be resumed into this run."""
        from repro.ft.atomic import fingerprint as _fp
        return _fp({"config": dataclasses.asdict(self.config),
                    "sizes": self.meta.sizes.tolist(),
                    "num_buckets": int(self.meta.num_buckets),
                    "dim": int(self.store.dim)})

    def run(self, graph: BucketGraph, *, checkpointer=None,
            resume_from=None, fault=None):
        """Execute the planned join → (pairs, info).

        ``checkpointer``: a ``repro.ft.JoinCheckpointer`` recording
        superstep progress (the raw emission stream) without ever
        blocking the verify pipeline. ``resume_from``: a checkpoint
        directory path or a ``ResumeState`` — committed supersteps are
        replayed from the spill files and execution restarts at the
        cursor; the final pairs+distances are byte-identical to an
        uninterrupted run. ``fault``: a ``repro.ft.FaultInjector``
        consulted at each superstep boundary (tests/benchmarks only).
        """
        eps2 = float(self.config.epsilon) ** 2
        steps = plan_supersteps(graph, self.config, self.cache_buckets,
                                meta=self.meta)
        pairs_out, dists_out = [], []
        start_si = 0
        restore_s = 0.0
        fp = (self.fingerprint()
              if checkpointer is not None or resume_from is not None
              else None)
        if resume_from is not None:
            from repro.ft import JoinCheckpointer
            rs = resume_from
            if isinstance(rs, str):
                rs = JoinCheckpointer.restore(rs, fingerprint=fp)
            if rs is not None:
                # the committed raw stream, in emission order — replayed
                # verbatim so the final dedup sees the same concatenation
                # an uninterrupted run would
                pairs_out.extend(rs.pairs)
                dists_out.extend(rs.dists)
                start_si = rs.superstep + 1
                restore_s = rs.restore_s
        if checkpointer is not None:
            checkpointer.begin(fp, start_si)
        sharding = None
        if self.mesh is not None:
            sharding = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec("data"))

        dc = 0
        tracer = get_tracer()
        for si, step in enumerate(steps):
            if si < start_si:
                continue  # committed by the restored checkpoint chain
            if fault is not None:
                fault.superstep(si)
            edges = step.edges_local
            if edges.shape[0] == 0:
                # defensive: planner always pairs buckets w/ edges — but
                # the checkpoint cursor must advance through empty steps
                if checkpointer is not None:
                    checkpointer.step_done(si, [], [])
                continue
            step_span = tracer.span("dist.superstep", step=si,
                                    buckets=len(step.bucket_ids),
                                    edges=int(edges.shape[0]))
            step_span.__enter__()
            entries = [self._fetch(int(b)) for b in step.bucket_ids]
            E = edges.shape[0]
            if self._dev_pool is not None:
                # device mode: the window slab is a stack of per-bucket
                # slabs already resident on-device (one transfer per host
                # residency), and the verify returns compacted pairs
                slab = jnp.stack(
                    [self._dev_pool.operand(int(b), e[0])
                     for b, e in zip(step.bucket_ids, entries)])
                # harvest this window's first-touch buckets as device-
                # resident slices NOW (queue idle): the next overlapping
                # window then stacks device arrays instead of
                # re-transferring staged host copies
                for wi, b in enumerate(step.bucket_ids):
                    if self._dev_pool.needs_harvest(int(b)):
                        self._dev_pool.harvest(int(b), slab[wi])
                out = self._dispatch_compact(slab, edges, entries,
                                             eps2, sharding)
            else:
                slab = jnp.asarray(np.stack([e[0] for e in entries]))
                # pad edge count to shard evenly; padding repeats edge 0
                # whose results are sliced off
                pe = edges
                if sharding is not None:
                    n_shards = self.mesh.shape["data"]
                    Ep = _round_up(E, n_shards)
                    if Ep != E:
                        pe = np.concatenate(
                            [edges, np.repeat(edges[:1], Ep - E, axis=0)])
                    edges_dev = jax.device_put(jnp.asarray(pe), sharding)
                else:
                    edges_dev = jnp.asarray(pe)
                out = verify_edges(slab, edges_dev, eps2)
            # verify is dispatched asynchronously: pull window w+1's
            # missing buckets from disk while this window's kernel runs
            if si + 1 < len(steps):
                self._prefetch_window(steps[si + 1])
            dc += sum(
                (entries[a][2] * entries[b][2]) if a != b
                else entries[a][2] * (entries[a][2] - 1) // 2
                for a, b in edges)
            if self._dev_pool is not None:
                step_pairs, step_dists = self._extract_compact(
                    out, slab, edges, entries, eps2)
            else:
                mask = np.asarray(out[1])[:E]
                d2 = np.asarray(out[2])[:E]
                step_pairs, step_dists = [], []
                for ei, (a, b) in enumerate(edges):
                    na, nb = entries[a][2], entries[b][2]
                    m = mask[ei][:na, :nb]
                    if a == b:
                        m = np.triu(m, k=1)
                    rows, cols = np.nonzero(m)
                    if rows.size:
                        ida, idb = entries[a][1], entries[b][1]
                        step_pairs.append(
                            np.stack([ida[rows], idb[cols]], axis=1))
                        step_dists.append(
                            np.sqrt(d2[ei][:na, :nb][rows, cols]
                                    ).astype(np.float32))
            pairs_out.extend(step_pairs)
            dists_out.extend(step_dists)
            if checkpointer is not None:
                checkpointer.step_done(si, step_pairs, step_dists)
            # keep-set is the *upcoming* window: evicting on the finished
            # window's set discards exactly the slabs superstep w+1 reuses
            # (e.g. buckets loaded in w-1 that skip w and return in w+1),
            # while keeping the finished window would park dead slabs
            # above the memory budget
            if si + 1 < len(steps):
                keep = set(int(b) for b in steps[si + 1].bucket_ids)
            else:
                keep = set(int(b) for b in step.bucket_ids)
            self._evict_to(keep)
            step_span.__exit__(None, None, None)

        if checkpointer is not None:
            checkpointer.finish()

        watermark = sum(len(p) for p in pairs_out)
        if pairs_out:
            pairs, dists = dedup_pairs(np.concatenate(pairs_out),
                                       np.concatenate(dists_out))
        else:
            pairs = np.zeros((0, 2), np.int64)
            dists = np.zeros(0, np.float32)
        info = {"supersteps": len(steps), "host_loads": self.loads,
                "host_hits": self.hits, "prefetched_buckets": self.prefetched,
                "distance_computations": dc, "dists": dists,
                "watermark_rows": watermark}
        if resume_from is not None:
            info["resumed_at"] = start_si
            info["restore_s"] = restore_s
        if checkpointer is not None:
            info["ckpt"] = dict(checkpointer.stats)
        if self._dev_pool is not None:
            info["h2d_transfers"] = self._dev_pool.transfers
            info["device_slab_hits"] = self._dev_pool.hits
            info["h2d_bytes"] = self._dev_pool.h2d_bytes
        return pairs, info
