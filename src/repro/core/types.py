"""Core datatypes for the DiskJoin engine.

Configuration is split along the build/query boundary of the session API
(``repro.core.index.DiskJoinIndex``):

  * **build-time** parameters (``BuildConfig``, ``BUILD_TIME_FIELDS``)
    shape the on-disk index — bucket count/capacity, padding, striping,
    layout order. They are frozen into the index manifest by
    ``DiskJoinIndex.build`` and can only change via a rebuild.
  * **query-time** parameters (``QueryConfig``, ``QUERY_TIME_FIELDS``)
    shape a single join/query — ε, λ, memory budget, eviction, io_mode,
    prefetch knobs. They may vary per call against one build.

``JoinConfig`` remains the flat union of both (the one-shot API), with
``split_config``/``merge_config`` converting between the two views.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def _validate_compute(compute_mode: str, verify_batch: int,
                      plan_mode: str = "off") -> None:
    if compute_mode not in ("host", "device", "auto"):
        raise ValueError(f"compute_mode must be 'host', 'device' or "
                         f"'auto', got {compute_mode!r}")
    if plan_mode not in ("off", "on"):
        raise ValueError(f"plan_mode must be 'off' or 'on', "
                         f"got {plan_mode!r}")
    if compute_mode == "auto" and plan_mode != "on":
        # "auto" is a planner decision, not an engine the executor can
        # instantiate — without a plan there is nothing to resolve it
        raise ValueError("compute_mode='auto' requires plan_mode='on'")
    if verify_batch < 1:
        raise ValueError(f"verify_batch must be >= 1, got {verify_batch}")


def _resolve_num_buckets(num_buckets: Optional[int], num_vectors: int) -> int:
    if num_buckets is not None:
        return max(2, min(num_buckets, num_vectors))
    # paper Fig. 11: best at ~1‰ of dataset size
    return max(2, min(num_vectors // 2, max(16, num_vectors // 1000)))


@dataclasses.dataclass(frozen=True)
class JoinConfig:
    """Task configuration (paper §3 inputs).

    Attributes:
      epsilon: distance threshold for similar pairs (L2).
      recall_target: λ — expected recall of the approximate result.
      memory_budget_bytes: C — cache memory for resident buckets.
      num_buckets: number of buckets; paper default ≈ 1‰ of N (Fig. 11).
      bucket_capacity: pad buckets to this many rows for fixed-shape kernels
        (TPU adaptation: one compiled kernel, MXU-aligned tiles).
      eviction_policy: "belady" | "lru" | "fifo" | "lfu" (Fig. 17 ablation).
      reorder: task reordering on/off (Fig. 17 ablation).
      order_strategy: "gorder" (paper §4.3) | "spatial" (beyond-paper
        nearest-neighbor center tour — see ordering.spatial_order).
      prune: probabilistic candidate-bucket pruning on/off (Fig. 18 ablation).
      max_candidates: L — nearest centers fetched per bucket before pruning.
      use_pallas: run the verify kernel through Pallas (interpret on CPU).
      block_rows: streaming block size (rows) for dataset scans.
      max_bucket_rows: split buckets above this row count into sub-buckets
        sharing the center (bounds kernel padding waste under skew).
      pad_align: bucket row padding alignment (128 = MXU tile; smaller is
        fine for CPU validation runs).
      seed: RNG seed for center sampling.
      io_mode: "sync" (read buckets inline, serial read→verify loop) or
        "prefetch" (repro.io subsystem: schedule-driven background reads
        overlapped with verification; identical result pair set).
      io_lookahead: max bucket loads the prefetcher runs ahead of the
        executor (bounds prefetch staging memory and queue depth).
      io_pool_slabs: slab count of the prefetch buffer pool; None sizes it
        to cache capacity + io_lookahead. Values below cache capacity + 1
        are raised to that floor (pipeline liveness).
      io_threads: background reader threads for prefetch mode — *per
        device* when the store is striped (models per-device queue depth).
      io_devices: number of backing files ("SSDs") the bucketed store is
        striped over; >1 selects ``StripedBucketedVectorStore`` and gives
        the prefetcher one submission queue per device.
      io_stripe_by: "phase" assigns buckets to devices round-robin in disk
        layout (≈ schedule) order — consecutive misses fan out across all
        devices; "hash" assigns bucket id mod devices.
      io_batch_reads: submit adjacent schedule misses that land on the
        same device as one batched request (io_uring-style submission).
      io_coalesce: merge batched reads of disk-contiguous buckets into a
        single sequential read, split into slabs on completion (implies
        batching; also makes the writer lay buckets out in schedule order
        so schedule-adjacent ⇒ disk-adjacent).
      emulate_read_latency_s: per-bucket-read sleep applied to the
        bucketed store — restores the paper's SSD-latency-bound regime on
        page-cached memmaps (benchmarks only; 0 disables).
      io_retries: transient read errors (OSError/IOError) tolerated per
        bucket read before the join aborts — each failed attempt is
        retried after a capped exponential backoff. 0 restores the old
        fail-fast behavior. Counted in ``PipelineStats.io_retries`` /
        ``io_read_errors``.
      io_retry_backoff_s: base backoff before the first retry; doubles
        per attempt, capped at 50× the base.
      compute_mode: "host" stages each verify batch from host slabs and
        extracts pairs from a fetched boolean mask; "device" mirrors the
        cache schedule on the accelerator (``repro.compute``): every
        bucket slab is transferred ONCE per cache residency into a device
        slab pool, dispatch is double-buffered, and the kernel returns
        compacted (row, col, distance) triples. Result pairs/distances
        are byte-identical between the modes.
      verify_batch: edges per batched verify-kernel dispatch (>= 1).
        Larger batches amortize dispatch overhead; smaller ones bound the
        slab pins a pending batch holds.
      emulate_xfer_gb_s: emulated host↔device link bandwidth (GB/s)
        charged against the verify engines' transfer volumes — restores
        the accelerator-attached regime (where staging bytes cost wall
        time) on hosts whose "device" is the same memory, exactly as
        ``emulate_read_latency_s`` restores the SSD regime on page-cached
        memmaps (benchmarks only; 0 disables).
      plan_mode: "off" keeps every sizing knob hand-tuned (legacy);
        "on" derives them from ``repro.plan`` — per-join ``pair_cap``
        and per-region ``verify_batch`` from the cardinality estimate,
        host/device verify routing from the cost model (enables
        ``compute_mode="auto"``), pool split from predicted reuse. The
        planner only sizes and places work: result pairs/distances are
        byte-identical between "off" and "on".
    """

    epsilon: float
    recall_target: float = 0.9
    memory_budget_bytes: int = 64 * 1024 * 1024
    num_buckets: Optional[int] = None
    bucket_capacity: Optional[int] = None
    eviction_policy: str = "belady"
    reorder: bool = True
    order_strategy: str = "gorder"
    prune: bool = True
    max_candidates: int = 64
    use_pallas: bool = False
    block_rows: int = 8192
    max_bucket_rows: Optional[int] = None
    pad_align: int = 128
    seed: int = 0
    io_mode: str = "sync"
    io_lookahead: int = 8
    io_pool_slabs: Optional[int] = None
    io_threads: int = 2
    io_devices: int = 1
    io_stripe_by: str = "phase"
    io_batch_reads: bool = False
    io_coalesce: bool = False
    emulate_read_latency_s: float = 0.0
    io_retries: int = 2
    io_retry_backoff_s: float = 0.005
    compute_mode: str = "host"
    verify_batch: int = 32
    emulate_xfer_gb_s: float = 0.0
    plan_mode: str = "off"

    def __post_init__(self):
        if self.io_mode not in ("sync", "prefetch"):
            raise ValueError(f"io_mode must be 'sync' or 'prefetch', "
                             f"got {self.io_mode!r}")
        if self.io_devices < 1:
            raise ValueError(f"io_devices must be >= 1, got {self.io_devices}")
        if self.io_stripe_by not in ("phase", "hash"):
            raise ValueError(f"io_stripe_by must be 'phase' or 'hash', "
                             f"got {self.io_stripe_by!r}")
        if self.io_retries < 0:
            raise ValueError(f"io_retries must be >= 0, got {self.io_retries}")
        _validate_compute(self.compute_mode, self.verify_batch,
                          self.plan_mode)

    def resolve_num_buckets(self, num_vectors: int) -> int:
        return _resolve_num_buckets(self.num_buckets, num_vectors)


# ---------------------------------------------------------------------------
# build-time / query-time split (session API)
# ---------------------------------------------------------------------------
BUILD_TIME_FIELDS = frozenset({
    "num_buckets", "bucket_capacity", "block_rows", "max_bucket_rows",
    "pad_align", "seed", "io_devices", "io_stripe_by", "io_coalesce",
})
"""Parameters baked into the on-disk index (bucketization + layout +
striping). ``io_coalesce`` is build-time because coalescing relies on the
writer laying extents in schedule order and on chunked phase striping."""

QUERY_TIME_FIELDS = frozenset(
    f.name for f in dataclasses.fields(JoinConfig)) - BUILD_TIME_FIELDS
"""Parameters a single join/query may vary against one build."""


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    """Build-time parameters: everything that shapes the on-disk index.

    Changing any of these requires ``DiskJoinIndex.build`` to rewrite the
    bucketed store; the session API rejects them as per-query overrides.
    Field semantics match the identically-named ``JoinConfig`` attributes.
    """

    num_buckets: Optional[int] = None
    bucket_capacity: Optional[int] = None
    block_rows: int = 8192
    max_bucket_rows: Optional[int] = None
    pad_align: int = 128
    seed: int = 0
    io_devices: int = 1
    io_stripe_by: str = "phase"
    io_coalesce: bool = False

    def __post_init__(self):
        if self.io_devices < 1:
            raise ValueError(f"io_devices must be >= 1, got {self.io_devices}")
        if self.io_stripe_by not in ("phase", "hash"):
            raise ValueError(f"io_stripe_by must be 'phase' or 'hash', "
                             f"got {self.io_stripe_by!r}")

    def resolve_num_buckets(self, num_vectors: int) -> int:
        return _resolve_num_buckets(self.num_buckets, num_vectors)


@dataclasses.dataclass(frozen=True)
class QueryConfig:
    """Query-time parameters: everything a call may vary against one build.

    Field semantics match the identically-named ``JoinConfig`` attributes.
    """

    epsilon: float
    recall_target: float = 0.9
    memory_budget_bytes: int = 64 * 1024 * 1024
    eviction_policy: str = "belady"
    reorder: bool = True
    order_strategy: str = "gorder"
    prune: bool = True
    max_candidates: int = 64
    use_pallas: bool = False
    io_mode: str = "sync"
    io_lookahead: int = 8
    io_pool_slabs: Optional[int] = None
    io_threads: int = 2
    io_batch_reads: bool = False
    emulate_read_latency_s: float = 0.0
    io_retries: int = 2
    io_retry_backoff_s: float = 0.005
    compute_mode: str = "host"
    verify_batch: int = 32
    emulate_xfer_gb_s: float = 0.0
    plan_mode: str = "off"

    def __post_init__(self):
        if self.io_mode not in ("sync", "prefetch"):
            raise ValueError(f"io_mode must be 'sync' or 'prefetch', "
                             f"got {self.io_mode!r}")
        if self.io_retries < 0:
            raise ValueError(f"io_retries must be >= 0, got {self.io_retries}")
        _validate_compute(self.compute_mode, self.verify_batch,
                          self.plan_mode)


def split_config(config: JoinConfig) -> tuple[BuildConfig, QueryConfig]:
    """Partition a flat ``JoinConfig`` into its (build, query) halves."""
    d = dataclasses.asdict(config)
    return (BuildConfig(**{k: d[k] for k in BUILD_TIME_FIELDS}),
            QueryConfig(**{k: d[k] for k in QUERY_TIME_FIELDS}))


def merge_config(build: BuildConfig, query: QueryConfig) -> JoinConfig:
    """Recombine the two halves into the flat config the engine consumes."""
    return JoinConfig(**dataclasses.asdict(build),
                      **dataclasses.asdict(query))


@dataclasses.dataclass
class BucketMeta:
    """Per-bucket metadata kept in memory (centers + radii + sizes)."""

    centers: np.ndarray    # (B, d) float32
    radii: np.ndarray      # (B,) float32 — max dist from member to center
    sizes: np.ndarray      # (B,) int64

    @property
    def num_buckets(self) -> int:
        return self.centers.shape[0]


@dataclasses.dataclass
class BucketGraph:
    """Directed bucket dependency graph, edges (i, j) with i < j (paper §3)."""

    num_nodes: int
    edges: np.ndarray            # (E, 2) int64, i < j
    self_edges_implicit: bool = True  # every bucket checks itself

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    def adjacency(self) -> list[list[int]]:
        """Undirected adjacency (orchestration treats G as undirected)."""
        adj: list[list[int]] = [[] for _ in range(self.num_nodes)]
        for i, j in self.edges:
            adj[int(i)].append(int(j))
            adj[int(j)].append(int(i))
        return adj

    def out_neighbors(self) -> list[list[int]]:
        adj: list[list[int]] = [[] for _ in range(self.num_nodes)]
        for i, j in self.edges:
            adj[int(i)].append(int(j))
        return adj


TIMING_KEYS = ("bucketing", "graph", "orchestration", "execute",
               "io_wait", "compute")
"""The documented ``JoinResult.timings`` schema, identical for every join
kind (self, cross, index session). Detail sub-phases appear under
``"<phase>/<sub>"`` keys (e.g. ``bucketing/assign``,
``orchestration/layout_plan``); consumers should treat unknown sub-keys as
additive detail of their parent phase."""


def finalize_timings(exec_timings: dict, graph_s: float,
                     bucketing_s: float = 0.0,
                     bucketing_sub: dict | None = None) -> dict:
    """Shape raw executor timings into the one documented schema.

    ``exec_timings`` is the executor's ``{plan, execute, io_wait, compute}``;
    ``graph_s`` the bucket-graph build time; ``bucketing_s`` the bucketize
    wall time (0 for index-session joins, where bucketization is amortized
    across calls) with ``bucketing_sub`` its per-scan detail. A
    ``layout_plan`` entry in the detail is re-attributed to orchestration —
    the disk-layout pass runs graph build + ordering that the executor then
    reuses, so phase fractions stay comparable across configurations.
    """
    sub = dict(bucketing_sub or {})
    layout_s = sub.pop("layout_plan", 0.0)
    out = dict(exec_timings)
    out["bucketing"] = bucketing_s - layout_s
    for k, v in sub.items():
        out[f"bucketing/{k}"] = v
    out["graph"] = graph_s
    out["orchestration"] = out.pop("plan") + graph_s + layout_s
    if layout_s:
        out["orchestration/layout_plan"] = layout_s
    return out


@dataclasses.dataclass
class JoinResult:
    """Join output + execution telemetry.

    ``timings`` follows the ``TIMING_KEYS`` schema for every join kind
    (self-join, cross-join and the ``DiskJoinIndex`` session calls emit the
    same top-level key set)."""

    pairs: np.ndarray                 # (P, 2) int64 original vector ids, a<b
    distances: np.ndarray             # (P,) float32
    num_distance_computations: int
    num_candidate_pairs: int
    cache_hits: int
    cache_misses: int
    bucket_loads: int
    io_stats: dict
    timings: dict                     # phase -> seconds (TIMING_KEYS schema)
    plan: object = None               # repro.plan.JoinPlan when plan_mode on

    @property
    def cache_hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def resolve_bucket_capacity(config: JoinConfig, sizes: np.ndarray) -> int:
    """Padded rows per bucket slab (fixed kernel shape), from the layout
    plan. One definition shared by the executor, the distributed join and
    bucketize's disk-layout planner, so they can never disagree."""
    max_size = int(np.max(sizes)) if len(sizes) else 1
    cap = config.bucket_capacity or round_up(max(max_size, 8),
                                             config.pad_align)
    if cap < max_size:
        raise ValueError(f"bucket_capacity {cap} < max bucket {max_size}")
    return cap


def resolve_cache_buckets(config: JoinConfig, capacity_rows: int,
                          dim: int) -> int:
    """Resident bucket slots under the memory budget (≥ 2 for edge pins)."""
    padded_bytes = capacity_rows * dim * 4
    return max(2, int(config.memory_budget_bytes // padded_bytes))


def dedup_pairs(raw: np.ndarray, dists: np.ndarray | None = None
                ) -> tuple[np.ndarray, np.ndarray | None]:
    """Canonicalize (lo, hi), drop self-pairs, deduplicate — id-range safe.

    The fast path packs each pair as ``(lo << 32) | hi``; large ids
    (reachable via cross-join id offsetting at billion scale) break the
    packing — ids ≥ 2^32 collide outright, and ids ≥ 2^31 overflow the
    int64 sign bit under the shift, so the arithmetic unshift returns
    negative ids. Both ranges fall back to a lexicographic ``np.unique``
    over rows. Returns (pairs, dists-at-first-occurrence) with dists None
    iff not supplied.
    """
    if raw.size == 0:
        return (np.zeros((0, 2), np.int64),
                np.zeros(0, np.float32) if dists is not None else None)
    raw = np.asarray(raw, dtype=np.int64)
    lo = np.minimum(raw[:, 0], raw[:, 1])
    hi = np.maximum(raw[:, 0], raw[:, 1])
    if int(lo.min()) >= 0 and int(hi.max()) < (1 << 31):
        keys = (lo << 32) | hi
        uniq, first_idx = np.unique(keys, return_index=True)
        pairs = np.stack([uniq >> 32, uniq & 0xFFFFFFFF], axis=1)
    else:
        stacked = np.stack([lo, hi], axis=1)
        pairs, first_idx = np.unique(stacked, axis=0, return_index=True)
    keep = pairs[:, 0] != pairs[:, 1]
    out_d = dists[first_idx][keep] if dists is not None else None
    return pairs[keep], out_d


def canonicalize_pairs(pairs: np.ndarray) -> np.ndarray:
    """Sort each pair (a<b), drop self-pairs and duplicates."""
    if pairs.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    lo = np.minimum(pairs[:, 0], pairs[:, 1])
    hi = np.maximum(pairs[:, 0], pairs[:, 1])
    keep = lo != hi
    stacked = np.stack([lo[keep], hi[keep]], axis=1)
    return np.unique(stacked, axis=0)


def recall(result_pairs: np.ndarray, truth_pairs: np.ndarray) -> float:
    """Standard recall |R ∩ R'| / |R| over canonicalized pair sets."""
    truth = canonicalize_pairs(truth_pairs)
    if truth.shape[0] == 0:
        return 1.0
    got = canonicalize_pairs(result_pairs)
    truth_keys = truth[:, 0].astype(np.int64) << 32 | truth[:, 1].astype(np.int64)
    got_keys = got[:, 0].astype(np.int64) << 32 | got[:, 1].astype(np.int64)
    inter = np.intersect1d(truth_keys, got_keys, assume_unique=True)
    return inter.size / truth_keys.size
