"""Continuous observability: streaming rollups, SLO burn-rate alerting,
and live cost-model recalibration.

PR 6's tracer/metrics made DiskJoin's temporal claims measurable *after
the fact* — export the trace, run the analysis. This module watches the
system *while it runs*, at fixed memory and near-zero overhead:

  * **``TimeSeries``** — a tracer *sink* (``Tracer.add_sink``) folding
    every recorded event into time-windowed ``RollupWindow`` aggregates:
    per-span counts, summed duration, and a log-bucket duration histogram
    (the same geometric bounds as ``repro.obs.metrics.Histogram``, so
    per-shard windows merge *exactly* — counts add, percentiles are
    re-derived). Async ``b``/``e`` pairs (serving requests) are matched
    into latency samples; ``C`` counter samples and ``i`` instants get
    last/max and count rollups. Windows close as events arrive (or on
    ``poll()``); in-process consumers subscribe to closed windows.
  * **``Slo`` / ``SloMonitor``** — declarative objectives (request p95
    latency, deadline-drop rate, cache hit rate, goodput, io-retry
    budget) evaluated per closed window with Google-SRE-style
    *multi-window burn rates*: an alert fires only when both the fast
    window (catches sharp degradation quickly) and the slow window
    (rejects blips) burn the error budget faster than ``burn_threshold``.
    Structured ``Alert`` records go to callbacks, the tracer (as
    ``slo.alert`` instants) and the metrics snapshot.
  * **``LiveCalibrator``** — rolling medians of span-derived unit costs
    (``io.read`` seconds/bucket, ``link.xfer`` bytes/second) that
    ``CostModel.from_telemetry(..., live=...)`` consumes as the ``live``
    provenance tier: long-running sessions re-price their ``WavePlan``s
    from what the hardware is doing *now*, not what it averaged since
    startup. Plans stay byte-neutral — costs size and place work, never
    change results.

``DiskJoinIndex.attach_live()`` wires all three to a session;
``repro.obs.dash`` renders the result as a one-screen text dashboard.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import statistics
import threading
import time
from collections import deque

from repro.obs.metrics import Histogram, log_bounds

# span arg naming the per-event unit count for unit-cost calibration
# (io.read may serve several coalesced buckets per event; link.xfer
# carries its byte volume)
UNIT_ARGS = {"io.read": "buckets", "link.xfer": "bytes"}
# span args that mark a completion as failed (deadline drops, errors)
BAD_ARGS = ("dropped", "error")
# open async begins kept for pairing; beyond this, oldest are forgotten
_OPEN_CAP = 8192


class _SpanAgg:
    """One span name's fixed-memory rollup inside one window."""

    __slots__ = ("count", "total_s", "units", "bad", "counts",
                 "min", "max")

    def __init__(self, nbuckets: int):
        self.count = 0
        self.total_s = 0.0
        self.units = 0.0       # Σ unit arg (buckets, bytes); count if none
        self.bad = 0           # completions flagged dropped/error
        self.counts = [0] * nbuckets
        self.min = math.inf
        self.max = -math.inf


class RollupWindow:
    """All events folded between ``t0`` and ``t1`` (tracer clock)."""

    __slots__ = ("t0", "t1", "spans", "counters", "instants")

    def __init__(self, t0: float, t1: float):
        self.t0 = t0
        self.t1 = t1
        self.spans: dict[str, _SpanAgg] = {}
        self.counters: dict[str, dict] = {}
        self.instants: dict[str, int] = {}

    @property
    def events(self) -> int:
        return (sum(a.count for a in self.spans.values())
                + sum(c["n"] for c in self.counters.values())
                + sum(self.instants.values()))


class TimeSeries:
    """Fixed-memory streaming rollup over tracer events.

    Install with ``tracer.add_sink(ts.on_event)``. Folding happens on
    the recording thread under one re-entrant lock (windows are shared
    state; tracer rings stay lock-free). Retains the last ``windows``
    closed windows plus the one being filled; a traffic gap fast-forwards
    through (bounded) empty windows so burn rates decay honestly.
    """

    def __init__(self, *, window_s: float = 1.0, windows: int = 60,
                 lo: float = 1e-6, hi: float = 1e4, factor: float = 2.0):
        self.window_s = float(window_s)
        self.retain = max(2, int(windows))
        self.bounds = log_bounds(lo, hi, factor)
        self._nbuckets = len(self.bounds) + 1
        self.windows: deque[RollupWindow] = deque(maxlen=self.retain)
        self.current: RollupWindow | None = None
        # RLock: a subscriber may emit a tracer instant (slo.alert) whose
        # sink delivery re-enters on_event on the same thread
        self._lock = threading.RLock()
        self._subs: list = []
        self._open: dict[tuple, float] = {}
        self.events_folded = 0

    # -- sink (hot path) ------------------------------------------------------
    def on_event(self, ev) -> None:
        ph = ev[0]
        if ph not in ("X", "i", "C", "b", "e"):
            return
        name, ts = ev[1], ev[2]
        with self._lock:
            self._roll(ts)
            w = self.current
            self.events_folded += 1
            if ph == "X":
                self._fold_span(w, name, ev[3], ev[4])
            elif ph == "b":
                if len(self._open) >= _OPEN_CAP:
                    self._open.pop(next(iter(self._open)))
                self._open[(name, ev[5])] = ts
            elif ph == "e":
                t0 = self._open.pop((name, ev[5]), None)
                if t0 is not None:
                    self._fold_span(w, name, ts - t0, ev[4])
            elif ph == "C":
                a = ev[4] or {}
                v = a.get("value", 0)
                ent = w.counters.get(name)
                if ent is None:
                    w.counters[name] = {"last": v, "max": v, "n": 1}
                else:
                    ent["last"] = v
                    if v > ent["max"]:
                        ent["max"] = v
                    ent["n"] += 1
            else:  # instant
                w.instants[name] = w.instants.get(name, 0) + 1

    def _fold_span(self, w: RollupWindow, name: str, dur: float,
                   args) -> None:
        agg = w.spans.get(name)
        if agg is None:
            agg = w.spans[name] = _SpanAgg(self._nbuckets)
        dur = max(0.0, float(dur))
        agg.count += 1
        agg.total_s += dur
        agg.counts[bisect.bisect_left(self.bounds, dur)] += 1
        if dur < agg.min:
            agg.min = dur
        if dur > agg.max:
            agg.max = dur
        unit_arg = UNIT_ARGS.get(name)
        units = 1.0
        if args:
            if unit_arg is not None:
                units = float(args.get(unit_arg) or 1.0)
            if any(args.get(k) for k in BAD_ARGS):
                agg.bad += 1
        agg.units += units

    def _roll(self, ts: float) -> None:
        if self.current is None:
            self.current = RollupWindow(ts, ts + self.window_s)
            return
        steps = 0
        while ts >= self.current.t1:
            if steps > self.retain:
                # gap longer than retention: every retained window is
                # already empty — snap the grid forward instead of
                # looping per elapsed window
                k = math.floor((ts - self.current.t0) / self.window_s)
                t0 = self.current.t0 + k * self.window_s
                if t0 > ts:   # fp rounding over ~k windows can overshoot
                    t0 -= self.window_s
                self.current = RollupWindow(t0, t0 + self.window_s)
                return
            closed = self.current
            self.windows.append(closed)
            self.current = RollupWindow(closed.t1,
                                        closed.t1 + self.window_s)
            steps += 1
            for fn in list(self._subs):
                try:
                    fn(closed)
                except Exception:  # consumers never take the session down
                    pass

    def poll(self, now: float | None = None) -> None:
        """Close overdue windows without waiting for traffic (dashboards
        and tests drive this; the tracer clock is ``time.perf_counter``)."""
        with self._lock:
            if self.current is not None:
                self._roll(time.perf_counter() if now is None else now)

    # -- consumers ------------------------------------------------------------
    def subscribe(self, fn) -> None:
        """``fn(closed_window)`` on every window close, on the folding
        thread. Exceptions are swallowed."""
        with self._lock:
            self._subs.append(fn)

    def unsubscribe(self, fn) -> None:
        with self._lock:
            self._subs = [s for s in self._subs if s != fn]

    # -- aggregate views ------------------------------------------------------
    def recent(self, n: int | None = None) -> list[RollupWindow]:
        """The last ``n`` *closed* windows, oldest first."""
        with self._lock:
            ws = list(self.windows)
        return ws if n is None else ws[-n:]

    def span_aggregate(self, name: str, n: int | None = None
                       ) -> dict | None:
        """Merge one span's rollup over the last ``n`` closed windows →
        histogram-snapshot-shaped dict (plus ``total_s``/``units``/
        ``bad``), or None if the span never fired."""
        merged: _SpanAgg | None = None
        for w in self.recent(n):
            agg = w.spans.get(name)
            if agg is None:
                continue
            if merged is None:
                merged = _SpanAgg(self._nbuckets)
            merged.count += agg.count
            merged.total_s += agg.total_s
            merged.units += agg.units
            merged.bad += agg.bad
            merged.counts = [a + b for a, b in zip(merged.counts,
                                                   agg.counts)]
            merged.min = min(merged.min, agg.min)
            merged.max = max(merged.max, agg.max)
        if merged is None:
            return None
        return self._agg_snapshot(merged)

    def _agg_snapshot(self, agg: _SpanAgg) -> dict:
        pct = Histogram.percentile_from
        return {"count": agg.count, "sum": agg.total_s,
                "total_s": agg.total_s, "units": agg.units,
                "bad": agg.bad,
                "min": agg.min if agg.count else 0.0,
                "max": agg.max if agg.count else 0.0,
                "p50": pct(self.bounds, agg.counts, 50),
                "p95": pct(self.bounds, agg.counts, 95),
                "p99": pct(self.bounds, agg.counts, 99),
                "bounds": list(self.bounds),
                "buckets": list(agg.counts)}

    def rate(self, name: str, n: int | None = None) -> float:
        """Span completions per second over the last ``n`` closed windows."""
        ws = self.recent(n)
        if not ws:
            return 0.0
        total = sum(w.spans[name].count for w in ws if name in w.spans)
        return total / (len(ws) * self.window_s)

    def percentile(self, name: str, q: float,
                   n: int | None = None) -> float:
        agg = self.span_aggregate(name, n)
        if agg is None:
            return 0.0
        return Histogram.percentile_from(self.bounds, agg["buckets"], q)

    def unit_cost_series(self, name: str, n: int | None = None
                         ) -> list[tuple[float, int]]:
        """Per-window ``(seconds-per-unit, sample count)`` for a span,
        oldest first — the calibrator's raw material."""
        out = []
        for w in self.recent(n):
            agg = w.spans.get(name)
            if agg is not None and agg.units > 0:
                out.append((agg.total_s / agg.units, agg.count))
        return out

    def span_names(self, n: int | None = None) -> list[str]:
        names: set[str] = set()
        for w in self.recent(n):
            names.update(w.spans)
        return sorted(names)

    def section(self, n: int | None = None) -> dict:
        """JSON-able rollup of the retained windows — the ``live``
        provider payload in ``metrics_snapshot()``. Mergeable across
        shards with ``merge_live_sections`` (exact histogram merge)."""
        ws = self.recent(n)
        spans = {name: self.span_aggregate(name, n)
                 for name in self.span_names(n)}
        counters: dict[str, dict] = {}
        instants: dict[str, int] = {}
        for w in ws:
            for name, ent in w.counters.items():
                cur = counters.get(name)
                if cur is None:
                    counters[name] = dict(ent)
                else:
                    cur["last"] = ent["last"]
                    cur["max"] = max(cur["max"], ent["max"])
                    cur["n"] += ent["n"]
            for name, cnt in w.instants.items():
                instants[name] = instants.get(name, 0) + cnt
        return {"window_s": self.window_s, "windows": len(ws),
                "events": sum(w.events for w in ws),
                "spans": spans, "counters": counters,
                "instants": instants}

    def fraction_leq(self, name: str, threshold_s: float,
                     window: RollupWindow) -> tuple[int, int]:
        """(samples ≤ threshold, total samples) for one span in one
        window, at bucket resolution: a bucket counts as "good" when its
        geometric midpoint is ≤ the threshold."""
        agg = window.spans.get(name)
        if agg is None or agg.count == 0:
            return 0, 0
        good = 0
        for i, c in enumerate(agg.counts):
            if not c:
                continue
            if i == 0:
                mid = self.bounds[0]
            elif i >= len(self.bounds):
                mid = self.bounds[-1]
            else:
                mid = math.sqrt(self.bounds[i - 1] * self.bounds[i])
            if mid <= threshold_s:
                good += c
        return good, agg.count


# -- SLOs ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Slo:
    """One declarative objective: "at least ``objective`` of the events
    must be good", where *good* depends on ``kind``:

      * ``latency``   — span ``span`` samples ≤ ``threshold_s``
      * ``bad_fraction`` — span completions not flagged dropped/error
      * ``pipeline_ratio`` — Δ``good_fields`` / Δ``total_fields`` over
        the window (or ``1 − Δbad/Δtotal`` when ``bad_fields`` is set),
        from the session's ``PipelineStats`` counter deltas

    Burn rate over a window span = (1 − good fraction) / (1 − objective);
    1.0 means the error budget is being spent exactly at the sustainable
    rate. The alert fires when BOTH the fast (last ``fast_windows``) and
    the slow (last ``slow_windows``) burn rates are ≥ ``burn_threshold``,
    and resolves when the fast one recovers below it.
    """

    name: str
    objective: float
    kind: str
    span: str | None = None
    threshold_s: float | None = None
    good_fields: tuple = ()
    bad_fields: tuple = ()
    total_fields: tuple = ()
    fast_windows: int = 3
    slow_windows: int = 12
    burn_threshold: float = 4.0

    def __post_init__(self):
        if not 0.0 < self.objective < 1.0:
            raise ValueError(f"objective must be in (0, 1), "
                             f"got {self.objective}")
        if self.kind not in ("latency", "bad_fraction", "pipeline_ratio"):
            raise ValueError(f"unknown Slo kind {self.kind!r}")
        if self.kind == "latency" and (self.span is None
                                       or self.threshold_s is None):
            raise ValueError("latency Slo needs span and threshold_s")
        if self.kind == "bad_fraction" and self.span is None:
            raise ValueError("bad_fraction Slo needs span")
        if self.kind == "pipeline_ratio":
            if not self.total_fields or not (bool(self.good_fields)
                                             ^ bool(self.bad_fields)):
                raise ValueError("pipeline_ratio Slo needs total_fields "
                                 "and exactly one of good_fields/"
                                 "bad_fields")
        if self.fast_windows > self.slow_windows:
            raise ValueError("fast_windows must be ≤ slow_windows")

    # -- constructors ----------------------------------------------------------
    @staticmethod
    def latency(name: str, span: str, threshold_s: float,
                objective: float = 0.95, **kw) -> "Slo":
        """"``objective`` of ``span`` samples complete within
        ``threshold_s``" — e.g. a serve p95 latency objective."""
        return Slo(name, objective, "latency", span=span,
                   threshold_s=threshold_s, **kw)

    @staticmethod
    def drop_rate(name: str, span: str = "serve.request",
                  objective: float = 0.99, **kw) -> "Slo":
        """"``objective`` of requests complete un-dropped" (deadline
        drops and errors both count against the budget)."""
        return Slo(name, objective, "bad_fraction", span=span, **kw)

    @staticmethod
    def ratio(name: str, good_fields, total_fields, objective: float,
              **kw) -> "Slo":
        """Pipeline-counter ratio objective, e.g. warm-cache hit rate:
        Δgood / Δtotal ≥ objective per window."""
        return Slo(name, objective, "pipeline_ratio",
                   good_fields=tuple(good_fields),
                   total_fields=tuple(total_fields), **kw)

    @staticmethod
    def budget_rate(name: str, bad_fields, total_fields,
                    objective: float, **kw) -> "Slo":
        """Pipeline-counter *budget* objective, e.g. io_retries:
        1 − Δbad/Δtotal ≥ objective per window."""
        return Slo(name, objective, "pipeline_ratio",
                   bad_fields=tuple(bad_fields),
                   total_fields=tuple(total_fields), **kw)


def default_serving_slos(latency_threshold_s: float = 0.25,
                         availability: float = 0.99,
                         hit_rate: float = 0.5,
                         goodput: float = 0.9,
                         retry_budget: float = 0.01) -> tuple:
    """The serving objectives a fresh ``attach_live()`` watches."""
    return (
        Slo.latency("serve_p95_latency", "serve.request",
                    latency_threshold_s, objective=0.95),
        Slo.drop_rate("serve_availability", objective=availability),
        Slo.ratio("cache_hit_rate", ("query_warm_hits",),
                  ("query_warm_hits", "query_reads",
                   "query_fallback_reads"), objective=hit_rate),
        Slo.budget_rate("serve_goodput", ("deadline_drops",),
                        ("queries", "deadline_drops"),
                        objective=goodput),
        Slo.budget_rate("io_retry_budget", ("io_retries",),
                        ("loads", "query_reads", "query_fallback_reads"),
                        objective=1.0 - retry_budget),
    )


@dataclasses.dataclass
class Alert:
    """One SLO state transition (``firing`` or ``resolved``)."""

    slo: str
    state: str
    t: float                 # window close time (tracer clock)
    fast_burn: float
    slow_burn: float
    good_fraction: float | None
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class SloMonitor:
    """Evaluates ``Slo`` specs on every closed window of a ``TimeSeries``.

    ``pipeline_source`` (a ``PipelineStats.snapshot`` callable) feeds the
    counter-delta objectives; per-window deltas are diffed here so the
    cumulative counters never dilute a fresh regression. Alerts go to
    ``on_alert`` callbacks, the ``tracer`` as ``slo.alert`` instants, and
    ``metrics`` counters (``slo.alerts_fired``/``slo.alerts_resolved``,
    gauge ``slo.firing``).
    """

    def __init__(self, timeseries: TimeSeries, slos, *,
                 pipeline_source=None, tracer=None, metrics=None,
                 on_alert=None, history: int = 256):
        self.ts = timeseries
        self.slos = list(slos)
        self._pipeline_source = pipeline_source
        self._tracer = tracer
        self._metrics = metrics
        self._cbs = [on_alert] if on_alert is not None else []
        self._lock = threading.RLock()
        self._prev_pipe = self._numeric(pipeline_source()) \
            if pipeline_source else None
        depth = max([s.slow_windows for s in self.slos] or [1])
        self._entries: deque = deque(maxlen=depth)
        self._state = {s.name: {"firing": False, "since": None,
                                "fast_burn": 0.0, "slow_burn": 0.0,
                                "good_fraction": None}
                       for s in self.slos}
        self.alerts: deque[Alert] = deque(maxlen=history)
        self.fired = 0
        self.resolved = 0
        timeseries.subscribe(self._on_window)

    @staticmethod
    def _numeric(snap: dict) -> dict:
        return {k: v for k, v in snap.items()
                if isinstance(v, (int, float)) and not isinstance(v, bool)}

    def close(self) -> None:
        self.ts.unsubscribe(self._on_window)

    def on_alert(self, fn) -> None:
        """Add an alert callback (``fn(Alert)``)."""
        with self._lock:
            self._cbs.append(fn)

    # -- evaluation (window-close cadence) ------------------------------------
    def _on_window(self, window: RollupWindow) -> None:
        delta = None
        if self._pipeline_source is not None:
            snap = self._numeric(self._pipeline_source())
            prev, self._prev_pipe = self._prev_pipe, snap
            delta = {k: v - prev.get(k, 0) for k, v in snap.items()}
        with self._lock:
            self._entries.append((window, delta))
            for slo in self.slos:
                self._evaluate(slo, window)

    def _good_total(self, slo: Slo, window: RollupWindow,
                    delta: dict | None) -> tuple[float, float]:
        if slo.kind == "latency":
            return self.ts.fraction_leq(slo.span, slo.threshold_s, window)
        if slo.kind == "bad_fraction":
            agg = window.spans.get(slo.span)
            if agg is None:
                return 0, 0
            return agg.count - agg.bad, agg.count
        if delta is None:
            return 0, 0
        total = sum(delta.get(f, 0) for f in slo.total_fields)
        if total <= 0:
            return 0, 0
        if slo.good_fields:
            good = sum(delta.get(f, 0) for f in slo.good_fields)
        else:
            good = total - sum(delta.get(f, 0) for f in slo.bad_fields)
        return max(0.0, min(good, total)), total

    def _burn(self, slo: Slo, n: int) -> tuple[float, float | None]:
        """(burn rate, good fraction) over the last ``n`` entries. No
        traffic ⇒ burn 0 (idle systems don't spend error budget)."""
        good = total = 0.0
        for window, delta in list(self._entries)[-n:]:
            g, t = self._good_total(slo, window, delta)
            good += g
            total += t
        if total <= 0:
            return 0.0, None
        frac = good / total
        return (1.0 - frac) / (1.0 - slo.objective), frac

    def _evaluate(self, slo: Slo, window: RollupWindow) -> None:
        fast, frac = self._burn(slo, slo.fast_windows)
        slow, _ = self._burn(slo, slo.slow_windows)
        st = self._state[slo.name]
        st["fast_burn"], st["slow_burn"] = fast, slow
        st["good_fraction"] = frac
        thr = slo.burn_threshold
        if not st["firing"] and fast >= thr and slow >= thr:
            st["firing"] = True
            st["since"] = window.t1
            self.fired += 1
            if self._metrics is not None:
                self._metrics.counter("slo.alerts_fired").inc()
            self._emit(slo, "firing", window, fast, slow, frac)
        elif st["firing"] and fast < thr:
            st["firing"] = False
            st["since"] = None
            self.resolved += 1
            if self._metrics is not None:
                self._metrics.counter("slo.alerts_resolved").inc()
            self._emit(slo, "resolved", window, fast, slow, frac)
        if self._metrics is not None:
            firing = sum(1 for s in self._state.values() if s["firing"])
            self._metrics.gauge("slo.firing").set(firing)

    def _emit(self, slo: Slo, state: str, window: RollupWindow,
              fast: float, slow: float, frac: float | None) -> None:
        msg = (f"SLO {slo.name} {state}: burn fast={fast:.2f} "
               f"slow={slow:.2f} (threshold {slo.burn_threshold:g}, "
               f"objective {slo.objective:g})")
        alert = Alert(slo.name, state, window.t1, fast, slow, frac, msg)
        self.alerts.append(alert)
        if self._tracer is not None:
            self._tracer.instant("slo.alert", slo=slo.name, state=state,
                                 fast_burn=round(fast, 3),
                                 slow_burn=round(slow, 3))
        for fn in list(self._cbs):
            try:
                fn(alert)
            except Exception:
                pass

    # -- views ----------------------------------------------------------------
    def status(self) -> dict:
        with self._lock:
            out = {}
            for slo in self.slos:
                st = self._state[slo.name]
                out[slo.name] = {
                    "state": "firing" if st["firing"] else "ok",
                    "objective": slo.objective,
                    "fast_burn": st["fast_burn"],
                    "slow_burn": st["slow_burn"],
                    "good_fraction": st["good_fraction"],
                    "since": st["since"],
                }
            return out

    def active_alerts(self) -> list[dict]:
        with self._lock:
            return [{"slo": name, "since": st["since"],
                     "fast_burn": st["fast_burn"],
                     "slow_burn": st["slow_burn"]}
                    for name, st in self._state.items() if st["firing"]]

    def section(self) -> dict:
        return {"slos": self.status(),
                "alerts": {"fired": self.fired,
                           "resolved": self.resolved,
                           "active": self.active_alerts()}}


# -- live cost calibration ----------------------------------------------------

class LiveCalibrator:
    """Rolling span-derived unit costs for ``CostModel``'s ``live`` tier.

    Per closed window, the rollup already holds each span's summed
    duration and unit count; the calibrator takes the *median* of the
    per-window seconds-per-unit ratios over the last ``windows`` windows
    — robust to one outlier window, O(windows) memory, and quick to
    converge after a regime shift (a stale window falls out of the
    median after ``windows`` closes, where a cumulative mean would
    remember it forever).
    """

    READ_SPAN = "io.read"
    XFER_SPAN = "link.xfer"

    def __init__(self, timeseries: TimeSeries, *, windows: int = 8,
                 min_samples: int = 4):
        self.ts = timeseries
        self.windows = max(1, int(windows))
        self.min_samples = max(1, int(min_samples))

    def read_s_per_bucket(self) -> dict | None:
        rows = self.ts.unit_cost_series(self.READ_SPAN, self.windows)
        n = sum(c for _, c in rows)
        if n < self.min_samples:
            return None
        return {"value": statistics.median(r for r, _ in rows),
                "samples": n, "windows": len(rows)}

    def link_gb_s(self) -> dict | None:
        rows = self.ts.unit_cost_series(self.XFER_SPAN, self.windows)
        n = sum(c for _, c in rows)
        if n < self.min_samples:
            return None
        # rows are seconds per byte; median then convert to GB/s
        s_per_byte = statistics.median(r for r, _ in rows)
        if s_per_byte <= 0:
            return None
        return {"value": 1.0 / (s_per_byte * 1e9),
                "samples": n, "windows": len(rows)}

    def constants(self) -> dict:
        """``{coefficient: {value, samples, windows}}`` for every
        coefficient with enough recent samples — the shape
        ``CostModel.from_telemetry(live=...)`` consumes."""
        out = {}
        read = self.read_s_per_bucket()
        if read is not None:
            out["read_s_per_bucket"] = read
        link = self.link_gb_s()
        if link is not None:
            out["h2d_gb_s"] = link
        return out

    def section(self) -> dict | None:
        c = self.constants()
        return c or None


# -- session bundle -----------------------------------------------------------

class LiveObserver:
    """One session's live-observability bundle: a ``TimeSeries`` sink on
    the session tracer, an optional ``SloMonitor``, and an optional
    ``LiveCalibrator``. Constructed by ``DiskJoinIndex.attach_live()``;
    ``section()`` is the ``live`` provider in ``metrics_snapshot()``.
    """

    def __init__(self, tracer, *, window_s: float = 1.0,
                 windows: int = 60, slos=None, pipeline_source=None,
                 metrics=None, on_alert=None, calibrate: bool = True,
                 calibrate_windows: int = 8, calibrate_min_samples: int = 4,
                 owns_tracing: bool = False, hist_factor: float = 2.0):
        self.tracer = tracer
        self.owns_tracing = bool(owns_tracing)
        self.timeseries = TimeSeries(window_s=window_s, windows=windows,
                                     factor=hist_factor)
        self.monitor = None
        if slos:
            self.monitor = SloMonitor(self.timeseries, slos,
                                      pipeline_source=pipeline_source,
                                      tracer=tracer, metrics=metrics,
                                      on_alert=on_alert)
        self.calibrator = None
        if calibrate:
            self.calibrator = LiveCalibrator(
                self.timeseries, windows=calibrate_windows,
                min_samples=calibrate_min_samples)
        self._closed = False
        tracer.add_sink(self.timeseries.on_event)

    def poll(self) -> None:
        """Close overdue windows (traffic gaps don't freeze the view)."""
        self.timeseries.poll()

    def live_constants(self) -> dict:
        """Calibrator constants (``{}`` when calibration is off or has
        too few samples) — what ``_planner_for`` feeds the cost model."""
        if self.calibrator is None:
            return {}
        return self.calibrator.constants()

    def slo_firing(self) -> int:
        """Number of SLOs currently firing (0 without a monitor) — the
        burn-state fold consumed by ``serve.replica.HealthTracker``: a
        replica whose SLOs are burning is DEGRADED for routing even
        before individual requests visibly fail."""
        if self.monitor is None:
            return 0
        self.timeseries.poll()
        return len(self.monitor.active_alerts())

    def section(self) -> dict:
        # a scrape wants the windows as of *now* — close overdue ones so
        # a traffic gap doesn't freeze the reported aggregates
        self.timeseries.poll()
        out = self.timeseries.section()
        if self.monitor is not None:
            out.update(self.monitor.section())
        if self.calibrator is not None:
            out["calibration"] = self.calibrator.section()
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.tracer.remove_sink(self.timeseries.on_event)
        if self.monitor is not None:
            self.monitor.close()
        if self.owns_tracing:
            from repro.obs.tracer import disable_tracing, get_tracer
            if get_tracer() is self.tracer:
                disable_tracing()


# -- fleet rollup -------------------------------------------------------------

def merge_live_sections(sections: list[dict]) -> dict:
    """Merge per-shard ``live`` sections into one fleet view: span
    histograms merge *exactly* (same geometric bounds ⇒ counts add,
    percentiles re-derived — never an average of shard percentiles),
    counts/instants/alert totals sum, SLO states take the worst, and
    per-shard calibrations are kept as a list (unit costs of different
    hardware don't average meaningfully). Zero-traffic shards contribute
    empty sections and merge cleanly."""
    sections = [s for s in sections if isinstance(s, dict)]
    out: dict = {"window_s": None, "windows": 0, "events": 0,
                 "spans": {}, "counters": {}, "instants": {}}
    from repro.obs.metrics import MetricsRegistry
    for s in sections:
        if out["window_s"] is None:
            out["window_s"] = s.get("window_s")
        out["windows"] = max(out["windows"], s.get("windows", 0))
        out["events"] += s.get("events", 0)
        for name, agg in (s.get("spans") or {}).items():
            if agg is None:
                continue
            cur = out["spans"].get(name)
            merged = MetricsRegistry._merge_hist(cur, agg)
            # _merge_hist covers the histogram part; sum the extras
            for k in ("total_s", "units", "bad"):
                merged[k] = ((cur or {}).get(k, 0)
                             + agg.get(k, 0)) if cur else agg.get(k, 0)
            out["spans"][name] = merged
        for name, ent in (s.get("counters") or {}).items():
            cur = out["counters"].get(name)
            if cur is None:
                out["counters"][name] = dict(ent)
            else:
                cur["max"] = max(cur["max"], ent["max"])
                cur["last"] = max(cur["last"], ent["last"])
                cur["n"] += ent["n"]
        for name, cnt in (s.get("instants") or {}).items():
            out["instants"][name] = out["instants"].get(name, 0) + cnt
    # SLO/alert rollup
    slos: dict = {}
    alerts = {"fired": 0, "resolved": 0, "active": []}
    any_slo = False
    for s in sections:
        a = s.get("alerts")
        if a:
            alerts["fired"] += a.get("fired", 0)
            alerts["resolved"] += a.get("resolved", 0)
            alerts["active"].extend(a.get("active", []))
        for name, st in (s.get("slos") or {}).items():
            any_slo = True
            cur = slos.get(name)
            if cur is None:
                slos[name] = dict(st)
            else:
                if st.get("state") == "firing":
                    cur["state"] = "firing"
                cur["fast_burn"] = max(cur.get("fast_burn", 0.0),
                                       st.get("fast_burn", 0.0))
                cur["slow_burn"] = max(cur.get("slow_burn", 0.0),
                                       st.get("slow_burn", 0.0))
    if any_slo or any("alerts" in s for s in sections):
        out["slos"] = slos
        out["alerts"] = alerts
    cals = [s.get("calibration") for s in sections if s.get("calibration")]
    if cals:
        out["calibration"] = cals
    return out
