"""One-screen text dashboard over a live session's rollups and alerts.

``render(target)`` returns a terminal-sized snapshot string;
``watch(target)`` re-renders on an interval. ``target`` is a
``DiskJoinIndex`` (with ``attach_live()`` called), an ``IndexRouter``
whose shards have live observers, or a bare ``LiveObserver``::

    index.attach_live(window_s=1.0)
    ... serve traffic ...
    print(repro.obs.dash.render(index))

The dashboard is pull-based: each render polls the rollup (closing any
overdue windows), reads the merged ``live`` section, and formats spans
(rate + p50/p95/p99), counters, SLO burn states, active alerts, and the
live cost-model constants. No background thread, no extra bookkeeping —
everything shown is already in ``metrics_snapshot()["live"]``.
"""
from __future__ import annotations

import sys
import time

from repro.obs.live import LiveObserver, merge_live_sections


def _fmt_s(v: float) -> str:
    """Duration → human units (µs/ms/s)."""
    if v <= 0:
        return "0"
    if v < 1e-3:
        return f"{v * 1e6:.0f}µs"
    if v < 1.0:
        return f"{v * 1e3:.1f}ms"
    return f"{v:.2f}s"


def _observers(target) -> list[LiveObserver]:
    if isinstance(target, LiveObserver):
        return [target]
    live = getattr(target, "live", None)           # DiskJoinIndex
    if live is not None:
        return [live]
    shards = getattr(target, "shards", None)       # IndexRouter
    if shards is not None:
        return [s.live for s in shards if s.live is not None]
    raise TypeError(
        f"dash target must be a DiskJoinIndex/IndexRouter with live "
        f"observability attached (attach_live()) or a LiveObserver, "
        f"got {type(target).__name__}")


def render(target, *, width: int = 78, title: str = "DiskJoin live"
           ) -> str:
    """One-screen text snapshot of rollups + SLOs + alerts."""
    observers = _observers(target)
    if not observers:
        return f"{title}: no live observers attached"
    for obs in observers:
        obs.poll()
    sections = [obs.section() for obs in observers]
    sec = sections[0] if len(sections) == 1 else \
        merge_live_sections(sections)

    lines = []
    head = (f"{title} · {len(observers)} session(s) · window "
            f"{sec.get('window_s', 0):g}s × {sec.get('windows', 0)} · "
            f"{sec.get('events', 0)} events")
    lines.append(head[:width])
    lines.append("─" * min(width, len(head)))

    spans = {n: a for n, a in (sec.get("spans") or {}).items() if a}
    if spans:
        lines.append(f"{'span':<24}{'n':>8}{'rate/s':>9}{'p50':>9}"
                     f"{'p95':>9}{'p99':>9}")
        horizon_s = (sec.get("window_s") or 1.0) * max(
            1, sec.get("windows") or 1)
        for name in sorted(spans):
            a = spans[name]
            lines.append(
                f"  {name:<22}{a['count']:>8}"
                f"{a['count'] / horizon_s:>9.1f}"
                f"{_fmt_s(a.get('p50', 0)):>9}"
                f"{_fmt_s(a.get('p95', 0)):>9}"
                f"{_fmt_s(a.get('p99', 0)):>9}")
    else:
        lines.append("(no spans in the retained windows — is tracing "
                     "enabled and traffic flowing?)")

    counters = sec.get("counters") or {}
    if counters:
        row = "  ".join(f"{n}={c['last']:g}(max {c['max']:g})"
                        for n, c in sorted(counters.items()))
        lines.append(f"counters: {row}"[:width])
    instants = sec.get("instants") or {}
    if instants:
        row = "  ".join(f"{n}×{c}" for n, c in sorted(instants.items()))
        lines.append(f"instants: {row}"[:width])

    cal = sec.get("calibration")
    if cal:
        cals = cal if isinstance(cal, list) else [cal]
        for i, c in enumerate(cals):
            parts = []
            r = c.get("read_s_per_bucket")
            if r:
                parts.append(f"read={_fmt_s(r['value'])}/bucket "
                             f"({r['samples']} spans/{r['windows']}w)")
            l = c.get("h2d_gb_s")
            if l:
                parts.append(f"link={l['value']:.2f} GB/s "
                             f"({l['samples']} spans)")
            tag = f" shard{i}" if len(cals) > 1 else ""
            lines.append(f"live cost{tag}: " + ", ".join(parts))

    slos = sec.get("slos") or {}
    if slos:
        lines.append("slos:")
        for name in sorted(slos):
            st = slos[name]
            state = st.get("state", "ok").upper()
            good = st.get("good_fraction")
            good_s = "  n/a " if good is None else f"{good:6.1%}"
            lines.append(
                f"  {name:<22}{state:>7}  good={good_s}  burn "
                f"fast={st.get('fast_burn', 0):.2f} "
                f"slow={st.get('slow_burn', 0):.2f}")
    alerts = sec.get("alerts") or {}
    if alerts:
        active = alerts.get("active", [])
        lines.append(f"alerts: {len(active)} active · "
                     f"{alerts.get('fired', 0)} fired · "
                     f"{alerts.get('resolved', 0)} resolved")
        for a in active:
            lines.append(f"  [FIRING] {a.get('slo')} burn "
                         f"fast={a.get('fast_burn', 0):.2f} "
                         f"slow={a.get('slow_burn', 0):.2f}")
    return "\n".join(lines)


def watch(target, *, interval_s: float = 2.0,
          iterations: int | None = None, out=None, clear: bool = True
          ) -> None:
    """Re-render ``target`` every ``interval_s`` seconds until
    interrupted (or for ``iterations`` renders — tests/demos pass a
    bound). ``clear`` prefixes the ANSI home+clear sequence so the
    screen updates in place."""
    out = out if out is not None else sys.stdout
    i = 0
    try:
        while iterations is None or i < iterations:
            text = render(target)
            if clear:
                out.write("\x1b[H\x1b[2J")
            out.write(text + "\n")
            out.flush()
            i += 1
            if iterations is not None and i >= iterations:
                break
            time.sleep(interval_s)
    except KeyboardInterrupt:
        pass
