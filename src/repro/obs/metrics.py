"""Metrics registry: counters, gauges and log-bucketed histograms.

One ``MetricsRegistry`` per index session is the single metrics surface
DiskJoin components publish into: typed instruments created through
``counter``/``gauge``/``histogram`` get-or-create calls, plus *providers*
— named snapshot-time callables that fold existing stats objects
(``PipelineStats``, the store's ``IOStats``, a scheduler's wave stats, a
query service's latency percentiles) into the same ``snapshot()`` /
``to_json()`` output without duplicating their bookkeeping.

Histograms use **fixed log-scale buckets** (geometric bounds, factor
``factor`` apart between ``lo`` and ``hi``): two histograms created with
the same parameters are bucket-compatible, which is what makes
``MetricsRegistry.merge`` an *exact* rollup — counts add element-wise
and percentiles are re-derived from the merged counts, instead of the
meaningless "average of shard p95s". ``merge`` is the router/fleet
aggregation seed: counters sum, gauges take the max, histograms merge
by bucket, and any provider sections are collected per-shard.

Naming convention (see ``repro/obs/README.md``): dotted lowercase
``subsystem.metric``, unit-suffixed where not obvious
(``serve.latency_s``, ``io.read_bytes``).
"""
from __future__ import annotations

import bisect
import json
import math
import threading


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n=1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v


class Gauge:
    """Point-in-time reading (last set wins)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def set(self, v) -> None:
        with self._lock:
            self._v = v

    def max(self, v) -> None:
        """High-watermark update."""
        with self._lock:
            if v > self._v:
                self._v = v

    @property
    def value(self):
        return self._v


def log_bounds(lo: float, hi: float, factor: float) -> list[float]:
    """Geometric bucket upper bounds: lo, lo·f, lo·f², … ≥ hi."""
    if lo <= 0 or hi <= lo or factor <= 1.0:
        raise ValueError(f"need 0 < lo < hi and factor > 1, "
                         f"got lo={lo}, hi={hi}, factor={factor}")
    n = max(1, math.ceil(math.log(hi / lo, factor)))
    return [lo * factor ** i for i in range(n + 1)]


class Histogram:
    """Fixed log-scale-bucket histogram (plus exact count/sum/min/max).

    ``observe(v)`` lands ``v`` in the first bucket whose upper bound is
    ≥ v; values past the top bound land in a final overflow bucket,
    values ≤ the lowest bound in the first. Percentiles interpolate at
    the geometric midpoint of the winning bucket — resolution is the
    bucket ``factor`` (default 2, i.e. percentiles within 2×), which is
    the price of mergeability and O(1) memory.
    """

    __slots__ = ("name", "bounds", "counts", "count", "sum",
                 "min", "max", "_lock")

    def __init__(self, name: str, *, lo: float = 1e-6, hi: float = 1e4,
                 factor: float = 2.0):
        self.name = name
        self.bounds = log_bounds(lo, hi, factor)
        self.counts = [0] * (len(self.bounds) + 1)  # +1 overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    @staticmethod
    def percentile_from(bounds: list[float], counts: list[int],
                        q: float) -> float:
        """q-th percentile (0–100) from bucket counts — shared by live
        histograms and merged snapshots."""
        total = sum(counts)
        if total == 0:
            return 0.0
        rank = max(1, math.ceil(total * min(max(q, 0.0), 100.0) / 100.0))
        run = 0
        for i, c in enumerate(counts):
            run += c
            if run >= rank:
                if i == 0:
                    return bounds[0]
                if i >= len(bounds):
                    return bounds[-1]
                return math.sqrt(bounds[i - 1] * bounds[i])
        return bounds[-1]

    def percentile(self, q: float) -> float:
        with self._lock:
            return self.percentile_from(self.bounds, self.counts, q)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "p50": self.percentile_from(self.bounds, self.counts, 50),
                "p95": self.percentile_from(self.bounds, self.counts, 95),
                "p99": self.percentile_from(self.bounds, self.counts, 99),
                "bounds": list(self.bounds),
                "buckets": list(self.counts),
            }


class MetricsRegistry:
    """Get-or-create instrument registry + provider snapshot surface."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}
        self._providers: dict[str, object] = {}

    # -- instruments ----------------------------------------------------------
    def _get_or_create(self, name: str, kind, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = factory()
                self._instruments[name] = inst
            elif not isinstance(inst, kind):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {kind.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str, *, lo: float = 1e-6, hi: float = 1e4,
                  factor: float = 2.0) -> Histogram:
        return self._get_or_create(
            name, Histogram,
            lambda: Histogram(name, lo=lo, hi=hi, factor=factor))

    # -- providers ------------------------------------------------------------
    def register_provider(self, name: str, fn) -> str:
        """Attach a snapshot-time callable (→ dict) under ``name``. A
        taken name gets a ``#k`` suffix (two services on one session must
        not shadow each other); the actual key is returned — keep it for
        ``unregister_provider``."""
        with self._lock:
            key, k = name, 2
            while key in self._providers:
                key = f"{name}#{k}"
                k += 1
            self._providers[key] = fn
            return key

    def unregister_provider(self, name: str) -> None:
        with self._lock:
            self._providers.pop(name, None)

    # -- snapshot surface -----------------------------------------------------
    def snapshot(self) -> dict:
        """One dict: typed instruments under ``counters``/``gauges``/
        ``histograms``, each provider's dict under its own key. A raising
        provider contributes ``{"error": ...}`` instead of killing the
        whole surface (telemetry must not take the session down)."""
        with self._lock:
            instruments = dict(self._instruments)
            providers = dict(self._providers)
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, inst in sorted(instruments.items()):
            if isinstance(inst, Counter):
                out["counters"][name] = inst.value
            elif isinstance(inst, Gauge):
                out["gauges"][name] = inst.value
            else:
                out["histograms"][name] = inst.snapshot()
        for name, fn in sorted(providers.items()):
            try:
                out[name] = fn()
            except Exception as e:  # pragma: no cover - defensive
                out[name] = {"error": repr(e)}
        return out

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, default=str)

    # -- rollup ---------------------------------------------------------------
    @staticmethod
    def merge(snapshots: list[dict]) -> dict:
        """Merge ``snapshot()`` dicts from several registries (e.g. one
        per router shard) into one rollup: counters sum, gauges max,
        bucket-compatible histograms merge exactly (counts added,
        percentiles re-derived); histograms with differing bounds fall
        back to count/sum/min/max only. Provider sections (any other
        top-level key) are collected as per-shard lists under the same
        key — domain-aware merges (``PipelineStats.merge``) happen at
        the caller."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        sections: dict[str, list] = {}
        for snap in snapshots:
            for name, v in snap.get("counters", {}).items():
                out["counters"][name] = out["counters"].get(name, 0) + v
            for name, v in snap.get("gauges", {}).items():
                cur = out["gauges"].get(name)
                out["gauges"][name] = v if cur is None else max(cur, v)
            for name, h in snap.get("histograms", {}).items():
                out["histograms"][name] = MetricsRegistry._merge_hist(
                    out["histograms"].get(name), h)
            for key, v in snap.items():
                if key not in ("counters", "gauges", "histograms"):
                    sections.setdefault(key, []).append(v)
        out.update(sections)
        return out

    @staticmethod
    def _merge_hist(acc: dict | None, h: dict) -> dict:
        if acc is None:
            return {k: (list(v) if isinstance(v, list) else v)
                    for k, v in h.items()}
        merged = dict(acc)
        merged["count"] = acc["count"] + h["count"]
        merged["sum"] = acc["sum"] + h["sum"]
        if h["count"]:
            merged["min"] = (min(acc["min"], h["min"]) if acc["count"]
                             else h["min"])
            merged["max"] = (max(acc["max"], h["max"]) if acc["count"]
                             else h["max"])
        if (acc.get("bounds") and h.get("bounds")
                and acc["bounds"] == h["bounds"]):
            merged["buckets"] = [a + b for a, b in zip(acc["buckets"],
                                                       h["buckets"])]
            for q, key in ((50, "p50"), (95, "p95"), (99, "p99")):
                merged[key] = Histogram.percentile_from(
                    merged["bounds"], merged["buckets"], q)
        else:  # incompatible buckets: exact aggregates only
            for key in ("p50", "p95", "p99", "bounds", "buckets"):
                merged.pop(key, None)
        return merged
