"""Alert webhooks: push SLO burn alerts out of the process.

``SloMonitor`` delivers alerts to in-process callbacks on the window
fold path — the thread that closes rollup windows. Anything slow there
(a network call most of all) would stall the fold and distort the very
latencies being monitored. ``WebhookSink`` decouples the two: the
callback only enqueues the alert into a bounded queue (dropping, and
counting the drop, when full — never blocking); a daemon thread POSTs
queued alerts as JSON via stdlib ``urllib``. Delivery failures are
counted, never raised — losing a webhook must not take down serving.

Usage::

    sink = WebhookSink("http://alerts.example/hook")
    observer = index.attach_live(slos=default_serving_slos())
    observer.monitor.on_alert(sink)
    ...
    sink.close()
    sink.snapshot()   # {"delivered": ..., "dropped": ..., "failures": ...}
"""
from __future__ import annotations

import json
import queue
import threading
import urllib.request

_CLOSE = object()


class WebhookSink:
    """Non-blocking ``SloMonitor.on_alert`` sink POSTing alerts as JSON.

    Parameters:
      url: webhook endpoint (http/https).
      queue_size: bounded backlog; alerts beyond it are dropped and
        counted (``dropped``) — the fold path never waits.
      timeout_s: per-POST socket timeout.
      headers: extra HTTP headers (merged over Content-Type).
    """

    def __init__(self, url: str, *, queue_size: int = 256,
                 timeout_s: float = 2.0, headers: dict | None = None):
        self.url = str(url)
        self.timeout_s = float(timeout_s)
        self.headers = {"Content-Type": "application/json",
                        **(headers or {})}
        self._q: queue.Queue = queue.Queue(maxsize=int(queue_size))
        self._lock = threading.Lock()
        self.delivered = 0
        self.dropped = 0
        self.failures = 0
        self._thread = threading.Thread(target=self._loop,
                                        name="diskjoin-webhook",
                                        daemon=True)
        self._thread.start()

    # -- fold-path side (must never block or raise) ---------------------------
    def __call__(self, alert) -> None:
        payload = alert.to_dict() if hasattr(alert, "to_dict") else dict(
            alert if isinstance(alert, dict) else vars(alert))
        try:
            self._q.put_nowait(payload)
        except queue.Full:
            with self._lock:
                self.dropped += 1

    # -- delivery side --------------------------------------------------------
    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is _CLOSE:
                return
            try:
                self._post(item)
            except Exception:
                with self._lock:
                    self.failures += 1
            else:
                with self._lock:
                    self.delivered += 1

    def _post(self, payload: dict) -> None:
        req = urllib.request.Request(
            self.url, data=json.dumps(payload).encode(),
            headers=self.headers, method="POST")
        with urllib.request.urlopen(req, timeout=self.timeout_s):
            pass

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout: float = 10.0) -> None:
        """Flush queued alerts (best effort) and stop the thread."""
        self._q.put(_CLOSE)
        self._thread.join(timeout=timeout)

    def snapshot(self) -> dict:
        with self._lock:
            return {"url": self.url, "delivered": self.delivered,
                    "dropped": self.dropped, "failures": self.failures,
                    "queued": self._q.qsize()}
